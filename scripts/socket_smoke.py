#!/usr/bin/env python3
"""CI socket-serve smoke (job `socket-smoke`): boot `wasi-train serve
--listen`, drive it with several concurrent framed clients, and assert
the front-end's contract from the outside.

    python3 scripts/socket_smoke.py BIN ARTIFACTS_DIR NET_STATS_OUT

What is exercised, all at once over real TCP connections:
* a training submit followed by a streamed `events wait:true`
  subscription that must deliver started -> steps -> done in order;
* concurrent `infer` traffic at f32, bf16, and i8, each request
  tagged with a unique framing-layer `"id"` that must echo back on
  exactly its own connection;
* one abrupt mid-stream disconnect (a client that subscribes to a job
  stream and vanishes without reading), which must not wedge anything;
* a `stats` snapshot (written to NET_STATS_OUT for the CI artifact)
  whose counters must reflect the traffic above;
* a protocol `shutdown`, after which the server process must drain and
  exit 0 on its own.

Stdlib only — the framing is 4-byte big-endian length + JSON payload
(rust/src/net/frame.rs).
"""

import json
import socket
import struct
import subprocess
import sys
import threading


class Client:
    """One framed JSON connection."""

    def __init__(self, addr):
        self.sock = socket.create_connection(addr, timeout=120)

    def send(self, obj):
        payload = json.dumps(obj).encode()
        self.sock.sendall(struct.pack(">I", len(payload)) + payload)

    def _read_exact(self, n):
        buf = b""
        while len(buf) < n:
            chunk = self.sock.recv(n - len(buf))
            if not chunk:
                raise EOFError("connection closed mid frame")
            buf += chunk
        return buf

    def recv(self):
        (length,) = struct.unpack(">I", self._read_exact(4))
        return json.loads(self._read_exact(length))

    def close(self):
        self.sock.close()


def fail(msg):
    print(f"socket-smoke: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def expect(cond, msg):
    if not cond:
        fail(msg)


def train_session(addr, errors):
    """Submit a short job and consume its full event stream."""
    try:
        c = Client(addr)
        c.send({"cmd": "submit", "model": "vit_demo_wasi_eps80", "steps": 5,
                "samples": 32, "engine": "native", "precision": "bf16",
                "id": "train-submit"})
        resp = c.recv()
        expect(resp.get("ok") is True, f"submit rejected: {resp}")
        expect(resp.get("id") == "train-submit", f"submit id mangled: {resp}")
        job = resp["job"]
        c.send({"cmd": "events", "job": job, "wait": True, "id": "train-events"})
        events = []
        while True:
            line = c.recv()
            expect(line.get("id") == "train-events", f"stream line untagged: {line}")
            if "event" in line:
                events.append(line["event"])
                continue
            # Final status line after the stream disconnects.
            expect(line.get("ok") is True and line.get("state") == "done",
                   f"job did not finish clean: {line}")
            break
        expect(events[0] == "started" and events[-1] == "done",
               f"stream out of order: {events}")
        expect(events.count("step") == 5, f"expected 5 step events: {events}")
        c.close()
    except Exception as e:  # noqa: BLE001 - surfaced via the errors list
        errors.append(f"train session: {e!r}")


def infer_session(addr, precision, count, errors):
    """Fire `count` sequential infers on one connection; ids must echo."""
    try:
        c = Client(addr)
        for i in range(count):
            rid = f"{precision}-{i}"
            c.send({"cmd": "infer", "model": "vit_demo_wasi_eps80",
                    "precision": precision, "seed": 40 + i, "id": rid})
            resp = c.recv()
            expect(resp.get("ok") is True, f"infer failed: {resp}")
            expect(resp.get("id") == rid, f"response for wrong request: {resp}")
            expect(resp.get("precision") == precision, f"wrong precision: {resp}")
            expect(resp.get("preds"), f"no predictions: {resp}")
        c.close()
    except Exception as e:  # noqa: BLE001
        errors.append(f"infer session {precision}: {e!r}")


def abrupt_disconnect(addr, errors):
    """Subscribe to a job stream, then vanish without reading it."""
    try:
        c = Client(addr)
        c.send({"cmd": "submit", "model": "vit_demo_wasi_eps80", "steps": 4,
                "samples": 32, "engine": "native", "id": "churn"})
        resp = c.recv()
        expect(resp.get("ok") is True, f"churn submit rejected: {resp}")
        c.send({"cmd": "events", "job": resp["job"], "wait": True, "id": "churn-ev"})
        c.close()  # mid-stream: the server must shrug this off
    except Exception as e:  # noqa: BLE001
        errors.append(f"abrupt disconnect: {e!r}")


def main():
    if len(sys.argv) != 4:
        fail(f"usage: {sys.argv[0]} BIN ARTIFACTS_DIR NET_STATS_OUT")
    bin_path, artifacts, stats_out = sys.argv[1:]

    proc = subprocess.Popen(
        [bin_path, "serve", "--artifacts", artifacts, "--listen", "127.0.0.1:0",
         "--workers", "2"],
        stdout=subprocess.DEVNULL, stderr=subprocess.PIPE, text=True,
    )
    addr = None
    try:
        for line in proc.stderr:
            if "listening on " in line:
                host_port = line.split("listening on ", 1)[1].split()[0]
                host, port = host_port.rsplit(":", 1)
                addr = (host, int(port))
                break
        expect(addr is not None, "server exited before announcing its address")
        print(f"socket-smoke: server up at {addr[0]}:{addr[1]}")

        errors = []
        threads = [
            threading.Thread(target=train_session, args=(addr, errors)),
            threading.Thread(target=abrupt_disconnect, args=(addr, errors)),
        ] + [
            threading.Thread(target=infer_session, args=(addr, p, 6, errors))
            for p in ("f32", "bf16", "i8", "f32")
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180)
            expect(not t.is_alive(), "a client thread wedged (server unresponsive)")
        expect(not errors, "; ".join(errors))

        c = Client(addr)
        c.send({"cmd": "stats", "id": "final"})
        stats = c.recv()
        expect(stats.get("ok") is True and stats.get("id") == "final",
               f"stats failed: {stats}")
        net = stats["net"]
        with open(stats_out, "w") as f:
            json.dump(stats, f, indent=2, sort_keys=True)
        # 7 client connections total (6 worker threads + this one).
        expect(net["connections_opened"] >= 7, f"missing connections: {net}")
        expect(net["frames_in"] >= 30, f"missing inbound frames: {net}")
        expect(net["frames_out"] >= 30, f"missing outbound frames: {net}")
        expect(net["infer_solo"] + net["infer_batched"] >= 24,
               f"infer traffic unaccounted for: {net}")
        print("socket-smoke: stats clean:",
              net["connections_opened"], "connections,",
              net["frames_in"], "frames in,",
              int(net["infer_batched"]), "infers micro-batched")

        c.send({"cmd": "shutdown", "id": "bye"})
        bye = c.recv()
        expect(bye.get("ok") is True and bye.get("id") == "bye",
               f"shutdown rejected: {bye}")
        c.close()
        code = proc.wait(timeout=60)
        expect(code == 0, f"server exited {code}, want 0")
        print("socket-smoke: OK (clean drain, exit 0)")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()


if __name__ == "__main__":
    main()

#!/usr/bin/env python3
"""Perf-regression gate: compare a fresh `wasi-train bench` record
against the committed baseline (CI job `bench-gate`).

    python3 scripts/bench_gate.py BENCH_baseline.json BENCH_native.json \
        [--tolerance 0.25]

Rules
-----
* **Structural keys match exactly**: the two records must have the same
  recursive key sets and array lengths.  A missing section (simd,
  precision, serve, ...) or a renamed key fails the gate even when the
  baseline is provisional.
* **Wallclock within tolerance**: every timing value (keys ending in
  `_seconds`, `_ms`, `_s`, or `_ms_per_step`) must be within
  ``(1 + tolerance)`` of the baseline in BOTH directions (a big speedup
  is a stale baseline — commit the fresh record).  Values below the
  noise floor (``--min-seconds``, default 0.05s / 50ms) in BOTH records
  are checked for positivity only — shared-runner jitter on
  millisecond-scale quick-mode timings is not a regression signal.
  The per-node attribution under ``"nodes"`` is micro-timing noise and
  is compared structurally only.
* **Required non-empty sections**: the SIMD-vs-scalar and precision
  (int8-vs-f32) sections must exist with their arms populated.
* A baseline marked ``"provisional": true`` (seeded before a CI runner
  ever measured it) downgrades wallclock violations to warnings so the
  first run can mint the real numbers; CI uploads the fresh record as
  an artifact — commit it (dropping the flag) to arm the gate fully.

The committed baseline assumes a MULTI-CORE runner (GitHub's hosted
runners): the bench emits second thread/serve arms only when more than
one core is available, and a single-core host therefore fails the
structural length check by design — re-seed the baseline from that
host's own record if you need to gate there.
"""

import argparse
import json
import re
import sys

TIMING_KEY = re.compile(r"(_seconds|_ms|_s|_ms_per_step)$")

# Top-level baseline bookkeeping keys absent from fresh records.
BASELINE_ONLY_KEYS = {"provisional", "host"}


def walk(base, fresh, path, errors, timings):
    """Collect structural mismatches into `errors` and (path, base,
    fresh) timing pairs into `timings`."""
    if isinstance(base, dict) or isinstance(fresh, dict):
        if not (isinstance(base, dict) and isinstance(fresh, dict)):
            errors.append(f"{path}: type mismatch ({type(base).__name__} vs {type(fresh).__name__})")
            return
        bkeys = set(base) - (BASELINE_ONLY_KEYS if path == "$" else set())
        fkeys = set(fresh)
        for k in sorted(bkeys - fkeys):
            errors.append(f"{path}.{k}: missing from fresh record")
        for k in sorted(fkeys - bkeys):
            errors.append(f"{path}.{k}: not in baseline")
        for k in sorted(bkeys & fkeys):
            walk(base[k], fresh[k], f"{path}.{k}", errors, timings)
    elif isinstance(base, list) or isinstance(fresh, list):
        if not (isinstance(base, list) and isinstance(fresh, list)):
            errors.append(f"{path}: type mismatch ({type(base).__name__} vs {type(fresh).__name__})")
            return
        if len(base) != len(fresh):
            errors.append(f"{path}: length {len(base)} vs {len(fresh)}")
            return
        for i, (b, f) in enumerate(zip(base, fresh)):
            walk(b, f, f"{path}[{i}]", errors, timings)
    else:
        key = path.rsplit(".", 1)[-1]
        is_timing = bool(TIMING_KEY.search(key)) and ".nodes[" not in path
        if is_timing and isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
            timings.append((path, float(base), float(fresh)))


def require(cond, msg, errors):
    if not cond:
        errors.append(msg)


def check_sections(fresh, errors):
    """The acceptance-criteria sections must be present and non-empty."""
    simd = fresh.get("simd") or {}
    require(
        isinstance(simd.get("scalar"), dict) and isinstance(simd.get("simd"), dict),
        "simd section must record scalar AND simd arms",
        errors,
    )
    require("train_speedup" in simd, "simd section must record train_speedup", errors)
    prec = fresh.get("precision") or {}
    arms = prec.get("arms") or []
    got = {a.get("precision") for a in arms if isinstance(a, dict)}
    require(
        got == {"f32", "bf16", "i8"},
        f"precision section must cover f32/bf16/i8, got {sorted(got)}",
        errors,
    )
    require(
        "int8_vs_f32_speedup" in prec,
        "precision section must record int8_vs_f32_speedup",
        errors,
    )
    require(bool(fresh.get("serve")), "serve section must be non-empty", errors)
    for a in arms:
        require(
            isinstance(a, dict) and a.get("weight_bytes", 0) > 0,
            "precision arms must record weight_bytes",
            errors,
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative wallclock deviation (default 0.25)")
    ap.add_argument("--min-seconds", type=float, default=0.05,
                    help="noise floor: timings below this (in their own unit — "
                         "seconds for *_s keys, ms for *_ms keys) in both records "
                         "are checked for positivity only (default 0.05 / 50)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    provisional = bool(base.get("provisional"))
    errors, timings, violations = [], [], []
    walk(base, fresh, "$", errors, timings)
    check_sections(fresh, errors)

    lo, hi = 1.0 / (1.0 + args.tolerance), 1.0 + args.tolerance
    skipped = 0
    for path, b, f in timings:
        if b <= 0.0 or f <= 0.0:
            violations.append(f"{path}: wallclock not positive ({b} vs {f})")
            continue
        # _ms keys carry milliseconds; scale the floor to the key's unit.
        floor = args.min_seconds * (1000.0 if "_ms" in path.rsplit(".", 1)[-1] else 1.0)
        if b < floor and f < floor:
            skipped += 1
            continue
        ratio = f / b
        if not (lo <= ratio <= hi):
            violations.append(
                f"{path}: {f:.4f} vs baseline {b:.4f} ({ratio:.2f}x, "
                f"allowed [{lo:.2f}, {hi:.2f}])"
            )

    status = 0
    if errors:
        print(f"bench-gate: {len(errors)} structural violation(s):")
        for e in errors:
            print(f"  FAIL {e}")
        status = 1
    if violations:
        label = "WARN" if provisional else "FAIL"
        print(f"bench-gate: {len(violations)} wallclock deviation(s) "
              f"({'provisional baseline — warning only' if provisional else 'regression'}):")
        for v in violations:
            print(f"  {label} {v}")
        if not provisional:
            status = 1
    if provisional and not errors:
        print("bench-gate: baseline is PROVISIONAL — commit the uploaded "
              "BENCH_native.json as BENCH_baseline.json (drop \"provisional\") "
              "to arm wallclock enforcement.")
    if status == 0 and not violations:
        print(f"bench-gate: OK ({len(timings) - skipped} wallclock values within "
              f"±{args.tolerance * 100:.0f}%, {skipped} below the noise floor, "
              "structure exact)")
    sys.exit(status)


if __name__ == "__main__":
    main()

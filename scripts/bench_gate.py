#!/usr/bin/env python3
"""Perf-regression gate: compare a fresh `wasi-train bench` record
against the committed baseline (CI job `bench-gate`).

    python3 scripts/bench_gate.py BENCH_baseline.json BENCH_native.json \
        [--tolerance 0.25]

Rules
-----
* **Structural keys match exactly**: the two records must have the same
  recursive key sets and array lengths.  A missing section (simd,
  precision, serve, ...) or a renamed key fails the gate even when the
  baseline is provisional.
* **Wallclock within tolerance**: every timing value (keys ending in
  `_seconds`, `_ms`, `_s`, or `_ms_per_step`) must be within
  ``(1 + tolerance)`` of the baseline in BOTH directions (a big speedup
  is a stale baseline — commit the fresh record).  Values below the
  noise floor (``--min-seconds``, default 0.05s / 50ms) in BOTH records
  are checked for positivity only — shared-runner jitter on
  millisecond-scale quick-mode timings is not a regression signal.
  The per-node attribution under ``"nodes"`` is micro-timing noise and
  is compared structurally only.
* **Required non-empty sections**: the SIMD-vs-scalar and precision
  (int8-vs-f32) sections must exist with their arms populated —
  including ``precision.int8_isa`` (the integer-dot backend the run
  dispatched to) and the ``precision.batched`` solo-vs-coalesced sweep;
  ``precision.int8_vs_f32_speedup`` and both ``precision.batched``
  per-request speedups must be >= 1.0 (the true-integer kernels must
  beat f32, and a coalesced batch of 8 must not lose to solo dispatch),
  riding the provisional downgrade like wallclock.  The
  ``soak`` section (the bench's embedded scenario-harness run) must
  report ``invariant_violations == 0``, and the ``store`` section (the
  variant-store paging sweep) must report ``reload_bit_identical: true``
  with nonzero ``evictions`` and ``compression_ratio >= 10`` — a
  serving-invariant violation or a lossy/underpaged store run fails the
  gate even when every wallclock is in range.  The ``passes`` section
  (optimization-pass pipeline) must report ``arena_reuse_ratio >= 1``
  and an optimized executor that allocates no more per step/infer than
  the unoptimized one — hard failures; its allocation counts are
  additionally budgeted at 10% + 4 against the baseline and
  ``prepack_infer_speedup`` must exceed 1.0, both riding the
  provisional downgrade like wallclock.  The ``net`` section (socket
  front-end, DESIGN.md §Network front-end) must cover both serving
  modes (solo, batched) at every in-flight level (10/100/1000), and
  ``batched_vs_solo_throughput_at_100`` — micro-batching's headline —
  must be >= 1.0, riding the provisional downgrade.  Every missing
  requirement is reported by its exact key path
  (``$.soak.invariant_violations: required key missing``), never as a
  raw KeyError traceback.
* A baseline marked ``"provisional": true`` (seeded before a CI runner
  ever measured it) downgrades wallclock violations to warnings so the
  first run can mint the real numbers; CI uploads the fresh record as
  an artifact — commit it (dropping the flag) to arm the gate fully.

The committed baseline assumes a MULTI-CORE runner (GitHub's hosted
runners): the bench emits second thread/serve arms only when more than
one core is available, and a single-core host therefore fails the
structural length check by design — re-seed the baseline from that
host's own record if you need to gate there.
"""

import argparse
import json
import re
import sys

TIMING_KEY = re.compile(r"(_seconds|_ms|_s|_ms_per_step)$")

# Top-level baseline bookkeeping keys absent from fresh records.
BASELINE_ONLY_KEYS = {"provisional", "host"}


def walk(base, fresh, path, errors, timings):
    """Collect structural mismatches into `errors` and (path, base,
    fresh) timing pairs into `timings`."""
    if isinstance(base, dict) or isinstance(fresh, dict):
        if not (isinstance(base, dict) and isinstance(fresh, dict)):
            errors.append(f"{path}: type mismatch ({type(base).__name__} vs {type(fresh).__name__})")
            return
        bkeys = set(base) - (BASELINE_ONLY_KEYS if path == "$" else set())
        fkeys = set(fresh)
        for k in sorted(bkeys - fkeys):
            errors.append(f"{path}.{k}: missing from fresh record")
        for k in sorted(fkeys - bkeys):
            errors.append(f"{path}.{k}: not in baseline")
        for k in sorted(bkeys & fkeys):
            walk(base[k], fresh[k], f"{path}.{k}", errors, timings)
    elif isinstance(base, list) or isinstance(fresh, list):
        if not (isinstance(base, list) and isinstance(fresh, list)):
            errors.append(f"{path}: type mismatch ({type(base).__name__} vs {type(fresh).__name__})")
            return
        if len(base) != len(fresh):
            errors.append(f"{path}: length {len(base)} vs {len(fresh)}")
            return
        for i, (b, f) in enumerate(zip(base, fresh)):
            walk(b, f, f"{path}[{i}]", errors, timings)
    else:
        key = path.rsplit(".", 1)[-1]
        is_timing = bool(TIMING_KEY.search(key)) and ".nodes[" not in path
        if is_timing and isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
            timings.append((path, float(base), float(fresh)))


def require(cond, msg, errors):
    if not cond:
        errors.append(msg)


class MissingKey:
    """Sentinel for a failed `lookup` — falsy, prints its path."""

    def __init__(self, path):
        self.path = path

    def __bool__(self):
        return False

    def __repr__(self):
        return f"<missing {self.path}>"


def lookup(record, path, errors=None):
    """Walk a dotted/indexed path (``precision.arms[0].weight_bytes``)
    through a parsed record.  On a dead end, append one actionable
    error naming the exact key path that is missing (never a raw
    KeyError/IndexError traceback) and return a falsy ``MissingKey``."""
    node, walked = record, "$"
    for part in re.findall(r"[^.\[\]]+|\[\d+\]", path):
        if part.startswith("["):
            idx = int(part[1:-1])
            if not isinstance(node, list) or idx >= len(node):
                kind = "not an array" if not isinstance(node, list) else f"has only {len(node)} item(s)"
                if errors is not None:
                    errors.append(f"{walked}{part}: required but {walked} is {kind}")
                return MissingKey(f"{walked}{part}")
            node, walked = node[idx], f"{walked}{part}"
        else:
            if not isinstance(node, dict) or part not in node:
                kind = "missing" if isinstance(node, dict) else f"unreachable ({walked} is {type(node).__name__}, not an object)"
                if errors is not None:
                    errors.append(f"{walked}.{part}: required key {kind}")
                return MissingKey(f"{walked}.{part}")
            node, walked = node[part], f"{walked}.{part}"
    return node


def check_sections(fresh, errors):
    """The acceptance-criteria sections must be present and non-empty —
    every failure names the exact key path it expected."""
    require(
        isinstance(lookup(fresh, "simd.scalar", errors), dict)
        and isinstance(lookup(fresh, "simd.simd", errors), dict),
        "simd section must record scalar AND simd arms",
        errors,
    )
    lookup(fresh, "simd.train_speedup", errors)
    arms = lookup(fresh, "precision.arms", errors)
    if not isinstance(arms, list):
        arms = []
    got = {a.get("precision") for a in arms if isinstance(a, dict)}
    require(
        got == {"f32", "bf16", "i8"},
        f"$.precision.arms must cover f32/bf16/i8, got {sorted(x for x in got if x)}",
        errors,
    )
    lookup(fresh, "precision.int8_vs_f32_speedup", errors)
    # The true-integer int8 path must record which integer-dot backend
    # it dispatched to and the solo-vs-coalesced batch sweep.
    isa = lookup(fresh, "precision.int8_isa", errors)
    if not isinstance(isa, MissingKey):
        require(isa in ("scalar", "avx2", "neon"),
                f"$.precision.int8_isa must name a known backend, got {isa!r}",
                errors)
    for key in ("precision.batched.batch",
                "precision.batched.f32_batch_per_req_speedup",
                "precision.batched.i8_batch_per_req_speedup"):
        lookup(fresh, key, errors)
    require(bool(fresh.get("serve")), "$.serve section must be non-empty", errors)
    for i, a in enumerate(arms):
        require(
            isinstance(a, dict) and a.get("weight_bytes", 0) > 0,
            f"$.precision.arms[{i}].weight_bytes must be present and positive",
            errors,
        )
    # The soak section (scenario harness, DESIGN.md §Scenario harness)
    # must exist and report a CLEAN run — invariant violations in the
    # bench's embedded soak fail the gate regardless of wallclock.
    violations = lookup(fresh, "soak.invariant_violations", errors)
    if not isinstance(violations, MissingKey):
        require(
            violations == 0,
            f"$.soak.invariant_violations must be 0, got {violations}",
            errors,
        )
    for key in ("soak.events", "soak.queue_depth_max", "soak.soak_seconds",
                "soak.p50_submit_to_done_ms"):
        lookup(fresh, key, errors)
    # The store section (variant-store paging, DESIGN.md §Variant store)
    # must show REAL paging under budget pressure — predictions stay bit
    # identical across evict→reload, eviction actually happened, and the
    # delta records carry the paper's headline compression (>= 10x
    # smaller than full personalized params).
    ident = lookup(fresh, "store.reload_bit_identical", errors)
    if not isinstance(ident, MissingKey):
        require(ident is True,
                f"$.store.reload_bit_identical must be true, got {ident}", errors)
    evictions = lookup(fresh, "store.evictions", errors)
    if not isinstance(evictions, MissingKey):
        require(isinstance(evictions, (int, float)) and evictions > 0,
                f"$.store.evictions must be nonzero, got {evictions}", errors)
    ratio = lookup(fresh, "store.compression_ratio", errors)
    if not isinstance(ratio, MissingKey):
        require(isinstance(ratio, (int, float)) and ratio >= 10,
                f"$.store.compression_ratio must be >= 10, got {ratio}", errors)
    for key in ("store.hit_rate", "store.delta_bytes", "store.full_bytes"):
        lookup(fresh, key, errors)
    # The passes section (optimization-pass pipeline, DESIGN.md §Pass
    # pipeline) must show the liveness plan actually sharing storage and
    # the planned executor allocating no more per step than the
    # unoptimized one.  These are machine-independent facts about the
    # code, so they fail hard even on a provisional baseline.
    reuse = lookup(fresh, "passes.arena_reuse_ratio", errors)
    if not isinstance(reuse, MissingKey):
        require(isinstance(reuse, (int, float)) and reuse >= 1.0,
                f"$.passes.arena_reuse_ratio must be >= 1, got {reuse}", errors)
    for opt_key, ref_key in (
        ("passes.allocations_per_step_optimized",
         "passes.allocations_per_step_unoptimized"),
        ("passes.allocations_per_infer_optimized",
         "passes.allocations_per_infer_unoptimized"),
    ):
        opt = lookup(fresh, opt_key, errors)
        ref = lookup(fresh, ref_key, errors)
        if not isinstance(opt, MissingKey) and not isinstance(ref, MissingKey):
            require(
                isinstance(opt, (int, float)) and isinstance(ref, (int, float))
                and opt <= ref,
                f"$.{opt_key}: optimized executor allocates more than the "
                f"unoptimized one ({opt} vs {ref})",
                errors,
            )
    for key in ("passes.arena_bytes", "passes.sum_buffer_bytes",
                "passes.prepack_panel_bytes", "passes.prepack_cache_hit_rate",
                "passes.prepack_infer_speedup"):
        lookup(fresh, key, errors)
    # The net section (socket front-end, DESIGN.md §Network front-end)
    # must sweep both serving modes across every in-flight level — a
    # missing arm means the high-concurrency bench silently degraded.
    net_arms = lookup(fresh, "net.arms", errors)
    if not isinstance(net_arms, list):
        net_arms = []
    pairs = {(a.get("mode"), a.get("inflight"))
             for a in net_arms if isinstance(a, dict)}
    want = {(m, n) for m in ("solo", "batched") for n in (10, 100, 1000)}
    require(
        want <= pairs,
        "$.net.arms must cover modes solo/batched at in-flight 10/100/1000, "
        f"missing {sorted(want - pairs)}",
        errors,
    )
    for key in ("net.batched.mean_batch", "net.batched.batches",
                "net.batched_vs_solo_throughput_at_100"):
        lookup(fresh, key, errors)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative wallclock deviation (default 0.25)")
    ap.add_argument("--min-seconds", type=float, default=0.05,
                    help="noise floor: timings below this (in their own unit — "
                         "seconds for *_s keys, ms for *_ms keys) in both records "
                         "are checked for positivity only (default 0.05 / 50)")
    args = ap.parse_args()

    def load(label, path):
        try:
            with open(path) as f:
                return json.load(f)
        except OSError as e:
            sys.exit(f"bench-gate: cannot read {label} record {path!r}: {e}")
        except json.JSONDecodeError as e:
            sys.exit(f"bench-gate: {label} record {path!r} is not valid JSON: {e}")

    base = load("baseline", args.baseline)
    fresh = load("fresh", args.fresh)
    if not isinstance(base, dict) or not isinstance(fresh, dict):
        sys.exit("bench-gate: both records must be JSON objects at top level")

    provisional = bool(base.get("provisional"))
    errors, timings, violations = [], [], []
    walk(base, fresh, "$", errors, timings)
    check_sections(fresh, errors)

    lo, hi = 1.0 / (1.0 + args.tolerance), 1.0 + args.tolerance
    skipped = 0
    for path, b, f in timings:
        if b <= 0.0 or f <= 0.0:
            violations.append(f"{path}: wallclock not positive ({b} vs {f})")
            continue
        # _ms keys carry milliseconds; scale the floor to the key's unit.
        floor = args.min_seconds * (1000.0 if "_ms" in path.rsplit(".", 1)[-1] else 1.0)
        if b < floor and f < floor:
            skipped += 1
            continue
        ratio = f / b
        if not (lo <= ratio <= hi):
            violations.append(
                f"{path}: {f:.4f} vs baseline {b:.4f} ({ratio:.2f}x, "
                f"allowed [{lo:.2f}, {hi:.2f}])"
            )

    # Allocation counts are not wallclock, but they are runner-neutral
    # code-version facts: the fresh record must stay within 10% (plus a
    # small absolute grace for allocator noise) of the baseline.  Routed
    # through the provisional downgrade like the timings so a seeded
    # baseline warns instead of failing.  The prepack speedup is
    # timing-derived and rides the same path: panels must beat
    # dequantize-on-the-fly.
    for key in ("passes.allocations_per_step_optimized",
                "passes.allocations_per_infer_optimized"):
        b, f = lookup(base, key), lookup(fresh, key)
        if isinstance(b, (int, float)) and isinstance(f, (int, float)) \
                and f > b * 1.10 + 4:
            violations.append(
                f"$.{key}: {f:.0f} allocations vs baseline {b:.0f} "
                f"(budget 1.10x + 4)")
    spd = lookup(fresh, "passes.prepack_infer_speedup")
    if isinstance(spd, (int, float)) and spd <= 1.0:
        violations.append(
            f"$.passes.prepack_infer_speedup: {spd:.3f} — prepacked panels "
            "must beat dequantize-on-the-fly")
    # True-integer int8's headline: the integer kernels must make int8
    # FASTER than f32 inference, not just smaller.  Timing-derived, so
    # it rides the provisional downgrade.
    i8_spd = lookup(fresh, "precision.int8_vs_f32_speedup")
    if isinstance(i8_spd, (int, float)) and i8_spd < 1.0:
        violations.append(
            f"$.precision.int8_vs_f32_speedup: {i8_spd:.3f} — true-integer "
            "int8 kernels must beat f32 inference")
    # Batched-GEMM amortization: a coalesced batch of 8 must not be
    # slower PER REQUEST than solo single-sample calls, in either
    # precision — the microtiles exist to amortize the panel walk.
    for key in ("precision.batched.f32_batch_per_req_speedup",
                "precision.batched.i8_batch_per_req_speedup"):
        b8 = lookup(fresh, key)
        if isinstance(b8, (int, float)) and b8 < 1.0:
            violations.append(
                f"$.{key}: {b8:.3f} — a coalesced batch of 8 must not "
                "lose to solo per-request dispatch")
    # Micro-batching's headline: at 100 concurrent in-flight requests
    # the batched front-end must not serve SLOWER than solo dispatch.
    # Timing-derived, so it rides the provisional downgrade too.
    net_ratio = lookup(fresh, "net.batched_vs_solo_throughput_at_100")
    if isinstance(net_ratio, (int, float)) and net_ratio < 1.0:
        violations.append(
            f"$.net.batched_vs_solo_throughput_at_100: {net_ratio:.3f} — "
            "cross-request micro-batching must not lose to solo dispatch")

    status = 0
    if errors:
        print(f"bench-gate: {len(errors)} structural violation(s):")
        for e in errors:
            print(f"  FAIL {e}")
        status = 1
    if violations:
        label = "WARN" if provisional else "FAIL"
        print(f"bench-gate: {len(violations)} wallclock deviation(s) "
              f"({'provisional baseline — warning only' if provisional else 'regression'}):")
        for v in violations:
            print(f"  {label} {v}")
        if not provisional:
            status = 1
    if provisional and not errors:
        print("bench-gate: baseline is PROVISIONAL — commit the uploaded "
              "BENCH_native.json as BENCH_baseline.json (drop \"provisional\") "
              "to arm wallclock enforcement.")
    if status == 0 and not violations:
        print(f"bench-gate: OK ({len(timings) - skipped} wallclock values within "
              f"±{args.tolerance * 100:.0f}%, {skipped} below the noise floor, "
              "structure exact)")
    sys.exit(status)


if __name__ == "__main__":
    main()

"""Tests for scripts/bench_gate.py (CI job `bench-gate`, satellite of
the scenario-harness PR): the gate must pass a faithful record, fail a
wallclock regression, and fail a missing section with an error naming
the exact key path — never a raw KeyError traceback.

Run: python3 -m pytest scripts/test_bench_gate.py -q
"""

import copy
import json
import subprocess
import sys
from pathlib import Path

GATE = Path(__file__).resolve().parent / "bench_gate.py"


def baseline_record():
    """A minimal but structurally complete bench record."""
    return {
        "bench": "wasi-train bench",
        "quick": True,
        "model": "vit_demo_wasi_eps80",
        "steps": 10,
        "host_auto_threads": 4,
        "demo_seconds": 0.06,
        "engines": [
            {
                "engine": "native",
                "available": True,
                "arms": [
                    {"threads": 1, "train_seconds": 0.09, "mean_step_ms": 9.0,
                     "infer_seconds": 0.01, "infer_reps": 5},
                    {"threads": 4, "train_seconds": 0.07, "mean_step_ms": 7.0,
                     "infer_seconds": 0.008, "infer_reps": 5},
                ],
                "thread_speedup": 1.3,
            },
            {"engine": "hlo", "available": False, "reason": "offline"},
        ],
        "simd": {
            "isa": "avx",
            "scalar": {"threads": 4, "train_seconds": 0.10, "mean_step_ms": 10.0,
                       "infer_seconds": 0.012, "infer_reps": 5},
            "simd": {"threads": 4, "train_seconds": 0.07, "mean_step_ms": 7.0,
                     "infer_seconds": 0.008, "infer_reps": 5},
            "train_speedup": 1.4,
            "infer_speedup": 1.5,
        },
        "precision": {
            "arms": [
                {"precision": "f32", "infer_seconds": 0.010, "infer_reps": 5,
                 "weight_bytes": 150000, "top1_agreement": 1.0},
                {"precision": "bf16", "infer_seconds": 0.011, "infer_reps": 5,
                 "weight_bytes": 80000, "top1_agreement": 1.0},
                {"precision": "i8", "infer_seconds": 0.008, "infer_reps": 5,
                 "weight_bytes": 45000, "top1_agreement": 1.0},
            ],
            "int8_isa": "avx2",
            "int8_vs_f32_speedup": 1.25,
            "int8_weight_compression": 3.4,
            "batched": {
                "batch": 8,
                "f32_solo_per_req_seconds": 0.0020,
                "f32_batch_per_req_seconds": 0.0015,
                "f32_batch_per_req_speedup": 1.33,
                "i8_solo_per_req_seconds": 0.0016,
                "i8_batch_per_req_seconds": 0.0011,
                "i8_batch_per_req_speedup": 1.45,
            },
        },
        "serve": [
            {"workers": 1, "jobs": 2, "steps_per_job": 3, "total_seconds": 0.2,
             "jobs_per_sec": 10.0, "p50_submit_to_done_s": 0.1,
             "p95_submit_to_done_s": 0.18},
        ],
        "soak": {
            "events": 40,
            "jobs": 10,
            "invariant_violations": 0,
            "queue_depth_max": 3,
            "soak_seconds": 1.5,
            "p50_submit_to_done_ms": 120.0,
            "p95_submit_to_done_ms": 250.0,
            "infer_p50_ms": 10.0,
        },
        "store": {
            "model": "vit_demo_wasi_eps80",
            "users": 40,
            "budget_residents": 4,
            "budget_bytes": 172032,
            "requests": 400,
            "hit_rate": 0.6,
            "hits": 240,
            "misses": 160,
            "reloads": 160,
            "evictions": 196,
            "delta_bytes": 43008,
            "full_bytes": 620000,
            "compression_ratio": 14.4,
            "users_per_gb_delta": 24966,
            "users_per_gb_full": 1732,
            "reload_p50_ms": 0.3,
            "reload_p95_ms": 0.8,
            "reload_bit_identical": True,
        },
        "passes": {
            "enabled": "fold,fuse,arena,prepack",
            "model": "vit_demo_vanilla",
            "arena_bytes": 400000,
            "sum_buffer_bytes": 1200000,
            "arena_reuse_ratio": 3.0,
            "intervals": 60,
            "allocations_per_step_optimized": 8,
            "allocations_per_step_unoptimized": 80,
            "allocations_per_infer_optimized": 3,
            "allocations_per_infer_unoptimized": 20,
            "train_step_optimized_ms": 8.0,
            "train_step_unoptimized_ms": 9.0,
            "infer_optimized_ms": 1.5,
            "infer_unoptimized_ms": 1.8,
            "infer_prepacked_ms": 1.8,
            "infer_repack_ms": 2.2,
            "prepack_infer_speedup": 1.2,
            "prepack_panel_count": 14,
            "prepack_panel_bytes": 120000,
            "prepack_cache_hit_rate": 0.875,
        },
        "net": {
            "model": "vit_demo_wasi_eps80",
            "workers": 1,
            "dispatchers": 64,
            "arms": [
                {"inflight": n, "mode": m, "requests": 60, "connections": 10,
                 "total_seconds": 0.3, "throughput_rps": 200.0,
                 "p50_ms": 40.0, "p99_ms": 90.0}
                for m in ("solo", "batched") for n in (10, 100, 1000)
            ],
            "batched": {"window_us": 400.0, "max_batch": 32.0, "batches": 60,
                        "batched_requests": 900, "mean_batch": 15.0},
            "batched_vs_solo_throughput_at_100": 2.0,
        },
        "nodes": [
            {"node": "dense:embed", "fwd_ms_per_step": 0.2, "bwd_ms_per_step": 0.3},
        ],
    }


def run_gate(tmp_path, base, fresh, *extra):
    bpath = tmp_path / "baseline.json"
    fpath = tmp_path / "fresh.json"
    bpath.write_text(json.dumps(base))
    fpath.write_text(json.dumps(fresh))
    return subprocess.run(
        [sys.executable, str(GATE), str(bpath), str(fpath), *extra],
        capture_output=True, text=True,
    )


def test_identical_records_pass(tmp_path):
    base = baseline_record()
    res = run_gate(tmp_path, base, copy.deepcopy(base))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "bench-gate: OK" in res.stdout


def test_wallclock_regression_fails_with_ratio(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    # 3x slower training on the single-thread arm: a real regression,
    # well above the noise floor (0.09s baseline < 0.05s? no: raise it).
    base["engines"][0]["arms"][0]["train_seconds"] = 1.0
    fresh["engines"][0]["arms"][0]["train_seconds"] = 3.0
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "wallclock deviation" in res.stdout
    assert "$.engines[0].arms[0].train_seconds" in res.stdout
    assert "3.00x" in res.stdout


def test_provisional_baseline_downgrades_wallclock_to_warning(tmp_path):
    base = baseline_record()
    base["provisional"] = True
    fresh = copy.deepcopy(baseline_record())
    base["engines"][0]["arms"][0]["train_seconds"] = 1.0
    fresh["engines"][0]["arms"][0]["train_seconds"] = 3.0
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "WARN" in res.stdout
    assert "PROVISIONAL" in res.stdout


def test_missing_soak_section_names_key_path(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    del fresh["soak"]
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    # Both the structural walk and the section check name the path.
    assert "$.soak" in res.stdout
    assert "KeyError" not in res.stdout + res.stderr
    assert "Traceback" not in res.stderr


def test_missing_nested_key_names_full_path(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    del fresh["soak"]["invariant_violations"]
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.soak.invariant_violations" in res.stdout
    assert "KeyError" not in res.stdout + res.stderr


def test_soak_violations_fail_even_when_wallclock_clean(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["soak"]["invariant_violations"] = 2
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.soak.invariant_violations must be 0, got 2" in res.stdout


def test_missing_store_section_names_key_path(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    del fresh["store"]
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.store" in res.stdout
    assert "KeyError" not in res.stdout + res.stderr
    assert "Traceback" not in res.stderr


def test_store_reload_bit_identity_is_required(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["store"]["reload_bit_identical"] = False
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.store.reload_bit_identical must be true" in res.stdout


def test_store_without_evictions_fails(tmp_path):
    # A store sweep that never paged measured nothing: the budget must
    # actually be under pressure for the section to count.
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["store"]["evictions"] = 0
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.store.evictions must be nonzero" in res.stdout


def test_store_compression_floor_is_enforced(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["store"]["compression_ratio"] = 7.0
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.store.compression_ratio must be >= 10, got 7.0" in res.stdout


def test_missing_passes_section_names_key_path(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    del fresh["passes"]
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.passes" in res.stdout
    assert "KeyError" not in res.stdout + res.stderr
    assert "Traceback" not in res.stderr


def test_optimized_executor_may_not_allocate_more(tmp_path):
    # Self-relative invariant inside the fresh record: the arena-planned
    # executor allocating MORE than the unoptimized one is a hard fail,
    # provisional baseline or not.
    base = baseline_record()
    base["provisional"] = True
    fresh = copy.deepcopy(baseline_record())
    fresh["passes"]["allocations_per_step_optimized"] = 200
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "optimized executor allocates more" in res.stdout


def test_arena_reuse_ratio_floor(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["passes"]["arena_reuse_ratio"] = 0.8
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.passes.arena_reuse_ratio must be >= 1" in res.stdout


def test_allocation_regression_vs_baseline_fails(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    # 8 -> 40 allocations/step: way past the 10% + 4 budget.
    fresh["passes"]["allocations_per_step_optimized"] = 40
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.passes.allocations_per_step_optimized" in res.stdout
    assert "budget 1.10x + 4" in res.stdout


def test_allocation_regression_warns_on_provisional_baseline(tmp_path):
    base = baseline_record()
    base["provisional"] = True
    fresh = copy.deepcopy(baseline_record())
    fresh["passes"]["allocations_per_step_optimized"] = 40
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "WARN" in res.stdout


def test_prepack_speedup_must_exceed_one(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["passes"]["prepack_infer_speedup"] = 0.9
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.passes.prepack_infer_speedup" in res.stdout
    assert "must beat dequantize-on-the-fly" in res.stdout


def test_missing_net_section_names_key_path(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    del fresh["net"]
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.net" in res.stdout
    assert "KeyError" not in res.stdout + res.stderr
    assert "Traceback" not in res.stderr


def test_net_arms_must_cover_both_modes_at_every_level(tmp_path):
    # Dropping the batched@1000 arm must be named, not silently passed.
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["net"]["arms"] = [
        a for a in fresh["net"]["arms"]
        if not (a["mode"] == "batched" and a["inflight"] == 1000)
    ]
    base["net"]["arms"] = copy.deepcopy(fresh["net"]["arms"])
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.net.arms must cover modes solo/batched" in res.stdout
    assert "('batched', 1000)" in res.stdout


def test_batched_throughput_must_not_lose_to_solo(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["net"]["batched_vs_solo_throughput_at_100"] = 0.7
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.net.batched_vs_solo_throughput_at_100" in res.stdout
    assert "must not lose to solo dispatch" in res.stdout


def test_batched_throughput_ratio_warns_on_provisional_baseline(tmp_path):
    base = baseline_record()
    base["provisional"] = True
    fresh = copy.deepcopy(baseline_record())
    fresh["net"]["batched_vs_solo_throughput_at_100"] = 0.7
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "WARN" in res.stdout
    assert "$.net.batched_vs_solo_throughput_at_100" in res.stdout


def test_int8_speedup_below_one_fails(tmp_path):
    # True-integer int8's headline: the kernels must make int8 FASTER
    # than f32, not just smaller.
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["precision"]["int8_vs_f32_speedup"] = 0.9
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.precision.int8_vs_f32_speedup" in res.stdout
    assert "must beat f32 inference" in res.stdout


def test_int8_speedup_warns_on_provisional_baseline(tmp_path):
    base = baseline_record()
    base["provisional"] = True
    fresh = copy.deepcopy(baseline_record())
    fresh["precision"]["int8_vs_f32_speedup"] = 0.9
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "WARN" in res.stdout
    assert "$.precision.int8_vs_f32_speedup" in res.stdout


def test_missing_int8_isa_names_key_path(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    del fresh["precision"]["int8_isa"]
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.precision.int8_isa" in res.stdout
    assert "KeyError" not in res.stdout + res.stderr


def test_unknown_int8_isa_is_rejected(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["precision"]["int8_isa"] = "sse2"
    base["precision"]["int8_isa"] = "sse2"  # keep structure identical
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "must name a known backend" in res.stdout


def test_batch8_per_req_speedup_below_one_fails(tmp_path):
    # A coalesced batch of 8 serving SLOWER per request than solo calls
    # means the microtiles amortized nothing.
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["precision"]["batched"]["i8_batch_per_req_speedup"] = 0.8
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.precision.batched.i8_batch_per_req_speedup" in res.stdout
    assert "lose to solo per-request dispatch" in res.stdout


def test_missing_batched_sweep_names_key_path(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    del fresh["precision"]["batched"]
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.precision.batched" in res.stdout
    assert "KeyError" not in res.stdout + res.stderr


def test_wrong_section_type_is_actionable_not_traceback(tmp_path):
    base = baseline_record()
    fresh = copy.deepcopy(base)
    fresh["precision"] = "oops"          # object replaced by a scalar
    res = run_gate(tmp_path, base, fresh)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "$.precision" in res.stdout
    assert "Traceback" not in res.stderr


def test_unreadable_record_is_actionable(tmp_path):
    base = baseline_record()
    bpath = tmp_path / "baseline.json"
    bpath.write_text(json.dumps(base))
    res = subprocess.run(
        [sys.executable, str(GATE), str(bpath), str(tmp_path / "nope.json")],
        capture_output=True, text=True,
    )
    assert res.returncode != 0
    assert "cannot read fresh record" in res.stderr
    assert "Traceback" not in res.stderr


def test_invalid_json_is_actionable(tmp_path):
    base = baseline_record()
    bpath = tmp_path / "baseline.json"
    fpath = tmp_path / "fresh.json"
    bpath.write_text(json.dumps(base))
    fpath.write_text("{not json")
    res = subprocess.run(
        [sys.executable, str(GATE), str(bpath), str(fpath)],
        capture_output=True, text=True,
    )
    assert res.returncode != 0
    assert "not valid JSON" in res.stderr
    assert "Traceback" not in res.stderr

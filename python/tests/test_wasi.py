"""WASI layer semantics: custom_vjp gradients, WSI refresh invariants,
rank selection, and the baseline factorizations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import ops, wasi
from compile.kernels import ref


def ortho(rng, n, r):
    return jnp.asarray(np.linalg.qr(rng.standard_normal((n, r)))[0], jnp.float32)


@pytest.fixture
def small():
    rng = np.random.default_rng(0)
    B, N, I, O, K = 4, 11, 24, 18, 6
    x = jnp.asarray(rng.standard_normal((B, N, I)), jnp.float32)
    l = jnp.asarray(0.3 * rng.standard_normal((O, K)), jnp.float32)
    r = jnp.asarray(0.3 * rng.standard_normal((K, I)), jnp.float32)
    us = (ortho(rng, B, 3), ortho(rng, N, 5), ortho(rng, I, 8))
    return x, l, r, us


class TestWasiLinearVjp:
    def test_grads_match_compressed_reference(self, small):
        x, l, r, us = small

        def loss(x, l, r):
            y, *_ = wasi.wasi_linear(x, l, r, *us)
            return 0.5 * jnp.sum(y * y)

        gx, gl, gr = jax.grad(loss, argnums=(0, 1, 2))(x, l, r)
        # reference: dy = y; dx exact; dl/dr against the Tucker-compressed x
        core, new_us = wasi.asi_compress(x, us)
        xt = ops.tucker_reconstruct(core, new_us)
        y = ref.lowrank_linear(x, l, r)
        np.testing.assert_allclose(gx, (y @ l) @ r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            gl, jnp.einsum("bno,bnk->ok", y, xt @ r.T), rtol=2e-3, atol=1e-4)
        np.testing.assert_allclose(
            gr, jnp.einsum("bnk,bni->ki", y @ l, xt), rtol=2e-3, atol=1e-4)

    def test_state_outputs_are_orthonormal(self, small):
        x, l, r, us = small
        _, u1n, u2n, u3n = wasi.wasi_linear(x, l, r, *us)
        for u in (u1n, u2n, u3n):
            g = np.asarray(u.T @ u)
            np.testing.assert_allclose(g, np.eye(u.shape[1]), atol=5e-4)

    def test_forward_value_is_exact(self, small):
        # Forward uses the UNcompressed x (compression affects backward only).
        x, l, r, us = small
        y, *_ = wasi.wasi_linear(x, l, r, *us)
        np.testing.assert_allclose(y, ref.lowrank_linear(x, l, r), rtol=1e-5)

    def test_4d_variant_grads_finite(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((2, 4, 4, 12)), jnp.float32)
        l = jnp.asarray(0.3 * rng.standard_normal((8, 3)), jnp.float32)
        r = jnp.asarray(0.3 * rng.standard_normal((3, 12)), jnp.float32)
        us = (ortho(rng, 2, 2), ortho(rng, 4, 3), ortho(rng, 4, 3), ortho(rng, 12, 4))

        def loss(l, r):
            y, *_ = wasi.wasi_linear_4d(x, l, r, *us)
            return jnp.sum(y ** 2)

        gl, gr = jax.grad(loss, argnums=(0, 1))(l, r)
        assert np.isfinite(np.asarray(gl)).all()
        assert np.isfinite(np.asarray(gr)).all()
        assert float(jnp.abs(gl).max()) > 0


class TestWsiRefresh:
    def test_preserves_product_and_orthonormalizes(self):
        rng = np.random.default_rng(2)
        l = jnp.asarray(rng.standard_normal((20, 5)), jnp.float32)
        r = jnp.asarray(rng.standard_normal((5, 16)), jnp.float32)
        lp, rp = wasi.wsi_refresh(l, r)
        np.testing.assert_allclose(lp @ rp, l @ r, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(lp.T @ lp), np.eye(5), atol=1e-3)

    def test_materialized_matches_factored_subspace(self):
        rng = np.random.default_rng(3)
        w = np.linalg.qr(rng.standard_normal((20, 8)))[0] @ \
            np.diag([8, 6, 4, 2, 1, 0.5, 0.2, 0.1]) @ \
            np.linalg.qr(rng.standard_normal((15, 8)))[0].T
        w = jnp.asarray(w, jnp.float32)
        l0 = ortho(np.random.default_rng(4), 20, 4)
        l1, r1 = wasi.wsi_refresh_materialized(w, l0)
        # iterate: converges toward the top-4 subspace of w
        for _ in range(6):
            l1, r1 = wasi.wsi_refresh_materialized(w, l1)
        u_true = np.linalg.svd(np.asarray(w))[0][:, :4]
        s = np.linalg.svd(np.asarray(l1).T @ u_true, compute_uv=False)
        assert s.min() > 0.98


class TestRankSelection:
    def test_select_rank_monotone_in_eps(self):
        s = np.array([5.0, 3.0, 2.0, 1.0, 0.5, 0.1])
        prev = 0
        for eps in [0.2, 0.5, 0.8, 0.95, 0.9999]:
            k = wasi.select_rank(s, eps)
            assert k >= prev
            prev = k
        assert wasi.select_rank(s, 0.9999) <= len(s)

    def test_svd_factorize_energy(self):
        rng = np.random.default_rng(5)
        u = np.linalg.qr(rng.standard_normal((30, 10)))[0]
        v = np.linalg.qr(rng.standard_normal((25, 10)))[0]
        w = (u * (np.arange(1, 11)[::-1] ** 1.5)) @ v.T
        l, r, s = wasi.svd_factorize(w.astype(np.float32), 0.9)
        rec = l @ r
        res = np.linalg.norm(rec - w) ** 2 / np.linalg.norm(w) ** 2
        assert res <= 0.1 + 1e-3

    def test_hosvd_ranks_and_reconstruction(self):
        rng = np.random.default_rng(6)
        core = rng.standard_normal((2, 3, 2))
        t = np.einsum("pqr,bp,nq,ir->bni", core,
                      rng.standard_normal((6, 2)),
                      rng.standard_normal((8, 3)),
                      rng.standard_normal((7, 2)))
        ranks = wasi.hosvd_ranks(t.astype(np.float32), 0.999)
        assert tuple(ranks) == (2, 3, 2)
        c, f = wasi.hosvd(t.astype(np.float32), ranks)
        rec = np.einsum("pqr,bp,nq,ir->bni", c, *f)
        assert np.linalg.norm(rec - t) / np.linalg.norm(t) < 1e-3

    def test_perplexity_falls_with_eps(self):
        rng = np.random.default_rng(7)
        x = rng.standard_normal((4, 10, 16)).astype(np.float32)
        dy = rng.standard_normal((4, 10, 12)).astype(np.float32)
        ppl = [wasi.perplexity_entry(x, dy, eps)[0] for eps in (0.3, 0.6, 0.9, 0.999)]
        assert ppl[0] >= ppl[-1]
        assert ppl[-1] < 0.1 * ppl[0] + 1e-3


class TestBaselines:
    def test_asi_linear_grads_match_flr(self):
        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.standard_normal((3, 7, 10)), jnp.float32)
        w = jnp.asarray(0.3 * rng.standard_normal((6, 10)), jnp.float32)
        us = (ortho(rng, 3, 2), ortho(rng, 7, 4), ortho(rng, 10, 5))

        def loss(w):
            y, *_ = wasi.asi_linear(x, w, *us)
            return 0.5 * jnp.sum(y * y)

        gw = jax.grad(loss)(w)
        core, new_us = wasi.asi_compress(x, us)
        dy = x @ w.T
        want = ref.lowrank_grad_3d(core, *new_us, dy)
        np.testing.assert_allclose(gw, want, rtol=2e-3, atol=1e-4)

    def test_svdllm_factorize_reconstructs_at_full_rank(self):
        rng = np.random.default_rng(9)
        w = rng.standard_normal((8, 12)).astype(np.float32)
        xc = rng.standard_normal((40, 12)).astype(np.float32)
        wu, wv = wasi.svdllm_factorize(w, xc, 12)
        np.testing.assert_allclose(wu @ wv, w, rtol=1e-2, atol=1e-3)

    def test_svdllm_rank_for_ratio(self):
        assert wasi.svdllm_rank_for_ratio(3072, 768, 4.0) == 153
        assert wasi.svdllm_rank_for_ratio(4, 4, 1e9) == 1

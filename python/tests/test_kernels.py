"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes (and the f32/bf16 dtypes the kernels accept);
assert_allclose against the reference is the CORE correctness signal for
the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lowrank_grad import lowrank_grad_3d
from compile.kernels.lowrank_linear import lowrank_linear
from compile.kernels.subspace import power_step

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def rnd(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


class TestLowrankLinear:
    @given(
        b=st.integers(1, 4),
        n=st.integers(1, 130),
        i=st.integers(1, 96),
        o=st.integers(1, 96),
        k=st.integers(1, 48),
        block=st.sampled_from([32, 128]),
    )
    def test_matches_ref_over_shapes(self, b, n, i, o, k, block):
        rng = np.random.default_rng(b * 1000 + n)
        x, l, r = rnd(rng, b, n, i), rnd(rng, o, k), rnd(rng, k, i)
        got = lowrank_linear(x, l, r, block_rows=block)
        want = ref.lowrank_linear(x, l, r)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_2d_input(self):
        rng = np.random.default_rng(0)
        x, l, r = rnd(rng, 7, 24), rnd(rng, 12, 5), rnd(rng, 5, 24)
        np.testing.assert_allclose(
            lowrank_linear(x, l, r), ref.lowrank_linear(x, l, r), rtol=1e-4
        )

    def test_bf16_inputs_compute_in_f32(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.bfloat16)
        l = jnp.asarray(rng.standard_normal((24, 8)), jnp.bfloat16)
        r = jnp.asarray(rng.standard_normal((8, 32)), jnp.bfloat16)
        got = lowrank_linear(x.astype(jnp.float32), l.astype(jnp.float32),
                             r.astype(jnp.float32))
        want = ref.lowrank_linear(x.astype(jnp.float32), l.astype(jnp.float32),
                                  r.astype(jnp.float32))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_rank_edge(self):
        # K=1 minimal rank still correct
        rng = np.random.default_rng(2)
        x, l, r = rnd(rng, 1, 1, 8), rnd(rng, 4, 1), rnd(rng, 1, 8)
        np.testing.assert_allclose(
            lowrank_linear(x, l, r), ref.lowrank_linear(x, l, r), rtol=1e-4
        )


class TestLowrankGrad:
    @given(
        b=st.integers(1, 6),
        n=st.integers(1, 70),
        i=st.integers(2, 64),
        o=st.integers(2, 64),
        r1=st.integers(1, 4),
        r2=st.integers(1, 12),
        r3=st.integers(1, 16),
    )
    def test_matches_ref_over_shapes(self, b, n, i, o, r1, r2, r3):
        r1, r2, r3 = min(r1, b), min(r2, n), min(r3, i)
        rng = np.random.default_rng(n * 100 + i)
        core = rnd(rng, r1, r2, r3)
        u1, u2, u3 = rnd(rng, b, r1), rnd(rng, n, r2), rnd(rng, i, r3)
        dy = rnd(rng, b, n, o)
        got = lowrank_grad_3d(core, u1, u2, u3, dy)
        want = ref.lowrank_grad_3d(core, u1, u2, u3, dy)
        np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)

    def test_equals_dense_grad_on_reconstruction(self):
        # f_LR(tucker(x), dy) == dense_grad(reconstruct(x), dy)
        rng = np.random.default_rng(3)
        x = rnd(rng, 4, 9, 12)
        dy = rnd(rng, 4, 9, 7)
        u1 = jnp.asarray(np.linalg.qr(rng.standard_normal((4, 3)))[0], jnp.float32)
        u2 = jnp.asarray(np.linalg.qr(rng.standard_normal((9, 5)))[0], jnp.float32)
        u3 = jnp.asarray(np.linalg.qr(rng.standard_normal((12, 6)))[0], jnp.float32)
        core = ref.tucker3(x, u1, u2, u3)
        xt = jnp.einsum("pqr,bp,nq,ir->bni", core, u1, u2, u3)
        got = lowrank_grad_3d(core, u1, u2, u3, dy)
        want = ref.dense_grad(xt, dy)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_4d_ref_consistent_with_dense(self):
        rng = np.random.default_rng(4)
        x = rnd(rng, 3, 4, 5, 8)
        dy = rnd(rng, 3, 4, 5, 6)
        us = [jnp.asarray(np.linalg.qr(rng.standard_normal((d, min(d, 3))))[0],
                          jnp.float32) for d in (3, 4, 5, 8)]
        core = ref.tucker4(x, *us)
        xt = jnp.einsum("pqrt,bp,hq,wr,it->bhwi", core, *us)
        got = ref.lowrank_grad_4d(core, *us, dy)
        want = ref.dense_grad(xt, dy)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


class TestPowerStep:
    @given(
        a=st.integers(2, 64),
        b=st.integers(2, 600),
        r=st.integers(1, 16),
        block=st.sampled_from([64, 256]),
    )
    def test_matches_ref(self, a, b, r, block):
        r = min(r, a)
        rng = np.random.default_rng(a + b)
        a_m = rnd(rng, a, b)
        u = rnd(rng, a, r)
        got = power_step(a_m, u, b_block=block)
        want = ref.power_step(a_m, u)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_invariant_subspace_is_fixed_point(self):
        # If u spans an invariant subspace, power step preserves its span.
        rng = np.random.default_rng(5)
        q = np.linalg.qr(rng.standard_normal((20, 3)))[0].astype(np.float32)
        a_m = jnp.asarray(q @ np.diag([5.0, 4.0, 3.0]).astype(np.float32) @ q.T)
        a_full = jnp.concatenate([a_m, jnp.zeros((20, 10))], axis=1)
        p = power_step(a_full, jnp.asarray(q))
        # columns of p stay in span(q)
        proj = q @ (q.T @ np.asarray(p))
        np.testing.assert_allclose(proj, p, rtol=1e-3, atol=1e-3)

"""ops.py: LAPACK-free orthogonalization + tensor algebra invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import ops

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


class TestOrthogonalize:
    @given(n=st.integers(4, 100), r=st.integers(1, 24))
    def test_gs_orthonormal(self, n, r):
        r = min(r, n)
        rng = np.random.default_rng(n * r)
        a = jnp.asarray(rng.standard_normal((n, r)), jnp.float32)
        q = ops.orthogonalize_gs(a)
        g = np.asarray(q.T @ q)
        np.testing.assert_allclose(g, np.eye(r), atol=2e-4)

    def test_gs_spans_input(self):
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((30, 6)), jnp.float32)
        q = ops.orthogonalize_gs(a)
        proj = q @ (q.T @ a)
        np.testing.assert_allclose(proj, a, rtol=1e-3, atol=1e-3)

    def test_ns_approximately_orthonormal(self):
        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.standard_normal((40, 8)), jnp.float32)
        q = ops.orthogonalize_ns(a, steps=12)
        g = np.asarray(q.T @ q)
        np.testing.assert_allclose(g, np.eye(8), atol=5e-2)

    def test_no_lapack_custom_calls_in_lowered_gs(self):
        # The whole point of ops.py: lowered HLO must be custom-call-free.
        lowered = jax.jit(ops.orthogonalize_gs).lower(
            jax.ShapeDtypeStruct((32, 8), jnp.float32))
        hlo = lowered.compiler_ir("stablehlo")
        assert "lapack" not in str(hlo).lower()

    def test_dispatch(self):
        a = jnp.eye(4)
        assert ops.orthogonalize(a, "gs").shape == (4, 4)
        assert ops.orthogonalize(a, "ns").shape == (4, 4)
        with pytest.raises(ValueError):
            ops.orthogonalize(a, "qr")


class TestSubspaceIter:
    def test_converges_to_dominant_subspace(self):
        rng = np.random.default_rng(3)
        u_true = np.linalg.qr(rng.standard_normal((30, 2)))[0]
        v_true = np.linalg.qr(rng.standard_normal((50, 2)))[0]
        a = jnp.asarray(
            (u_true * [9.0, 7.0]) @ v_true.T
            + 0.01 * rng.standard_normal((30, 50)),
            jnp.float32,
        )
        u = jnp.asarray(rng.standard_normal((30, 2)), jnp.float32)
        for _ in range(8):
            u = ops.subspace_iter_step(a, u)
        # principal angles ≈ 0
        s = np.linalg.svd(np.asarray(u).T @ u_true, compute_uv=False)
        assert s.min() > 0.99


class TestTensorAlgebra:
    @given(
        shape=st.tuples(st.integers(2, 5), st.integers(2, 6), st.integers(2, 7)),
        mode=st.integers(0, 2),
    )
    def test_unfold_consistent_with_moveaxis(self, shape, mode):
        rng = np.random.default_rng(sum(shape))
        t = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        m = ops.unfold(t, mode)
        want = np.moveaxis(np.asarray(t), mode, 0).reshape(shape[mode], -1)
        np.testing.assert_array_equal(np.asarray(m), want)

    def test_mode_product_identity(self):
        rng = np.random.default_rng(4)
        t = jnp.asarray(rng.standard_normal((3, 4, 5)), jnp.float32)
        for mode in range(3):
            p = ops.mode_product(t, jnp.eye(t.shape[mode]), mode)
            np.testing.assert_allclose(p, t, atol=1e-6)

    def test_tucker_reconstruct_inverts_projection(self):
        rng = np.random.default_rng(5)
        t = jnp.asarray(rng.standard_normal((4, 5, 6)), jnp.float32)
        us = [jnp.asarray(np.linalg.qr(rng.standard_normal((d, d)))[0], jnp.float32)
              for d in t.shape]
        core = t
        for m, u in enumerate(us):
            core = ops.mode_product(core, u.T, m)
        rec = ops.tucker_reconstruct(core, us)
        np.testing.assert_allclose(rec, t, rtol=1e-3, atol=1e-4)


class TestClip:
    def test_clip_reduces_large_norm(self):
        tree = {"a": jnp.ones((10,)) * 10.0}
        clipped, norm = ops.clip_by_global_norm(tree, 2.0)
        assert float(norm) > 2.0
        new_norm = float(ops.global_norm(clipped))
        assert abs(new_norm - 2.0) < 1e-3

    def test_clip_noop_below_threshold(self):
        tree = {"a": jnp.ones((4,)) * 0.1}
        clipped, _ = ops.clip_by_global_norm(tree, 2.0)
        np.testing.assert_allclose(clipped["a"], tree["a"], rtol=1e-5)

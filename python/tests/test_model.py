"""L2 model semantics: shapes, packing, and loss-decreases for all three
architectures in both vanilla and WASI parameterizations."""

import jax
import numpy as np
import pytest

from compile import model, train
from compile.model import SwinLiteConfig, TinyDecConfig, ViTConfig, WasiSpec


def make_batch(rng, b, dim, classes):
    x = rng.standard_normal((b, dim)).astype(np.float32)
    y = np.eye(classes, dtype=np.float32)[rng.integers(0, classes, b)]
    return x, y


@pytest.fixture(scope="module")
def vit_setup():
    cfg = ViTConfig(dim=64, depth=2, heads=2)
    params = model.init_vit(cfg, seed=0)
    return cfg, params


class TestShapes:
    def test_vit_forward_shapes(self, vit_setup):
        cfg, params = vit_setup
        rng = np.random.default_rng(0)
        x, _ = make_batch(rng, 3, 32 * 32 * 3, 10)
        logits, state = model.vit_forward(params, x, cfg)
        assert logits.shape == (3, 10)
        assert state == {}

    def test_swin_forward_shapes(self):
        cfg = SwinLiteConfig(dim=32, depths=(1, 1), heads=2)
        params = model.init_swinlite(cfg, 0)
        rng = np.random.default_rng(1)
        x, _ = make_batch(rng, 2, 32 * 32 * 3, 10)
        logits, _ = model.swinlite_forward(params, x, cfg)
        assert logits.shape == (2, 10)

    def test_tinydec_forward_shapes(self):
        cfg = TinyDecConfig(dim=32, depth=2, heads=2, seq=16)
        params = model.init_tinydec(cfg, 0)
        ids = np.random.default_rng(2).integers(0, 256, (3, 16)).astype(np.float32)
        logits, _ = model.tinydec_forward(params, ids, cfg)
        assert logits.shape == (3, 2)

    def test_patchify_roundtrip_count(self, vit_setup):
        cfg, _ = vit_setup
        rng = np.random.default_rng(3)
        x, _ = make_batch(rng, 2, 32 * 32 * 3, 10)
        tok = model.patchify(jax.numpy.asarray(x), cfg)
        assert tok.shape == (2, 64, 48)
        # patch content preservation: total energy equal
        np.testing.assert_allclose(
            np.sum(np.asarray(tok) ** 2), np.sum(x ** 2), rtol=1e-5)


class TestPacking:
    def test_pack_unpack_roundtrip(self, vit_setup):
        _, params = vit_setup
        spec = train.ParamSpec.from_params(params)
        flat = spec.pack(params)
        assert flat.shape == (spec.total,)
        back = spec.unpack(jax.numpy.asarray(flat))
        for name in params:
            np.testing.assert_array_equal(np.asarray(back[name]),
                                          np.asarray(params[name]))

    def test_spec_is_deterministic(self, vit_setup):
        _, params = vit_setup
        s1 = train.ParamSpec.from_params(params)
        s2 = train.ParamSpec.from_params(dict(reversed(list(params.items()))))
        assert s1.entries == s2.entries

    def test_manifest_offsets_contiguous(self, vit_setup):
        _, params = vit_setup
        spec = train.ParamSpec.from_params(params)
        m = spec.manifest()
        off = 0
        for e in m:
            assert e["offset"] == off
            off += int(np.prod(e["shape"])) if e["shape"] else 1
        assert off == spec.total


def run_steps(forward, cfg, spec, params, state, x, y, n=6, lr=0.05):
    pspec = train.ParamSpec.from_params(params)
    sspec = train.ParamSpec.from_params(state) if state else train.empty_spec()
    step = jax.jit(train.make_train_step(forward, cfg, spec, pspec, sspec))
    fp = pspec.pack(params)
    fs = sspec.pack(state) if state else np.zeros(0, np.float32)
    losses = []
    for _ in range(n):
        fp, fs, loss, acc = step(fp, fs, x, y, lr)
        losses.append(float(loss))
    return losses


class TestTraining:
    def test_vanilla_vit_loss_decreases(self, vit_setup):
        cfg, params = vit_setup
        rng = np.random.default_rng(4)
        x, y = make_batch(rng, 8, 32 * 32 * 3, 10)
        losses = run_steps(model.vit_forward, cfg, None, params, None, x, y)
        assert losses[-1] < losses[0]
        assert all(np.isfinite(losses))

    def test_wasi_vit_loss_decreases(self, vit_setup):
        cfg, params = vit_setup
        rng = np.random.default_rng(5)
        x, y = make_batch(rng, 8, 32 * 32 * 3, 10)
        plan = model.vit_wasi_layers(cfg)
        acts = train.capture_activations(model.vit_forward, params, cfg, x, list(plan))
        wp, wr, _ = train.factorize_params(params, plan, 0.8)
        state, ar = train.init_asi_state(acts, plan, 0.8)
        spec = WasiSpec(weight_ranks=wr, asi_ranks=ar)
        losses = run_steps(model.vit_forward, cfg, spec, wp, state, x, y, n=8)
        assert losses[-1] < losses[0]

    def test_asi_baseline_loss_decreases(self, vit_setup):
        cfg, params = vit_setup
        rng = np.random.default_rng(6)
        x, y = make_batch(rng, 8, 32 * 32 * 3, 10)
        plan = model.vit_wasi_layers(cfg)
        acts = train.capture_activations(model.vit_forward, params, cfg, x, list(plan))
        state, ar = train.init_asi_state(acts, plan, 0.8)
        spec = WasiSpec(asi_ranks=ar, asi_only=frozenset(plan.keys()))
        losses = run_steps(model.vit_forward, cfg, spec, params, state, x, y, n=8)
        assert losses[-1] < losses[0]

    def test_svdllm_baseline_trains_adapters_only(self, vit_setup):
        cfg, params = vit_setup
        rng = np.random.default_rng(7)
        x, y = make_batch(rng, 8, 32 * 32 * 3, 10)
        plan = model.vit_wasi_layers(cfg)
        import compile.aot as aot
        acts = train.capture_activations(model.vit_forward, params, cfg, x, list(plan))
        wp, state, spec, _ = aot.build_svdllm_variant(params, plan, 0.8, acts)
        pspec = train.ParamSpec.from_params(wp)
        step = jax.jit(train.make_train_step(model.vit_forward, cfg, spec, pspec,
                                             train.empty_spec()))
        fp = pspec.pack(wp)
        fs = np.zeros(0, np.float32)
        fp0 = np.asarray(fp).copy()
        for _ in range(3):
            fp, fs, loss, _ = step(fp, fs, x, y, 0.05)
        fp = np.asarray(fp)
        # frozen factors unchanged, adapters changed
        d = pspec.unpack(fp)
        d0 = pspec.unpack(fp0)
        name = sorted(plan.keys())[0]
        np.testing.assert_array_equal(np.asarray(d[f"{name}.wu"]),
                                      np.asarray(d0[f"{name}.wu"]))
        assert not np.array_equal(np.asarray(d[f"{name}.lb"]),
                                  np.asarray(d0[f"{name}.lb"]))

    def test_wasi_memory_layout_smaller(self, vit_setup):
        cfg, params = vit_setup
        plan = model.vit_wasi_layers(cfg)
        wp, _, _ = train.factorize_params(params, plan, 0.6)
        p0 = train.ParamSpec.from_params(params).total
        p1 = train.ParamSpec.from_params(wp).total
        assert p1 < p0

    def test_tinydec_freezes_early_blocks(self):
        cfg = TinyDecConfig(dim=32, depth=2, heads=2, seq=16)
        params = model.init_tinydec(cfg, 0)
        rng = np.random.default_rng(8)
        ids = rng.integers(0, 256, (4, 16)).astype(np.float32)
        y = np.eye(2, dtype=np.float32)[rng.integers(0, 2, 4)]
        fwd = lambda p, x, c, s, st: model.tinydec_forward(p, x, c, s, st, tune_from=1)
        pspec = train.ParamSpec.from_params(params)
        step = jax.jit(train.make_train_step(fwd, cfg, None, pspec, train.empty_spec()))
        fp0 = pspec.pack(params)
        fp, _, _, _ = step(fp0, np.zeros(0, np.float32), ids, y, 0.05)
        d0, d1 = pspec.unpack(fp0), pspec.unpack(np.asarray(fp))
        # Block 0 (before tune_from) gets no gradient — only the tiny weight
        # decay term moves it; block 1 receives real task gradients.
        frozen_delta = np.abs(np.asarray(d1["blocks.0.attn.qkv.w"])
                              - np.asarray(d0["blocks.0.attn.qkv.w"])).max()
        trained_delta = np.abs(np.asarray(d1["blocks.1.attn.qkv.w"])
                               - np.asarray(d0["blocks.1.attn.qkv.w"])).max()
        scale = np.abs(np.asarray(d0["blocks.0.attn.qkv.w"])).max()
        assert frozen_delta <= 0.05 * 1e-4 * scale * 1.01  # lr * wd * |w|
        assert trained_delta > 10 * frozen_delta

"""Train/infer step builders + flat parameter packing.

The rust coordinator drives training through a single AOT-compiled step:

    (params_flat, asi_state_flat, batch_x, batch_y_onehot, lr)
        -> (params_flat', asi_state_flat', loss, accuracy)

Everything is f32; parameter and state layouts are fixed by ``ParamSpec``
and exported to the manifest so rust can slice, checkpoint, and inspect
individual tensors.

The optimizer is the paper's recipe (App. B.1): SGD, momentum 0, weight
decay 1e-4 (matrices only), global L2 gradient clipping at 2.0, cosine LR
handled by the rust scheduler (lr arrives as an input scalar).  After the
SGD update, every factored layer gets one WSI refresh step (Algorithm 1).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import ops, wasi
from .model import WasiSpec

GRAD_CLIP = 2.0
WEIGHT_DECAY = 1e-4


# ---------------------------------------------------------------------------
# Flat packing
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    """Deterministic (name, shape, offset) layout of a parameter dict."""

    entries: tuple  # ((name, shape, offset), ...)
    total: int

    @staticmethod
    def from_params(params: dict) -> "ParamSpec":
        entries = []
        off = 0
        for name in sorted(params.keys()):
            shape = tuple(int(d) for d in np.shape(params[name]))
            entries.append((name, shape, off))
            off += int(np.prod(shape)) if shape else 1
        return ParamSpec(tuple(entries), off)

    def pack(self, params: dict):
        """Dict -> flat vector (numpy or jnp, following the inputs)."""
        parts = [np.asarray(params[name], np.float32).reshape(-1)
                 for name, _, _ in self.entries]
        return np.concatenate(parts) if parts else np.zeros(0, np.float32)

    def unpack(self, flat):
        """Flat traced vector -> dict of reshaped views (static slices)."""
        out = {}
        for name, shape, off in self.entries:
            n = int(np.prod(shape)) if shape else 1
            out[name] = flat[off:off + n].reshape(shape)
        return out

    def manifest(self):
        return [
            {"name": name, "shape": list(shape), "offset": off}
            for name, shape, off in self.entries
        ]


def empty_spec() -> ParamSpec:
    return ParamSpec((), 0)


# ---------------------------------------------------------------------------
# WASI-ification of a pretrained model
# ---------------------------------------------------------------------------


def factorize_params(params: dict, layer_plan: dict, eps: float):
    """Replace each planned layer's dense W with (L, R) at threshold eps.

    ``layer_plan``: name -> ((O, I), act_dims) as produced by
    ``model.*_wasi_layers``.  Returns (new_params, weight_ranks, spectra).
    """
    out = dict(params)
    weight_ranks = {}
    spectra = {}
    for name in sorted(layer_plan.keys()):
        w = np.asarray(params[f"{name}.w"])
        l, r, s = wasi.svd_factorize(w, eps)
        del out[f"{name}.w"]
        out[f"{name}.l"] = l
        out[f"{name}.r"] = r
        weight_ranks[name] = l.shape[1]
        spectra[name] = s
    return out, weight_ranks, spectra


def init_asi_state(activations: dict, layer_plan: dict, eps: float,
                   max_ranks: dict | None = None):
    """HOSVD-initialize the ASI warm-start bases from captured activations.

    ``activations``: name -> ndarray (the input activation of each planned
    layer on a held-out batch).  Returns (state_dict, asi_ranks).
    """
    state = {}
    asi_ranks = {}
    for name in sorted(layer_plan.keys()):
        x = np.asarray(activations[name])
        ranks = wasi.hosvd_ranks(x, eps)
        if max_ranks and name in max_ranks:
            ranks = tuple(min(r, m) for r, m in zip(ranks, max_ranks[name]))
        _, factors = wasi.hosvd(x, ranks)
        asi_ranks[name] = ranks
        for m, u in enumerate(factors, start=1):
            state[f"{name}.u{m}"] = u
    return state, asi_ranks


def capture_activations(forward, params, cfg, x, layer_names):
    """Run a vanilla forward and stash the input activation of each layer.

    Uses the capture hook in ``model.linear`` via a WasiSpec that marks
    the layers but factors nothing.
    """
    spec = WasiSpec(weight_ranks={}, asi_ranks={n: () for n in layer_names},
                    capture=True)
    _, new_state = forward(params, x, cfg, spec, {})
    return {n: np.asarray(new_state[f"{n}.__x"]) for n in layer_names}


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def _decay_mask(name: str) -> bool:
    return name.endswith((".w", ".l", ".r")) or name in ("tok_embed",)


def make_train_step(forward, cfg, spec: WasiSpec | None,
                    pspec: ParamSpec, sspec: ParamSpec):
    """Build the jittable train step closed over the model and layouts."""

    factored = sorted(spec.weight_ranks.keys()) if spec else []

    def train_step(flat_params, flat_state, x, y1h, lr):
        params = pspec.unpack(flat_params)
        state = sspec.unpack(flat_state)

        def loss_fn(p):
            logits, new_state = forward(p, x, cfg, spec, state)
            logp = jax.nn.log_softmax(logits, axis=-1)
            loss = -jnp.mean(jnp.sum(y1h * logp, axis=-1))
            acc = jnp.mean(
                (jnp.argmax(logits, -1) == jnp.argmax(y1h, -1)).astype(jnp.float32))
            return loss, (acc, new_state)

        (loss, (acc, new_state)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)

        grads, _ = ops.clip_by_global_norm(grads, GRAD_CLIP)

        new_params = {}
        for name, p in params.items():
            g = grads[name]
            if _decay_mask(name):
                g = g + WEIGHT_DECAY * p
            new_params[name] = p - lr * g

        # WSI refresh (Algorithm 1) on every factored layer.
        method = spec.method if spec else "gs"
        for name in factored:
            l, r = new_params[f"{name}.l"], new_params[f"{name}.r"]
            lp, rp = wasi.wsi_refresh(l, r, method)
            new_params[f"{name}.l"] = lp
            new_params[f"{name}.r"] = rp

        out_state = {}
        for name, _, _ in sspec.entries:
            out_state[name] = new_state.get(name, state[name])

        return (pspec.pack_traced(new_params), sspec.pack_traced(out_state),
                loss, acc)

    return train_step


def make_infer_step(forward, cfg, spec: WasiSpec | None, pspec: ParamSpec):
    """(flat_params, x) -> logits.  ASI is inactive at inference (no
    backward pass), so the factored layers run plain X R^T L^T."""

    def infer_step(flat_params, x):
        params = pspec.unpack(flat_params)
        logits, _ = forward(params, x, cfg, spec, {})
        return logits

    return infer_step


# Traced packing (jnp concatenate; numpy path lives on ParamSpec.pack).
def _pack_traced(self: ParamSpec, params: dict):
    if not self.entries:
        return jnp.zeros((0,), jnp.float32)
    return jnp.concatenate([params[name].reshape(-1)
                            for name, _, _ in self.entries])


ParamSpec.pack_traced = _pack_traced

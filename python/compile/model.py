"""L2 models: ViTTiny, SwinLite (4D activations), TinyDec (decoder-only).

Each model exists in two parameterizations:

* **vanilla** — every linear layer is a dense (O, I) matrix;
* **WASI**    — the designated linear layers are factored (L, R) pairs with
  per-layer ASI warm-start bases threaded through the forward pass
  (see wasi.py).  By default only the MLP-block linears are factored
  (the paper's main experiments); ``wasi_attn=True`` extends to the
  attention qkv/proj linears (paper Tab. 1).

Parameters are plain dicts keyed by dotted names; ``param_spec`` fixes a
deterministic order so the whole model crosses the rust↔XLA boundary as a
single flat f32 vector (static slicing in ``pack.py``).
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import wasi
from .kernels import ref

# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ViTConfig:
    """ViT-tiny: 32x32x3 images, 4x4 patches -> 64 tokens + CLS."""

    image: int = 32
    patch: int = 4
    dim: int = 128
    depth: int = 4
    heads: int = 4
    mlp_ratio: int = 4
    classes: int = 10

    @property
    def tokens(self) -> int:
        return (self.image // self.patch) ** 2 + 1  # + CLS

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * 3

    @property
    def hidden(self) -> int:
        return self.dim * self.mlp_ratio


@dataclass(frozen=True)
class SwinLiteConfig:
    """Two-stage hierarchical model with (B, H, W, C) activations.

    Window attention over ``window``-sized squares + 4D-activation MLP
    blocks; patch merging halves H,W and doubles C between stages.  This
    is the 4D-ASI path that SVD-LLM's whitening cannot handle (App. A.4).
    """

    image: int = 32
    patch: int = 2
    dim: int = 48
    depths: tuple = (2, 2)
    window: int = 4
    heads: int = 4
    mlp_ratio: int = 4
    classes: int = 10

    @property
    def grid(self) -> int:
        return self.image // self.patch  # 16

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * 3


@dataclass(frozen=True)
class TinyDecConfig:
    """Decoder-only LM head for BoolQ-like yes/no sequence classification."""

    vocab: int = 256
    seq: int = 64
    dim: int = 128
    depth: int = 4
    heads: int = 4
    mlp_ratio: int = 4
    classes: int = 2


@dataclass(frozen=True)
class WasiSpec:
    """Factorization plan for one model: layer name -> (K, asi_ranks)."""

    weight_ranks: dict = field(default_factory=dict)   # name -> K
    asi_ranks: dict = field(default_factory=dict)      # name -> tuple r_m
    method: str = "gs"
    use_kernels: bool = False
    refresh_every: int = 1
    capture: bool = False  # record layer inputs (build-time calibration)
    # Baseline modes: ASI-only (dense W, compressed residuals) and
    # SVD-LLM (frozen whitened factors + LoRA adapter).
    asi_only: frozenset = frozenset()
    svdllm: frozenset = frozenset()
    lora_alpha: float = 16.0

    def is_factored(self, name: str) -> bool:
        return name in self.weight_ranks


# ---------------------------------------------------------------------------
# Shared building blocks
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def linear(params, prefix, x, spec: WasiSpec | None, state, new_state):
    """Dense or WASI-factored linear + bias, dispatching on the spec.

    ``state``/``new_state`` are dicts of ASI warm-start bases; the layer
    reads its bases from ``state`` and writes refreshed ones into
    ``new_state``.
    """
    b = params[f"{prefix}.b"]
    if spec is not None and spec.capture and prefix in spec.asi_ranks:
        new_state[f"{prefix}.__x"] = x  # build-time calibration hook
    if spec is not None and prefix in spec.asi_only and f"{prefix}.u1" in state:
        # ASI-only baseline: dense weight, compressed backward residuals.
        w = params[f"{prefix}.w"]
        u1, u2, u3 = (state[f"{prefix}.u{m}"] for m in (1, 2, 3))
        y, u1n, u2n, u3n = wasi.asi_linear(x, w, u1, u2, u3, spec.method)
        for m, u in zip((1, 2, 3), (u1n, u2n, u3n)):
            new_state[f"{prefix}.u{m}"] = u
        return y + b
    if spec is not None and prefix in spec.svdllm and f"{prefix}.wu" in params:
        # SVD-LLM baseline: frozen whitened low-rank pair + LoRA adapter.
        wu = jax.lax.stop_gradient(params[f"{prefix}.wu"])
        wv = jax.lax.stop_gradient(params[f"{prefix}.wv"])
        la = params[f"{prefix}.la"]  # (r, I)
        lb = params[f"{prefix}.lb"]  # (O, r)
        y = (x @ wv.T) @ wu.T
        y = y + ((x @ la.T) @ lb.T) * (spec.lora_alpha / la.shape[0])
        return y + b
    if spec is not None and spec.is_factored(prefix) and f"{prefix}.l" in params:
        l, r = params[f"{prefix}.l"], params[f"{prefix}.r"]
        if f"{prefix}.u1" not in state:
            # Inference: no backward pass, so no ASI compression (Eq. 8 only).
            return ref.lowrank_linear(x, l, r) + b
        if x.ndim == 3:
            u1, u2, u3 = (state[f"{prefix}.u{m}"] for m in (1, 2, 3))
            y, u1n, u2n, u3n = wasi.wasi_linear(
                x, l, r, u1, u2, u3, spec.method, spec.use_kernels
            )
            for m, u in zip((1, 2, 3), (u1n, u2n, u3n)):
                new_state[f"{prefix}.u{m}"] = u
        elif x.ndim == 4:
            u1, u2, u3, u4 = (state[f"{prefix}.u{m}"] for m in (1, 2, 3, 4))
            y, u1n, u2n, u3n, u4n = wasi.wasi_linear_4d(
                x, l, r, u1, u2, u3, u4, spec.method
            )
            for m, u in zip((1, 2, 3, 4), (u1n, u2n, u3n, u4n)):
                new_state[f"{prefix}.u{m}"] = u
        else:
            raise ValueError(f"unsupported activation rank {x.ndim}")
        return y + b
    w = params[f"{prefix}.w"]
    y = x @ w.T + b
    if spec is not None and spec.capture:
        probe = state.get(f"{prefix}.__probe")
        if probe is not None:
            # Gradient w.r.t. this zero probe is exactly dL/dY for this
            # layer — used to build the Eq. 28 perplexity table at AOT time.
            y = y + probe
    return y


def attention(params, prefix, x, heads, spec, state, new_state, causal=False):
    """Multi-head self-attention over (B, N, D) tokens."""
    b_, n, d = x.shape
    hd = d // heads
    qkv = linear(params, f"{prefix}.qkv", x, spec, state, new_state)  # (B,N,3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def split_heads(t):
        return t.reshape(b_, n, heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split_heads(q), split_heads(k), split_heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
    if causal:
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b_, n, d)
    return linear(params, f"{prefix}.proj", out, spec, state, new_state)


def mlp(params, prefix, x, spec, state, new_state):
    h = linear(params, f"{prefix}.fc1", x, spec, state, new_state)
    h = jax.nn.gelu(h)
    return linear(params, f"{prefix}.fc2", h, spec, state, new_state)


def block(params, prefix, x, heads, spec, state, new_state, causal=False):
    h = layer_norm(x, params[f"{prefix}.ln1.g"], params[f"{prefix}.ln1.b"])
    x = x + attention(params, f"{prefix}.attn", h, heads, spec, state, new_state, causal)
    h = layer_norm(x, params[f"{prefix}.ln2.g"], params[f"{prefix}.ln2.b"])
    x = x + mlp(params, f"{prefix}.mlp", h, spec, state, new_state)
    return x


# ---------------------------------------------------------------------------
# Weight init (power-law spectra: the "pretrained" premise, see DESIGN.md §3)
# ---------------------------------------------------------------------------


def _powerlaw_matrix(rng: np.random.Generator, o: int, i: int, alpha: float = 0.8,
                     scale: float | None = None) -> np.ndarray:
    """Random (O, I) matrix with singular values s_j ∝ (j+1)^-alpha.

    Real pretrained transformer weights have rapidly decaying spectra —
    exactly the premise WASI exploits.  Plain Gaussian init has a flat
    Marchenko-Pastur spectrum and would make every K_i ≈ full rank.
    """
    k = min(o, i)
    u, _ = np.linalg.qr(rng.standard_normal((o, k)))
    v, _ = np.linalg.qr(rng.standard_normal((i, k)))
    s = (np.arange(1, k + 1, dtype=np.float64) ** -alpha)
    if scale is None:
        scale = np.sqrt(2.0 / (o + i)) * np.sqrt(k) / np.linalg.norm(s)
    w = (u * (s * scale * np.sqrt(k))) @ v.T
    return w.astype(np.float32)


def _init_linear(params, rng, prefix, o, i):
    params[f"{prefix}.w"] = _powerlaw_matrix(rng, o, i)
    params[f"{prefix}.b"] = np.zeros((o,), np.float32)


def _init_block(params, rng, prefix, d, hidden):
    _init_linear(params, rng, f"{prefix}.attn.qkv", 3 * d, d)
    _init_linear(params, rng, f"{prefix}.attn.proj", d, d)
    _init_linear(params, rng, f"{prefix}.mlp.fc1", hidden, d)
    _init_linear(params, rng, f"{prefix}.mlp.fc2", d, hidden)
    for ln in ("ln1", "ln2"):
        params[f"{prefix}.{ln}.g"] = np.ones((d,), np.float32)
        params[f"{prefix}.{ln}.b"] = np.zeros((d,), np.float32)


# ---------------------------------------------------------------------------
# ViTTiny
# ---------------------------------------------------------------------------


def init_vit(cfg: ViTConfig, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    params: dict = {}
    _init_linear(params, rng, "embed", cfg.dim, cfg.patch_dim)
    params["cls"] = (0.02 * rng.standard_normal((1, 1, cfg.dim))).astype(np.float32)
    params["pos"] = (0.02 * rng.standard_normal((1, cfg.tokens, cfg.dim))).astype(np.float32)
    for i in range(cfg.depth):
        _init_block(params, rng, f"blocks.{i}", cfg.dim, cfg.hidden)
    params["norm.g"] = np.ones((cfg.dim,), np.float32)
    params["norm.b"] = np.zeros((cfg.dim,), np.float32)
    _init_linear(params, rng, "head", cfg.classes, cfg.dim)
    return params


def patchify(x, cfg: ViTConfig):
    """(B, 32*32*3) flat images -> (B, 64, 48) patch tokens."""
    b = x.shape[0]
    g = cfg.image // cfg.patch
    x = x.reshape(b, g, cfg.patch, g, cfg.patch, 3)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, g * g, cfg.patch_dim)


def vit_forward(params, x, cfg: ViTConfig, spec: WasiSpec | None = None,
                state: dict | None = None):
    """x: (B, image*image*3) flat f32 -> (logits (B, classes), new_state)."""
    new_state: dict = {}
    state = state or {}
    tok = patchify(x, cfg)
    tok = linear(params, "embed", tok, None, state, new_state)
    cls = jnp.broadcast_to(params["cls"], (tok.shape[0], 1, cfg.dim))
    tok = jnp.concatenate([cls, tok], axis=1) + params["pos"]
    for i in range(cfg.depth):
        tok = block(params, f"blocks.{i}", tok, cfg.heads, spec, state, new_state)
    tok = layer_norm(tok, params["norm.g"], params["norm.b"])
    logits = linear(params, "head", tok[:, 0], None, state, new_state)
    return logits, new_state


def vit_wasi_layers(cfg: ViTConfig, attn: bool = False):
    """Names of the linears WASI factors, with their (O, I) and activation dims."""
    layers = {}
    n = cfg.tokens
    for i in range(cfg.depth):
        layers[f"blocks.{i}.mlp.fc1"] = ((cfg.hidden, cfg.dim), (n, cfg.dim))
        layers[f"blocks.{i}.mlp.fc2"] = ((cfg.dim, cfg.hidden), (n, cfg.hidden))
        if attn:
            layers[f"blocks.{i}.attn.qkv"] = ((3 * cfg.dim, cfg.dim), (n, cfg.dim))
            layers[f"blocks.{i}.attn.proj"] = ((cfg.dim, cfg.dim), (n, cfg.dim))
    return layers


# ---------------------------------------------------------------------------
# SwinLite
# ---------------------------------------------------------------------------


def init_swinlite(cfg: SwinLiteConfig, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    params: dict = {}
    _init_linear(params, rng, "embed", cfg.dim, cfg.patch_dim)
    d = cfg.dim
    g = cfg.grid
    for s, depth in enumerate(cfg.depths):
        params[f"stages.{s}.pos"] = (0.02 * rng.standard_normal((1, g, g, d))).astype(np.float32)
        for i in range(depth):
            _init_block(params, rng, f"stages.{s}.blocks.{i}", d, d * cfg.mlp_ratio)
        if s + 1 < len(cfg.depths):
            _init_linear(params, rng, f"stages.{s}.merge", 2 * d, 4 * d)
            d, g = 2 * d, g // 2
    params["norm.g"] = np.ones((d,), np.float32)
    params["norm.b"] = np.zeros((d,), np.float32)
    _init_linear(params, rng, "head", cfg.classes, d)
    return params


def _window_partition(x, w):
    b, h, ww, c = x.shape
    x = x.reshape(b, h // w, w, ww // w, w, c).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(-1, w * w, c)  # (B*nw, w*w, C)


def _window_merge(x, w, h, ww, b):
    c = x.shape[-1]
    x = x.reshape(b, h // w, ww // w, w, w, c).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, h, ww, c)


def swin_block(params, prefix, x, cfg: SwinLiteConfig, spec, state, new_state):
    """Window attention (3D within windows) + 4D-activation MLP."""
    b, h, w_, c = x.shape
    hn = layer_norm(x, params[f"{prefix}.ln1.g"], params[f"{prefix}.ln1.b"])
    win = _window_partition(hn, cfg.window)
    # Attention linears stay dense here (spec=None): the 4D WASI path is
    # exercised by the MLP; qkv inside windows is 3D with a huge batch dim.
    att = attention(params, f"{prefix}.attn", win, cfg.heads, None, state, new_state)
    x = x + _window_merge(att, cfg.window, h, w_, b)
    hn = layer_norm(x, params[f"{prefix}.ln2.g"], params[f"{prefix}.ln2.b"])
    x = x + mlp(params, f"{prefix}.mlp", hn, spec, state, new_state)  # 4D
    return x


def swinlite_forward(params, x, cfg: SwinLiteConfig, spec: WasiSpec | None = None,
                     state: dict | None = None):
    """x: (B, image*image*3) -> (logits, new_state); activations are 4D."""
    new_state: dict = {}
    state = state or {}
    b = x.shape[0]
    g = cfg.grid
    x = x.reshape(b, g, cfg.patch, g, cfg.patch, 3).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, g, g, cfg.patch_dim)
    x = linear(params, "embed", x, None, state, new_state)
    d = cfg.dim
    for s, depth in enumerate(cfg.depths):
        x = x + params[f"stages.{s}.pos"]
        for i in range(depth):
            x = swin_block(params, f"stages.{s}.blocks.{i}", x, cfg, spec, state, new_state)
        if s + 1 < len(cfg.depths):
            bb, hh, ww, cc = x.shape
            x = x.reshape(bb, hh // 2, 2, ww // 2, 2, cc).transpose(0, 1, 3, 2, 4, 5)
            x = x.reshape(bb, hh // 2, ww // 2, 4 * cc)
            x = linear(params, f"stages.{s}.merge", x, None, state, new_state)
            d = 2 * d
    x = layer_norm(x, params["norm.g"], params["norm.b"])
    pooled = jnp.mean(x, axis=(1, 2))
    logits = linear(params, "head", pooled, None, state, new_state)
    return logits, new_state


def swinlite_wasi_layers(cfg: SwinLiteConfig):
    layers = {}
    d, g = cfg.dim, cfg.grid
    for s, depth in enumerate(cfg.depths):
        for i in range(depth):
            layers[f"stages.{s}.blocks.{i}.mlp.fc1"] = (
                (d * cfg.mlp_ratio, d), (g, g, d))
            layers[f"stages.{s}.blocks.{i}.mlp.fc2"] = (
                (d, d * cfg.mlp_ratio), (g, g, d * cfg.mlp_ratio))
        if s + 1 < len(cfg.depths):
            d, g = 2 * d, g // 2
    return layers


# ---------------------------------------------------------------------------
# TinyDec
# ---------------------------------------------------------------------------


def init_tinydec(cfg: TinyDecConfig, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    params: dict = {}
    params["tok_embed"] = (0.02 * rng.standard_normal((cfg.vocab, cfg.dim))).astype(np.float32)
    params["pos"] = (0.02 * rng.standard_normal((1, cfg.seq, cfg.dim))).astype(np.float32)
    for i in range(cfg.depth):
        _init_block(params, rng, f"blocks.{i}", cfg.dim, cfg.dim * cfg.mlp_ratio)
    params["norm.g"] = np.ones((cfg.dim,), np.float32)
    params["norm.b"] = np.zeros((cfg.dim,), np.float32)
    _init_linear(params, rng, "head", cfg.classes, cfg.dim)
    return params


def tinydec_forward(params, x, cfg: TinyDecConfig, spec: WasiSpec | None = None,
                    state: dict | None = None, tune_from: int = 0):
    """x: (B, seq) f32 token ids -> (logits (B, classes), new_state).

    ``tune_from`` freezes blocks [0, tune_from) with stop_gradient —
    the paper's "fine-tune the last k layers" sweep (Fig. 7).
    """
    new_state: dict = {}
    state = state or {}
    ids = x.astype(jnp.int32)
    tok = params["tok_embed"][ids] + params["pos"]
    for i in range(cfg.depth):
        tok = block(params, f"blocks.{i}", tok, cfg.heads, spec, state, new_state,
                    causal=True)
        if i + 1 == tune_from:
            tok = jax.lax.stop_gradient(tok)
    tok = layer_norm(tok, params["norm.g"], params["norm.b"])
    logits = linear(params, "head", tok[:, -1], None, state, new_state)
    return logits, new_state


def tinydec_wasi_layers(cfg: TinyDecConfig, tune_from: int = 0):
    layers = {}
    hidden = cfg.dim * cfg.mlp_ratio
    for i in range(tune_from, cfg.depth):
        layers[f"blocks.{i}.mlp.fc1"] = ((hidden, cfg.dim), (cfg.seq, cfg.dim))
        layers[f"blocks.{i}.mlp.fc2"] = ((cfg.dim, hidden), (cfg.seq, hidden))
    return layers

"""LAPACK-free linear-algebra primitives used inside lowered graphs.

Everything here must lower to plain HLO ops: the standalone PJRT CPU client
used by the rust runtime (xla_extension 0.5.1) cannot resolve the LAPACK
custom-calls that ``jnp.linalg.{qr,svd,cholesky}`` emit on CPU.  The paper's
Algorithm 1 calls for Gram-Schmidt anyway, so that is the default
orthogonalizer; Newton-Schulz (pure matmuls) is provided as the perf-pass
alternative.
"""

from functools import partial

import jax
import jax.numpy as jnp

_EPS = 1e-8


def orthogonalize_gs(a: jax.Array) -> jax.Array:
    """Column-wise (modified) Gram-Schmidt orthonormalization.

    ``a`` has shape (n, r) with static r.  Returns Q (n, r) with
    orthonormal columns spanning (approximately) the column space of
    ``a``.  Implemented as a ``fori_loop`` over columns so the lowered
    graph stays small regardless of r; at step j the accumulator q holds
    zeros in columns >= j, so the full-width projection ``q @ (q.T v)``
    only removes components along already-orthonormalized columns.
    """
    n, r = a.shape

    def body(j, q):
        v = jax.lax.dynamic_slice(a, (0, j), (n, 1))  # (n, 1)
        coef = q.T @ v  # (r, 1); columns >= j of q are zero
        v = v - q @ coef
        # second projection pass for numerical robustness (CGS2)
        coef2 = q.T @ v
        v = v - q @ coef2
        nrm = jnp.sqrt(jnp.sum(v * v)) + _EPS
        v = v / nrm
        return jax.lax.dynamic_update_slice(q, v, (0, j))

    q0 = jnp.zeros_like(a)
    return jax.lax.fori_loop(0, r, body, q0)


def orthogonalize_ns(a: jax.Array, steps: int = 8) -> jax.Array:
    """Newton-Schulz orthogonalization (pure matmuls).

    Iterates Y <- Y (1.5 I - 0.5 Y^T Y) after spectral pre-scaling, which
    drives all singular values of Y to 1 while preserving the column
    space.  Cheaper than GS on wide matrices when r is large because it
    is matmul-bound (MXU-friendly); used by the perf pass as an
    alternative orthogonalizer.
    """
    n, r = a.shape
    # Upper bound on the spectral norm: ||A||_2 <= sqrt(||A||_1 ||A||_inf).
    norm1 = jnp.max(jnp.sum(jnp.abs(a), axis=0))
    norminf = jnp.max(jnp.sum(jnp.abs(a), axis=1))
    y = a / (jnp.sqrt(norm1 * norminf) + _EPS)
    eye = jnp.eye(r, dtype=a.dtype)

    def body(_, y):
        g = y.T @ y
        return y @ (1.5 * eye - 0.5 * g)

    return jax.lax.fori_loop(0, steps, body, y)


def orthogonalize(a: jax.Array, method: str = "gs") -> jax.Array:
    """Dispatch helper; ``method`` in {"gs", "ns"}."""
    if method == "gs":
        return orthogonalize_gs(a)
    if method == "ns":
        return orthogonalize_ns(a)
    raise ValueError(f"unknown orthogonalization method {method!r}")


def subspace_iter_step(a_m: jax.Array, u_prev: jax.Array, method: str = "gs") -> jax.Array:
    """One warm-started subspace-iteration step (Algorithm 2 / PowerSGD).

    ``a_m`` is a mode unfolding (a, b); ``u_prev`` (a, r) is last
    iteration's basis.  Returns the refreshed orthonormal basis
    U = orth(A (A^T U_prev)).
    """
    v = a_m.T @ u_prev  # (b, r)
    return orthogonalize(a_m @ v, method)


def global_norm(tree) -> jax.Array:
    """Global L2 norm over a pytree of arrays (for gradient clipping)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x * x) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    """Scale a gradient pytree so its global L2 norm is <= max_norm."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + _EPS))
    return jax.tree_util.tree_map(lambda x: x * scale, tree), norm


def unfold(t: jax.Array, mode: int) -> jax.Array:
    """Mode-m unfolding of a tensor: moves axis ``mode`` first, flattens the rest."""
    moved = jnp.moveaxis(t, mode, 0)
    return moved.reshape(t.shape[mode], -1)


def mode_product(t: jax.Array, m: jax.Array, mode: int) -> jax.Array:
    """i-mode product  (T x_mode M)  with M of shape (q, t.shape[mode])."""
    moved = jnp.moveaxis(t, mode, -1)
    out = moved @ m.T
    return jnp.moveaxis(out, -1, mode)


def tucker_reconstruct(core: jax.Array, factors) -> jax.Array:
    """Reconstruct a tensor from its Tucker core and factor matrices.

    ``factors[m]`` has shape (dim_m, rank_m); the core has the ranks as its
    shape.  Inverse of the compression performed by ASI.
    """
    out = core
    for mode, u in enumerate(factors):
        out = mode_product(out, u, mode)
    return out


@partial(jax.jit, static_argnames=("k",))
def topk_energy_rank(s: jax.Array, eps: float, k: int | None = None):
    """Smallest K with cumulative explained variance >= eps (Eq. sec 3.3).

    ``s`` are singular values sorted descending.  Used only at trace /
    build time (the ranks must be static in the artifacts).
    """
    energy = s * s
    cum = jnp.cumsum(energy) / (jnp.sum(energy) + _EPS)
    return jnp.argmax(cum >= eps) + 1

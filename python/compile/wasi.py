"""WASI — Weight-Activation Subspace Iteration (paper §3.3), in JAX.

Three pieces live here:

* :func:`wasi_linear` — the WASI linear layer as a ``jax.custom_vjp``:
  forward runs in the factored weight subspace (Eq. 8) and Tucker-
  compresses the input activation with one warm-started subspace-iteration
  step per mode (Algorithm 2); backward consumes ONLY the compressed
  factors, computing dR through the f_LR contraction chain (Eqs. 15-18)
  and dX through Eq. 10.  The refreshed ASI bases are primal outputs so
  the warm start threads through the train-step signature.

* :func:`wsi_refresh` — the per-iteration Weight Subspace Iteration step
  (Algorithm 1) in factored form: one subspace-iteration step on the
  implicit W = L R, with Gram-Schmidt orthogonalization, never
  materializing W.

* :func:`svd_factorize` / :func:`select_rank` — the t=0 step: truncated
  SVD with the explained-variance threshold ε (build-time only, numpy).

All in-graph code is LAPACK-free (see ops.py).
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import ops
from .kernels import ref
from .kernels.lowrank_linear import lowrank_linear as pallas_lowrank_linear
from .kernels.lowrank_grad import lowrank_grad_3d as pallas_lowrank_grad_3d

# ---------------------------------------------------------------------------
# Build-time factorization (Step 1 of WSI; numpy, never lowered)
# ---------------------------------------------------------------------------


def select_rank(s: np.ndarray, eps: float) -> int:
    """Smallest K with cumulative explained variance >= eps (§3.3 Step 1).

    sigma_j^2 = s_j^2 / sum_k s_k^2 with s sorted descending.
    """
    energy = s.astype(np.float64) ** 2
    cum = np.cumsum(energy) / max(energy.sum(), 1e-30)
    return int(np.searchsorted(cum, eps) + 1)


def svd_factorize(w: np.ndarray, eps: float):
    """Truncated SVD of a weight matrix (Eqs. 5-7).

    w: (O, I)  ->  L = U_K Σ_K (O, K),  R = V_K^T (K, I),  and the full
    singular-value spectrum (exported to the manifest for rust-side rank
    re-derivation and the Fig-3a stability study).
    """
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    k = select_rank(s, eps)
    l = (u[:, :k] * s[:k]).astype(np.float32)
    r = vt[:k, :].astype(np.float32)
    return l, r, s.astype(np.float32)


def hosvd_ranks(x: np.ndarray, eps: float):
    """Per-mode ranks of a tensor by explained variance of each unfolding.

    Used at build time to size the ASI factors (the AMC criterion the
    paper reuses for rank selection, §3.3(i)).
    """
    ranks = []
    for m in range(x.ndim):
        a = np.moveaxis(x, m, 0).reshape(x.shape[m], -1)
        s = np.linalg.svd(a, compute_uv=False)
        ranks.append(min(select_rank(s, eps), a.shape[0]))
    return tuple(ranks)


def hosvd(x: np.ndarray, ranks):
    """Truncated HOSVD (the AMC baseline's compressor; build-time only)."""
    factors = []
    core = x.astype(np.float64)
    for m, r in enumerate(ranks):
        a = np.moveaxis(x, m, 0).reshape(x.shape[m], -1)
        u, _, _ = np.linalg.svd(a, full_matrices=False)
        u = u[:, :r]
        factors.append(u.astype(np.float32))
        core = np.moveaxis(np.moveaxis(core, m, -1) @ u, -1, m)
    return core.astype(np.float32), factors


# ---------------------------------------------------------------------------
# ASI: activation compression inside the layer (Algorithm 2)
# ---------------------------------------------------------------------------


def asi_compress(x, us, method: str = "gs"):
    """One warm-started subspace-iteration step per mode; returns
    (core, new_us).  x is an N-d tensor, us a tuple of (dim_m, r_m) bases."""
    new_us = []
    for m, u_prev in enumerate(us):
        a_m = ops.unfold(x, m)
        new_us.append(ops.subspace_iter_step(a_m, u_prev, method))
    core = x
    for m, u in enumerate(new_us):
        core = ops.mode_product(core, u.T, m)
    return core, tuple(new_us)


# ---------------------------------------------------------------------------
# The WASI linear layer (custom_vjp)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def wasi_linear(x, l, r, u1, u2, u3, method="gs", use_kernels=False):
    """Factored linear with ASI-compressed residuals (3D activations).

    x: (B, N, I); l: (O, K); r: (K, I); u{1,2,3}: warm-start bases for the
    three modes of x.  Returns (y, u1', u2', u3').
    """
    y, (u1n, u2n, u3n) = _wasi_forward(x, l, r, (u1, u2, u3), method, use_kernels)
    return y, u1n, u2n, u3n


def _wasi_forward(x, l, r, us, method, use_kernels):
    if use_kernels:
        y = pallas_lowrank_linear(x, l, r)
    else:
        y = ref.lowrank_linear(x, l, r)
    _, new_us = asi_compress(x, us, method)
    return y, new_us


def _wasi_fwd(x, l, r, u1, u2, u3, method, use_kernels):
    core, (u1n, u2n, u3n) = asi_compress(x, (u1, u2, u3), method)
    if use_kernels:
        y = pallas_lowrank_linear(x, l, r)
    else:
        y = ref.lowrank_linear(x, l, r)
    # Residuals: ONLY the Tucker factors of x (Eq. 44 memory) + the weight
    # factors.  x itself is dropped — that is the whole point.
    return (y, u1n, u2n, u3n), (core, u1n, u2n, u3n, l, r)


def _wasi_bwd(method, use_kernels, res, cts):
    core, u1, u2, u3, l, r = res
    dy = cts[0]  # (B, N, O); cotangents of the u outputs are ignored
    # Eq. 10: dX = dY · L R  (two thin matmuls, never forming L R)
    dh = dy @ l                      # (B, N, K)
    dx = dh @ r                      # (B, N, I)
    # dL = sum_{b,n} dY ⊗ H~  with H~ = X~ R^T computed in Tucker space:
    #   H~ = core x1 u1 x2 u2 x3 (R u3)   — (B, N, K), K small.
    ru3 = r @ u3                     # (K, r3)
    h_t = ops.tucker_reconstruct(core, (u1, u2, ru3))  # (B, N, K)
    dl = jnp.einsum("bno,bnk->ok", dy, h_t)
    # dR via the f_LR contraction chain (Eqs. 15-18) with dH in place of dY.
    if use_kernels:
        dr = pallas_lowrank_grad_3d(core, u1, u2, u3, dh)
    else:
        dr = ref.lowrank_grad_3d(core, u1, u2, u3, dh)
    zu1 = jnp.zeros_like(u1)
    zu2 = jnp.zeros_like(u2)
    zu3 = jnp.zeros_like(u3)
    return dx, dl, dr, zu1, zu2, zu3


wasi_linear.defvjp(_wasi_fwd, _wasi_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(7,))
def wasi_linear_4d(x, l, r, u1, u2, u3, u4, method="gs"):
    """4D-activation WASI linear (SwinLite path, Eqs. 19-26).

    x: (B, H, W, I); returns (y, u1', u2', u3', u4').  This is the case
    SVD-LLM's whitening cannot handle (Appendix A.4).
    """
    y, us = _wasi_forward_4d(x, l, r, (u1, u2, u3, u4), method)
    return (y,) + us


def _wasi_forward_4d(x, l, r, us, method):
    y = ref.lowrank_linear(x, l, r)
    _, new_us = asi_compress(x, us, method)
    return y, new_us


def _wasi_fwd_4d(x, l, r, u1, u2, u3, u4, method):
    core, new_us = asi_compress(x, (u1, u2, u3, u4), method)
    y = ref.lowrank_linear(x, l, r)
    return (y,) + new_us, (core,) + new_us + (l, r)


def _wasi_bwd_4d(method, res, cts):
    core, u1, u2, u3, u4, l, r = res
    dy = cts[0]                      # (B, H, W, O)
    dh = dy @ l                      # (B, H, W, K)
    dx = dh @ r
    ru4 = r @ u4                     # (K, r4)
    h_t = ops.tucker_reconstruct(core, (u1, u2, u3, ru4))
    dl = jnp.einsum("bhwo,bhwk->ok", dy, h_t)
    dr = ref.lowrank_grad_4d(core, u1, u2, u3, u4, dh)
    zeros = tuple(jnp.zeros_like(u) for u in (u1, u2, u3, u4))
    return (dx, dl, dr) + zeros


wasi_linear_4d.defvjp(_wasi_fwd_4d, _wasi_bwd_4d)


# ---------------------------------------------------------------------------
# ASI-only layer (Nguyen et al. 2025 baseline): dense W, compressed residuals
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(5,))
def asi_linear(x, w, u1, u2, u3, method="gs"):
    """Dense linear whose backward uses ASI-compressed activations.

    x: (B, N, I); w: (O, I).  Returns (y, u1', u2', u3').  The weight
    gradient is computed through the f_LR chain with the full dY — the
    original Eqs. 15-18 orientation (dense O x I output).
    """
    y, us = _asi_forward(x, w, (u1, u2, u3), method)
    return (y,) + us


def _asi_forward(x, w, us, method):
    y = x @ w.T
    _, new_us = asi_compress(x, us, method)
    return y, new_us


def _asi_fwd(x, w, u1, u2, u3, method):
    core, new_us = asi_compress(x, (u1, u2, u3), method)
    y = x @ w.T
    return (y,) + new_us, (core,) + new_us + (w,)


def _asi_bwd(method, res, cts):
    core, u1, u2, u3, w = res
    dy = cts[0]
    dx = dy @ w
    dw = ref.lowrank_grad_3d(core, u1, u2, u3, dy)
    zeros = tuple(jnp.zeros_like(u) for u in (u1, u2, u3))
    return (dx, dw) + zeros


asi_linear.defvjp(_asi_fwd, _asi_bwd)


# ---------------------------------------------------------------------------
# SVD-LLM baseline factorization (Wang et al. 2024; App. A.4)
# ---------------------------------------------------------------------------


def svdllm_factorize(w: np.ndarray, x_calib: np.ndarray, k: int, ridge: float = 1e-3):
    """Truncation-aware data whitening + truncated SVD (Eqs. 47-48).

    w: (O, I); x_calib: (N, I) batch-summed calibration activation.
    Returns (wu (O, K), wv (K, I)).
    """
    g = (x_calib.astype(np.float64).T @ x_calib.astype(np.float64))
    # Scale-aware ridge: the calibration Gram is rank-deficient whenever
    # N < I (batch-summed activations), so regularize relative to its
    # mean diagonal magnitude.
    scale = max(float(np.trace(g)) / g.shape[0], 1e-12)
    g += (ridge * scale) * np.eye(w.shape[1], dtype=np.float64)
    s = np.linalg.cholesky(g)
    u, sv, vt = np.linalg.svd(w.astype(np.float64) @ s, full_matrices=False)
    k = min(k, len(sv))
    sq = np.sqrt(sv[:k])
    wu = (u[:, :k] * sq).astype(np.float32)
    wv = ((sq[:, None] * vt[:k, :]) @ np.linalg.inv(s)).astype(np.float32)
    return wu, wv


def svdllm_rank_for_ratio(o: int, i: int, ratio: float) -> int:
    """K such that K (O + I) = O I / ratio (the paper drives SVD-LLM by
    the compression ratios WASI achieves, App. B.1)."""
    return max(1, int(o * i / (ratio * (o + i))))


# ---------------------------------------------------------------------------
# WSI: weight-factor refresh (Algorithm 1, factored form)
# ---------------------------------------------------------------------------


def wsi_refresh(l, r, method: str = "gs"):
    """One subspace-iteration step on the implicit W = L R.

    Algorithm 1 step t>0, reconciled with the factored parameterization
    (the paper's Eq. 11 updates the product; see DESIGN.md §2.1):

        R'ᵀ = Wᵀ L          = Rᵀ (Lᵀ L)
        L'  = orth(W R'ᵀ)   = orth(L (R R'ᵀ))
        R'' = L'ᵀ W         = (L'ᵀ L) R

    Every product is K×K-bounded except the final thin ones; W is never
    materialized.  After the refresh L is orthonormal and R carries the
    singular-value mass, matching the SVD-based initialization (Eq. 7 up
    to a rotation within the subspace — the product L R is preserved to
    first order, exactly preserved when L has full column rank).
    """
    ltl = l.T @ l                    # (K, K)
    rp = ltl @ r                     # R'ᵀ = Wᵀ L  -> R' = (LᵀL) R, (K, I)
    lp = ops.orthogonalize(l @ (r @ rp.T), method)   # (O, K)
    rpp = (lp.T @ l) @ r             # re-project so L' R'' ≈ L R
    return lp, rpp


def wsi_refresh_materialized(w, l_prev, method: str = "gs"):
    """Algorithm 1 verbatim (requires the full W): the ablation mode used
    by the Fig-3b WSI-vs-SVD study in the rust-native engine, mirrored
    here for cross-checking."""
    rt = w.T @ l_prev                # (I, K)
    l = ops.orthogonalize(w @ rt, method)   # (O, K)
    r = l.T @ w                      # (K, I)
    return l, r


# ---------------------------------------------------------------------------
# Perplexity (Eq. 28) — build-time table for the rank-selection DP
# ---------------------------------------------------------------------------


def perplexity_entry(x: np.ndarray, dy: np.ndarray, eps: float):
    """|| dW_exact - dW_compressed ||_F for one layer at one threshold.

    x: (B, N, I) held-out activation; dy: (B, N, O) its output gradient.
    Returns (perplexity, ranks, memory_elems).
    """
    ranks = hosvd_ranks(x, eps)
    core, factors = hosvd(x, ranks)
    exact = ref.dense_grad(jnp.asarray(x), jnp.asarray(dy))
    approx = ref.lowrank_grad_3d(
        jnp.asarray(core), *(jnp.asarray(f) for f in factors), jnp.asarray(dy)
    )
    ppl = float(jnp.linalg.norm(exact - approx))
    mem = int(np.prod(ranks) + sum(d * r for d, r in zip(x.shape, ranks)))
    return ppl, ranks, mem

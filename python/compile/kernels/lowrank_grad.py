"""Pallas kernel for the f_LR low-rank gradient contraction (Eqs. 15-18).

Computes  dW[o,i] = sum_{b,n} dy[b,n,o] * ~X[b,n,i]  from the Tucker
factors of ~X without ever reconstructing ~X.  The grid walks the token
dimension N in blocks and accumulates dW in the output block, which stays
resident (all grid steps map to block (0, 0)) — the classic reduction
pattern.  Per grid step every operand is small: a (B, n_blk, O) slab of
dy, the (r1, r2, r3) core, and the three thin factor matrices, so the
whole working set fits VMEM at WASI ranks.

Runs under ``interpret=True`` on CPU; see lowrank_linear.py for why.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(dy_ref, u1_ref, u2_ref, u3_ref, core_ref, o_ref):
    g = pl.program_id(0)

    dy = dy_ref[...]        # (B, n_blk, O)
    u1 = u1_ref[...]        # (B, r1)
    u2 = u2_ref[...]        # (n_blk, r2)
    u3 = u3_ref[...]        # (I, r3)
    core = core_ref[...]    # (r1, r2, r3)

    # Eq. 15: Z1[n, o, p] = sum_b dy[b,n,o] u1[b,p]
    z1 = jnp.einsum("bno,bp->nop", dy, u1)
    # Eq. 16: Z2[p, s, n] = sum_q core[p,q,s] u2[n,q]
    z2 = jnp.einsum("pqs,nq->psn", core, u2)
    # Eq. 17: Z3[p, i, n] = sum_s Z2[p,s,n] u3[i,s]
    z3 = jnp.einsum("psn,is->pin", z2, u3)
    # Eq. 18 (partial over this n-block): dW += sum_{n,p} Z1 Z3
    contrib = jnp.einsum("nop,pin->oi", z1, z3)

    @pl.when(g == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("n_block", "interpret"))
def lowrank_grad_3d(core, u1, u2, u3, dy, n_block: int = 64, interpret: bool = True):
    """f_LR for 3D activations via Pallas.

    core: (r1, r2, r3); u1: (B, r1); u2: (N, r2); u3: (I, r3);
    dy: (B, N, O)  ->  dW (O, I).
    """
    b, n, o_dim = dy.shape
    i_dim, r3 = u3.shape
    r1, r2, _ = core.shape

    padded = (n + n_block - 1) // n_block * n_block
    if padded != n:
        dy = jnp.pad(dy, ((0, 0), (0, padded - n), (0, 0)))
        u2 = jnp.pad(u2, ((0, padded - n), (0, 0)))

    return pl.pallas_call(
        _kernel,
        grid=(padded // n_block,),
        in_specs=[
            pl.BlockSpec((b, n_block, o_dim), lambda g: (0, g, 0)),
            pl.BlockSpec((b, r1), lambda g: (0, 0)),
            pl.BlockSpec((n_block, r2), lambda g: (g, 0)),
            pl.BlockSpec((i_dim, r3), lambda g: (0, 0)),
            pl.BlockSpec((r1, r2, r3), lambda g: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((o_dim, i_dim), lambda g: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((o_dim, i_dim), jnp.float32),
        interpret=interpret,
    )(dy, u1, u2, u3, core)

"""Pallas kernel for the warm-started subspace-iteration power step.

Computes  P = A (A^T U)  for a mode unfolding A (a, b) and the previous
basis U (a, r) — the compute core of Algorithm 2 (ASI) and of the WSI
factor refresh.  Orthogonalization of P happens outside the kernel
(Gram-Schmidt, see ops.py): GS is sequential in the rank dimension and
benefits nothing from tiling, while the two rank-r matmuls here are the
FLOPs-dominant part.

The grid tiles the (large) b dimension: each step loads a (a, b_blk) slab
of A once from HBM and uses it for BOTH matmuls — V_blk = A_blk^T U and
P += A_blk V_blk — halving HBM traffic versus two separate matmul ops.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, u_ref, o_ref):
    g = pl.program_id(0)
    a = a_ref[...]  # (a_dim, b_blk)
    u = u_ref[...]  # (a_dim, r)
    v = jnp.dot(a.T, u, preferred_element_type=jnp.float32)   # (b_blk, r)
    p = jnp.dot(a, v, preferred_element_type=jnp.float32)     # (a_dim, r)

    @pl.when(g == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += p


@functools.partial(jax.jit, static_argnames=("b_block", "interpret"))
def power_step(a_m, u_prev, b_block: int = 256, interpret: bool = True):
    """P = A (A^T U) via Pallas; a_m: (a, b), u_prev: (a, r) -> (a, r)."""
    a_dim, b_dim = a_m.shape
    _, r = u_prev.shape

    padded = (b_dim + b_block - 1) // b_block * b_block
    if padded != b_dim:
        a_m = jnp.pad(a_m, ((0, 0), (0, padded - b_dim)))

    return pl.pallas_call(
        _kernel,
        grid=(padded // b_block,),
        in_specs=[
            pl.BlockSpec((a_dim, b_block), lambda g: (0, g)),
            pl.BlockSpec((a_dim, r), lambda g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((a_dim, r), lambda g: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((a_dim, r), jnp.float32),
        interpret=interpret,
    )(a_m, u_prev)

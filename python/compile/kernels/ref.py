"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact reference implementation
here; pytest asserts allclose between the two over a hypothesis-driven
sweep of shapes and dtypes.  The references are also what the L2 model
uses by default (XLA fuses them well on CPU); the kernel path is selected
with ``use_kernels=True`` to prove the full three-layer composition.
"""

import jax.numpy as jnp


def lowrank_linear(x, l, r):
    """Y = X R^T L^T  — the WASI factored forward (Eq. 8).

    x: (..., I), r: (K, I), l: (O, K)  ->  (..., O).
    The rank-space intermediate H = X R^T is the small tensor.
    """
    h = x @ r.T
    return h @ l.T


def lowrank_linear_h(x, r):
    """Rank-space intermediate H = X R^T, exposed for the backward pass."""
    return x @ r.T


def gram(m):
    """G = M^T M — the (small) Gram matrix used by orthogonalization."""
    return m.T @ m


def power_step(a_m, u_prev):
    """Un-orthogonalized subspace-iteration power step:  A (A^T U)."""
    return a_m @ (a_m.T @ u_prev)


def lowrank_grad_3d(core, u1, u2, u3, dy):
    """f_LR for 3D activations (paper Eqs. 15-18).

    Computes  dW[o, i] = sum_{b,n} dy[b,n,o] * ~X[b,n,i]  where
    ~X = core x1 u1 x2 u2 x3 u3, WITHOUT reconstructing ~X.

    core: (r1, r2, r3); u1: (B, r1); u2: (N, r2); u3: (I, r3);
    dy: (B, N, O)  ->  (O, I).

    In factored WASI the same contraction runs with dH (B, N, K) in place
    of dy, producing dR (K, I).
    """
    # Eq. 15: Z1[n, o, r1] = sum_b dy[b,n,o] u1[b,r1]
    z1 = jnp.einsum("bno,bp->nop", dy, u1)
    # Eq. 16: Z2[r1, r3, n] = sum_r2 core[r1,r2,r3] u2[n,r2]
    z2 = jnp.einsum("pqs,nq->psn", core, u2)
    # Eq. 17: Z3[r1, i, n] = sum_r3 Z2[r1,r3,n] u3[i,r3]
    z3 = jnp.einsum("psn,is->pin", z2, u3)
    # Eq. 18: dW[o, i] = sum_{n,r1} Z1[n,o,r1] Z3[r1,i,n]
    return jnp.einsum("nop,pin->oi", z1, z3)


def lowrank_grad_4d(core, u1, u2, u3, u4, dy):
    """f_LR for 4D activations (paper Eqs. 22-26, SwinLite path).

    core: (r1, r2, r3, r4); u1: (B, r1); u2: (H, r2); u3: (W, r3);
    u4: (I, r4); dy: (B, H, W, O)  ->  (O, I).
    """
    # Eq. 22: Z1[r1, h, w, o] = sum_b dy[b,h,w,o] u1[b,r1]
    z1 = jnp.einsum("bhwo,bp->phwo", dy, u1)
    # Eq. 23: Z2[r1, h, r3, r4] = sum_r2 core[r1,r2,r3,r4] u2[h,r2]
    z2 = jnp.einsum("pqst,hq->phst", core, u2)
    # Eq. 24: Z3[r1, h, r3, o] = sum_w Z1[r1,h,w,o] u3[w,r3]
    z3 = jnp.einsum("phwo,ws->phso", z1, u3)
    # Eq. 25: Z4[r1, h, i, r3] = sum_r4 Z2[r1,h,r3,r4] u4[i,r4]
    z4 = jnp.einsum("phst,it->phis", z2, u4)
    # Eq. 26: dW[o, i] = sum_{h,r1,r3} Z3[r1,h,r3,o] Z4[r1,h,i,r3]
    return jnp.einsum("phso,phis->oi", z3, z4)


def dense_grad(x, dy):
    """Vanilla weight gradient  dW = dy^T x  over all leading dims (Eq. 2)."""
    xf = x.reshape(-1, x.shape[-1])
    dyf = dy.reshape(-1, dy.shape[-1])
    return dyf.T @ xf


def tucker3(x, u1, u2, u3):
    """Tucker core  S = X x1 u1^T x2 u2^T x3 u3^T  for a 3D tensor."""
    s = jnp.einsum("bni,bp->pni", x, u1)
    s = jnp.einsum("pni,nq->pqi", s, u2)
    return jnp.einsum("pqi,ir->pqr", s, u3)


def tucker4(x, u1, u2, u3, u4):
    """Tucker core for a 4D tensor."""
    s = jnp.einsum("bhwi,bp->phwi", x, u1)
    s = jnp.einsum("phwi,hq->pqwi", s, u2)
    s = jnp.einsum("pqwi,wr->pqri", s, u3)
    return jnp.einsum("pqri,it->pqrt", s, u4)

"""Pallas kernel for the WASI factored forward  Y = X R^T L^T  (Eq. 8).

TPU mapping of the paper's insight (DESIGN.md §Hardware-Adaptation): the
rank-space intermediate H = X R^T is the *small* tensor, so it stays in
VMEM between the two matmul stages of a single kernel — one HBM round-trip
of H is eliminated compared to two separate matmul ops.  The grid walks
the flattened token dimension (B*N) in ``block_rows`` panels; R^T and L^T
are small enough at WASI ranks to be resident per grid step.

Runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); real-TPU perf is estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, rt_ref, lt_ref, o_ref):
    """One grid step: a rows-panel of X -> rows-panel of Y.

    The intermediate H = X R^T (block_rows x K) never leaves VMEM: it is
    produced by the first ``dot`` and consumed by the second inside the
    same kernel invocation.
    """
    h = jnp.dot(x_ref[...], rt_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(h, lt_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def lowrank_linear(x, l, r, block_rows: int = 128, interpret: bool = True):
    """Factored linear forward via Pallas.

    x: (..., I); l: (O, K); r: (K, I)  ->  (..., O)

    Leading dims are flattened to rows and padded up to a multiple of
    ``block_rows``; the pad rows are sliced off on return.
    """
    lead = x.shape[:-1]
    i_dim = x.shape[-1]
    o_dim, k_dim = l.shape
    rows = 1
    for d in lead:
        rows *= d
    xf = x.reshape(rows, i_dim)

    padded = (rows + block_rows - 1) // block_rows * block_rows
    if padded != rows:
        xf = jnp.pad(xf, ((0, padded - rows), (0, 0)))

    out = pl.pallas_call(
        _kernel,
        grid=(padded // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, i_dim), lambda g: (g, 0)),
            pl.BlockSpec((i_dim, k_dim), lambda g: (0, 0)),
            pl.BlockSpec((k_dim, o_dim), lambda g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, o_dim), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, o_dim), jnp.float32),
        interpret=interpret,
    )(xf, r.T, l.T)

    return out[:rows].reshape(*lead, o_dim)

"""Synthetic datasets for build-time pretraining & calibration.

Stand-in for the paper's ImageNet-pretrain + CIFAR/CUB/Flowers/Pets
fine-tune pipeline (DESIGN.md §3): each class c gets a low-rank template
T_c (rank ``template_rank`` in patch space), and a sample is
``T_c + sigma * noise``.  The low-rank class structure gives activation
maps the concentrated singular-value spectra the paper measures (Fig. 4)
while keeping the task learnable at ViT-tiny scale.

The rust coordinator has an independent implementation of the same family
(rust/src/data/synth.rs) for the fine-tuning datasets; this module only
feeds the build-time pretrain ("base task") and calibration batches.
"""

import numpy as np


def make_templates(rng: np.random.Generator, classes: int, dim: int,
                   template_rank: int = 8) -> np.ndarray:
    """(classes, dim) low-rank class templates with unit RMS."""
    basis = rng.standard_normal((template_rank, dim))
    coefs = rng.standard_normal((classes, template_rank))
    t = coefs @ basis
    t /= np.sqrt(np.mean(t * t, axis=1, keepdims=True)) + 1e-9
    return t.astype(np.float32)


class SynthVision:
    """Synthetic image-classification task: flat (image*image*3,) samples."""

    def __init__(self, classes: int = 10, image: int = 32, sigma: float = 0.7,
                 template_rank: int = 8, seed: int = 0):
        self.classes = classes
        self.dim = image * image * 3
        self.sigma = sigma
        self.rng = np.random.default_rng(seed)
        self.templates = make_templates(self.rng, classes, self.dim, template_rank)

    def batch(self, n: int):
        """Returns (x (n, dim) f32, y_onehot (n, classes) f32)."""
        labels = self.rng.integers(0, self.classes, n)
        x = self.templates[labels] + self.sigma * self.rng.standard_normal(
            (n, self.dim)).astype(np.float32)
        y = np.eye(self.classes, dtype=np.float32)[labels]
        return x.astype(np.float32), y


class SynthSequence:
    """BoolQ-like yes/no task over token sequences.

    The label is determined by which of two marker motifs appears in the
    sequence — learnable by a causal decoder attending over the sequence.
    """

    def __init__(self, vocab: int = 256, seq: int = 64, seed: int = 0):
        self.vocab = vocab
        self.seq = seq
        self.rng = np.random.default_rng(seed)
        self.motifs = self.rng.integers(1, vocab, (2, 4))

    def batch(self, n: int):
        labels = self.rng.integers(0, 2, n)
        x = self.rng.integers(0, self.vocab, (n, self.seq))
        pos = self.rng.integers(0, self.seq - 4, n)
        for j in range(n):
            x[j, pos[j]:pos[j] + 4] = self.motifs[labels[j]]
        y = np.eye(2, dtype=np.float32)[labels]
        return x.astype(np.float32), y

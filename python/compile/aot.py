"""AOT compiler: lower every model variant to HLO text + build the manifest.

This is the ONLY entry point that runs Python; after ``make artifacts`` the
rust binary is self-contained.  For each variant we emit:

* ``<name>.train.hlo.txt``  — (params, state, x, y1h, lr) -> (params', state', loss, acc)
* ``<name>.infer.hlo.txt``  — (params, x) -> logits
* ``<name>.params.f32``     — initial flat parameters (little-endian f32)
* ``<name>.state.f32``      — initial ASI warm-start state (WASI variants)

plus micro-kernel artifacts for the rust-side L1 benches, the per-layer
singular-value spectra (Fig. 3a), the Eq. 28 perplexity table for the
rust rank-selection DP, and ``manifest.json`` tying it all together.

HLO **text** is the interchange format (not ``.serialize()``): jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, synthdata, train, wasi
from .kernels import ref
from .kernels.lowrank_linear import lowrank_linear as pallas_lowrank_linear
from .kernels.subspace import power_step as pallas_power_step
from .model import (SwinLiteConfig, TinyDecConfig, ViTConfig, WasiSpec)

EPS_GRID = [0.4, 0.5, 0.6, 0.7, 0.8, 0.9]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_hlo(fn, example_args, path: str) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)


def write_f32(arr: np.ndarray, path: str) -> None:
    np.asarray(arr, np.float32).tofile(path)


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Build-time pretraining (the "ImageNet" stand-in, DESIGN.md §3)
# ---------------------------------------------------------------------------


def pretrain_vit(cfg: ViTConfig, steps: int, batch: int, seed: int = 7):
    """Brief supervised pretrain on a synthetic base task so fine-tuning
    starts from a genuinely trained (decaying-spectrum) model."""
    params = model.init_vit(cfg, seed=0)
    pspec = train.ParamSpec.from_params(params)
    sspec = train.empty_spec()
    step = jax.jit(train.make_train_step(model.vit_forward, cfg, None, pspec, sspec))
    data = synthdata.SynthVision(classes=cfg.classes, image=cfg.image, seed=seed)
    flat = pspec.pack(params)
    state = np.zeros(0, np.float32)
    loss = acc = None
    for i in range(steps):
        x, y = data.batch(batch)
        lr = 0.05 * 0.5 * (1 + np.cos(np.pi * i / steps))
        flat, state, loss, acc = step(flat, state, x, y, lr)
    print(f"  pretrain: {steps} steps, final loss {float(loss):.4f} acc {float(acc):.3f}")
    return pspec.unpack(np.asarray(flat)), float(loss), float(acc)


def pretrain_generic(forward, cfg, init_fn, data, steps: int, batch: int):
    params = init_fn(cfg, 0)
    pspec = train.ParamSpec.from_params(params)
    step = jax.jit(train.make_train_step(forward, cfg, None, pspec, train.empty_spec()))
    flat = pspec.pack(params)
    state = np.zeros(0, np.float32)
    loss = None
    for i in range(steps):
        x, y = data.batch(batch)
        lr = 0.05 * 0.5 * (1 + np.cos(np.pi * i / steps))
        flat, state, loss, acc = step(flat, state, x, y, lr)
    print(f"  pretrain: {steps} steps, final loss {float(loss):.4f}")
    return pspec.unpack(np.asarray(flat))


# ---------------------------------------------------------------------------
# Eq. 28 perplexity table (feeds the rust rank-selection DP)
# ---------------------------------------------------------------------------


def capture_dy(forward, params, cfg, x, y1h, plan):
    """Exact per-layer output gradients via zero probes (see model.linear)."""
    spec = WasiSpec(asi_ranks={n: () for n in plan}, capture=True)
    acts = train.capture_activations(forward, params, cfg, x, list(plan))
    probes = {f"{n}.__probe": jnp.zeros(acts[n].shape[:-1] + (
        np.shape(params[f"{n}.w"])[0],), jnp.float32) for n in plan}

    def loss_fn(pr):
        logits, _ = forward(params, x, cfg, spec, pr)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.sum(y1h * logp, axis=-1))

    grads = jax.grad(loss_fn)(probes)
    return acts, {n: np.asarray(grads[f"{n}.__probe"]) for n in plan}


def perplexity_table(acts, dys, plan, eps_grid):
    """P in R^{layers x E} + the rank tensor R^{layers x E x 3} (App. A.2)."""
    layers = sorted(plan.keys())
    table, ranks, mems = [], [], []
    for name in layers:
        row_p, row_r, row_m = [], [], []
        for eps in eps_grid:
            ppl, r, mem = wasi.perplexity_entry(acts[name], dys[name], eps)
            row_p.append(ppl)
            row_r.append(list(r))
            row_m.append(mem)
        table.append(row_p)
        ranks.append(row_r)
        mems.append(row_m)
    return {"layers": layers, "eps_grid": eps_grid, "perplexity": table,
            "ranks": ranks, "memory": mems}


# ---------------------------------------------------------------------------
# Variant emission
# ---------------------------------------------------------------------------


def emit_variant(out, name, forward, cfg, params, spec, state, batch,
                 input_dim, classes, extra=None, train_too=True):
    """Lower train+infer for one (model, spec) pair and write all files."""
    pspec = train.ParamSpec.from_params(params)
    sspec = train.ParamSpec.from_params(state) if state else train.empty_spec()

    files = {}
    t0 = time.time()
    if train_too:
        step = train.make_train_step(forward, cfg, spec, pspec, sspec)
        args = (sds((pspec.total,)), sds((sspec.total,)),
                sds((batch, input_dim)), sds((batch, classes)), sds(()))
        path = os.path.join(out, f"{name}.train.hlo.txt")
        write_hlo(step, args, path)
        files["train_hlo"] = os.path.basename(path)
    infer = train.make_infer_step(forward, cfg, spec, pspec)
    ipath = os.path.join(out, f"{name}.infer.hlo.txt")
    write_hlo(infer, (sds((pspec.total,)), sds((batch, input_dim))), ipath)
    files["infer_hlo"] = os.path.basename(ipath)

    write_f32(pspec.pack(params), os.path.join(out, f"{name}.params.f32"))
    files["params_file"] = f"{name}.params.f32"
    if state:
        write_f32(sspec.pack(state), os.path.join(out, f"{name}.state.f32"))
        files["state_file"] = f"{name}.state.f32"

    entry = {
        **files,
        "batch": batch,
        "input_dim": input_dim,
        "classes": classes,
        "params_len": pspec.total,
        "state_len": sspec.total,
        "param_spec": pspec.manifest(),
        "state_spec": sspec.manifest(),
    }
    if extra:
        entry.update(extra)
    print(f"  {name}: params={pspec.total} state={sspec.total} "
          f"({time.time() - t0:.1f}s)")
    return entry


def build_asi_variant(params, plan, eps, acts):
    """ASI-only baseline: dense weights + compressed backward residuals."""
    state, asi_ranks = train.init_asi_state(acts, plan, eps)
    spec = WasiSpec(asi_ranks=asi_ranks, asi_only=frozenset(plan.keys()))
    extra = {
        "eps": eps,
        "baseline": "asi",
        "asi_ranks": {k: list(v) for k, v in asi_ranks.items()},
        "layer_dims": {k: {"out_in": list(v[0]), "act": list(v[1])}
                       for k, v in plan.items()},
    }
    return dict(params), state, spec, extra


def build_svdllm_variant(params, plan, eps, acts, lora_rank=8):
    """SVD-LLM baseline at the compression ratio WASI reaches at ``eps``
    (App. B.1), with LoRA adapters (α=16, r=8)."""
    out = dict(params)
    ranks = {}
    rng = np.random.default_rng(99)
    for name in sorted(plan.keys()):
        w = np.asarray(params[f"{name}.w"])
        o, i = w.shape
        # WASI's ratio at this eps for this layer:
        _, _, s = wasi.svd_factorize(w, eps)
        k_wasi = wasi.select_rank(s, eps)
        ratio = (o * i) / max(1, k_wasi * (o + i))
        k = wasi.svdllm_rank_for_ratio(o, i, max(ratio, 1.0))
        x = np.asarray(acts[name]).sum(axis=0)  # (N, I) batch-summed
        wu, wv = wasi.svdllm_factorize(w, x, k)
        del out[f"{name}.w"]
        out[f"{name}.wu"] = wu
        out[f"{name}.wv"] = wv
        out[f"{name}.la"] = (rng.standard_normal((lora_rank, i)) /
                             np.sqrt(lora_rank)).astype(np.float32)
        out[f"{name}.lb"] = np.zeros((o, lora_rank), np.float32)
        ranks[name] = k
    spec = WasiSpec(svdllm=frozenset(plan.keys()))
    extra = {
        "eps": eps,
        "baseline": "svdllm",
        "weight_ranks": ranks,
        "layer_dims": {k: {"out_in": list(v[0]), "act": list(v[1])}
                       for k, v in plan.items()},
    }
    return out, {}, spec, extra


def activation_spectra(acts):
    """Per-mode singular-value spectra of each captured activation (Fig. 4)."""
    out = {}
    for name, x in acts.items():
        x = np.asarray(x)
        modes = []
        for m in range(x.ndim):
            a = np.moveaxis(x, m, 0).reshape(x.shape[m], -1)
            s = np.linalg.svd(a, compute_uv=False)
            modes.append([float(v) for v in s[:64]])
        out[name] = modes
    return out


def build_wasi_variant(forward, cfg, params, plan, eps, acts,
                       use_kernels=False, method="gs"):
    wp, weight_ranks, spectra = train.factorize_params(params, plan, eps)
    state, asi_ranks = train.init_asi_state(acts, plan, eps)
    spec = WasiSpec(weight_ranks=weight_ranks, asi_ranks=asi_ranks,
                    method=method, use_kernels=use_kernels)
    extra = {
        "eps": eps,
        "weight_ranks": weight_ranks,
        "asi_ranks": {k: list(v) for k, v in asi_ranks.items()},
        "layer_dims": {k: {"out_in": list(v[0]), "act": list(v[1])}
                       for k, v in plan.items()},
    }
    return wp, state, spec, extra, spectra


# ---------------------------------------------------------------------------
# Micro-kernel artifacts (rust-side L1 benches)
# ---------------------------------------------------------------------------


def emit_kernels(out, manifest, fast):
    b, n, i_dim, o_dim, k = 16, 65, 128, 512, 40
    rows = b * n

    def pallas_fwd(x, l, r):
        return (pallas_lowrank_linear(x, l, r),)

    def ref_fwd(x, l, r):
        return (ref.lowrank_linear(x, l, r),)

    def dense_fwd(x, w):
        return (x @ w.T,)

    shapes = (sds((b, n, i_dim)), sds((o_dim, k)), sds((k, i_dim)))
    write_hlo(pallas_fwd, shapes, os.path.join(out, "kernel.lowrank_pallas.hlo.txt"))
    write_hlo(ref_fwd, shapes, os.path.join(out, "kernel.lowrank_ref.hlo.txt"))
    write_hlo(dense_fwd, (sds((b, n, i_dim)), sds((o_dim, i_dim))),
              os.path.join(out, "kernel.dense.hlo.txt"))

    def pallas_power(a, u):
        return (pallas_power_step(a, u),)

    write_hlo(pallas_power, (sds((i_dim, rows)), sds((i_dim, 16))),
              os.path.join(out, "kernel.power_pallas.hlo.txt"))

    manifest["kernels"] = {
        "lowrank_pallas": {"hlo": "kernel.lowrank_pallas.hlo.txt",
                           "shapes": {"x": [b, n, i_dim], "l": [o_dim, k], "r": [k, i_dim]}},
        "lowrank_ref": {"hlo": "kernel.lowrank_ref.hlo.txt",
                        "shapes": {"x": [b, n, i_dim], "l": [o_dim, k], "r": [k, i_dim]}},
        "dense": {"hlo": "kernel.dense.hlo.txt",
                  "shapes": {"x": [b, n, i_dim], "w": [o_dim, i_dim]}},
        "power_pallas": {"hlo": "kernel.power_pallas.hlo.txt",
                         "shapes": {"a": [i_dim, rows], "u": [i_dim, 16]}},
    }


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="reduced variant set + short pretrain (CI)")
    ap.add_argument("--pretrain-steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    out = args.out
    os.makedirs(out, exist_ok=True)
    fast = args.fast
    t_start = time.time()

    vit_cfg = ViTConfig(dim=64, depth=2, heads=2) if fast else ViTConfig()
    pre_steps = args.pretrain_steps or (20 if fast else 250)
    batch = args.batch

    manifest = {"models": {}, "spectra": {}, "eps_grid": EPS_GRID,
                "vit_config": vit_cfg.__dict__ | {"tokens": vit_cfg.tokens}}

    # ---- ViT ------------------------------------------------------------
    print("[aot] pretraining ViT base model ...")
    vit_params, _, _ = pretrain_vit(vit_cfg, pre_steps, 32)
    plan = model.vit_wasi_layers(vit_cfg)

    calib = synthdata.SynthVision(classes=vit_cfg.classes, image=vit_cfg.image,
                                  seed=23)
    cx, cy = calib.batch(batch)
    acts, dys = capture_dy(model.vit_forward, vit_params, vit_cfg, cx, cy, plan)

    print("[aot] emitting ViT variants ...")
    manifest["models"]["vit_vanilla"] = emit_variant(
        out, "vit_vanilla", model.vit_forward, vit_cfg, vit_params, None, None,
        batch, vit_cfg.image ** 2 * 3, vit_cfg.classes)

    wasi_eps = [0.8] if fast else [0.4, 0.6, 0.8, 0.9]
    for eps in wasi_eps:
        wp, state, spec, extra, spectra = build_wasi_variant(
            model.vit_forward, vit_cfg, vit_params, plan, eps, acts)
        tag = f"vit_wasi_eps{int(round(eps * 100))}"
        manifest["models"][tag] = emit_variant(
            out, tag, model.vit_forward, vit_cfg, wp, spec, state,
            batch, vit_cfg.image ** 2 * 3, vit_cfg.classes, extra)
        if eps == 0.8:
            manifest["spectra"] = {k: [float(x) for x in v]
                                   for k, v in spectra.items()}

    # Baseline artifacts: ASI-only and SVD-LLM (for Fig. 5 / Tab. 2 rows).
    asi_eps = [0.8] if fast else [0.4, 0.6, 0.8, 0.9]
    for eps in asi_eps:
        wp, state, spec, extra = build_asi_variant(vit_params, plan, eps, acts)
        tag = f"vit_asi_eps{int(round(eps * 100))}"
        manifest["models"][tag] = emit_variant(
            out, tag, model.vit_forward, vit_cfg, wp, spec, state,
            batch, vit_cfg.image ** 2 * 3, vit_cfg.classes, extra)
    for eps in ([0.8] if fast else [0.4, 0.6, 0.8, 0.9]):
        wp, state, spec, extra = build_svdllm_variant(vit_params, plan, eps, acts)
        tag = f"vit_svdllm_eps{int(round(eps * 100))}"
        manifest["models"][tag] = emit_variant(
            out, tag, model.vit_forward, vit_cfg, wp, spec, state,
            batch, vit_cfg.image ** 2 * 3, vit_cfg.classes, extra)

    manifest["activation_spectra"] = activation_spectra(acts)

    if not fast:
        # Pallas-kernels-in-graph variant: proves the full L1->L2->L3 stack.
        wp, state, spec, extra, _ = build_wasi_variant(
            model.vit_forward, vit_cfg, vit_params, plan, 0.8, acts,
            use_kernels=True)
        extra["kernels_in_graph"] = True
        manifest["models"]["vit_wasi_kernel_eps80"] = emit_variant(
            out, "vit_wasi_kernel_eps80", model.vit_forward, vit_cfg, wp, spec,
            state, batch, vit_cfg.image ** 2 * 3, vit_cfg.classes, extra)

        # Attention+MLP variant (paper Tab. 1).
        plan_attn = model.vit_wasi_layers(vit_cfg, attn=True)
        acts_a, _ = capture_dy(model.vit_forward, vit_params, vit_cfg, cx, cy,
                               plan_attn)
        wp, state, spec, extra, _ = build_wasi_variant(
            model.vit_forward, vit_cfg, vit_params, plan_attn, 0.8, acts_a)
        extra["attn"] = True
        manifest["models"]["vit_wasi_attn_eps80"] = emit_variant(
            out, "vit_wasi_attn_eps80", model.vit_forward, vit_cfg, wp, spec,
            state, batch, vit_cfg.image ** 2 * 3, vit_cfg.classes, extra)

    # Eq. 28 perplexity table for the rust rank-selection DP.
    print("[aot] building perplexity table ...")
    manifest["perplexity"] = perplexity_table(acts, dys, plan, EPS_GRID)
    manifest["activation_dims"] = {n: list(np.shape(acts[n])) for n in plan}

    # ---- SwinLite (4D activations) --------------------------------------
    if not fast:
        swin_cfg = SwinLiteConfig()
        print("[aot] pretraining SwinLite ...")
        swin_data = synthdata.SynthVision(classes=swin_cfg.classes,
                                          image=swin_cfg.image, seed=11)
        swin_params = pretrain_generic(model.swinlite_forward, swin_cfg,
                                       model.init_swinlite, swin_data,
                                       pre_steps // 2, 32)
        splan = model.swinlite_wasi_layers(swin_cfg)
        sacts = train.capture_activations(model.swinlite_forward, swin_params,
                                          swin_cfg, swin_data.batch(batch)[0],
                                          list(splan))
        manifest["models"]["swinlite_vanilla"] = emit_variant(
            out, "swinlite_vanilla", model.swinlite_forward, swin_cfg,
            swin_params, None, None, batch, swin_cfg.image ** 2 * 3,
            swin_cfg.classes)
        for eps in [0.6, 0.8]:
            wp, state, spec, extra, _ = build_wasi_variant(
                model.swinlite_forward, swin_cfg, swin_params, splan, eps, sacts)
            tag = f"swinlite_wasi_eps{int(round(eps * 100))}"
            manifest["models"][tag] = emit_variant(
                out, tag, model.swinlite_forward, swin_cfg, wp, spec, state,
                batch, swin_cfg.image ** 2 * 3, swin_cfg.classes, extra)
        manifest["swin_config"] = {
            "image": swin_cfg.image, "patch": swin_cfg.patch,
            "dim": swin_cfg.dim, "depths": list(swin_cfg.depths),
            "window": swin_cfg.window, "classes": swin_cfg.classes}

    # ---- TinyDec (decoder-only, BoolQ-like) ------------------------------
    if not fast:
        dec_cfg = TinyDecConfig()
        print("[aot] pretraining TinyDec ...")
        dec_data = synthdata.SynthSequence(vocab=dec_cfg.vocab, seq=dec_cfg.seq,
                                           seed=13)
        dec_params = pretrain_generic(model.tinydec_forward, dec_cfg,
                                      model.init_tinydec, dec_data,
                                      pre_steps // 2, 32)
        dplan = model.tinydec_wasi_layers(dec_cfg)
        dacts = train.capture_activations(model.tinydec_forward, dec_params,
                                          dec_cfg, dec_data.batch(batch)[0],
                                          list(dplan))
        manifest["models"]["tinydec_vanilla"] = emit_variant(
            out, "tinydec_vanilla", model.tinydec_forward, dec_cfg, dec_params,
            None, None, batch, dec_cfg.seq, dec_cfg.classes)
        wp, state, spec, extra, _ = build_wasi_variant(
            model.tinydec_forward, dec_cfg, dec_params, dplan, 0.5, dacts)
        manifest["models"]["tinydec_wasi_eps50"] = emit_variant(
            out, "tinydec_wasi_eps50", model.tinydec_forward, dec_cfg, wp, spec,
            state, batch, dec_cfg.seq, dec_cfg.classes, extra)
        manifest["dec_config"] = {
            "vocab": dec_cfg.vocab, "seq": dec_cfg.seq, "dim": dec_cfg.dim,
            "depth": dec_cfg.depth, "classes": dec_cfg.classes}

    # ---- micro-kernels ----------------------------------------------------
    print("[aot] emitting kernel artifacts ...")
    emit_kernels(out, manifest, fast)

    manifest["build"] = {"fast": fast, "pretrain_steps": pre_steps,
                         "batch": batch,
                         "elapsed_s": round(time.time() - t_start, 1)}
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time() - t_start:.1f}s -> {out}/manifest.json")


if __name__ == "__main__":
    main()

"""Repo-root pytest config: make the build-time python package importable
when pytest is invoked as `pytest python/tests/` from the repository root
(the Makefile `cd python` path works either way)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))

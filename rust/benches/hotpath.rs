//! `cargo bench --bench hotpath` — microbenchmarks of every hot path:
//!
//! * native engine: dense vs WASI layer forward/backward at ViT-tiny and
//!   ViT-B dims (the per-layer numbers behind Tab. 2's shape);
//! * linalg substrate: matmul, Gram-Schmidt, Jacobi SVD, subspace step;
//! * PJRT path: compiled train/infer step, and the Pallas lowrank kernel
//!   artifact vs its jnp reference artifact vs dense (L1 comparison).

use wasi_train::bench::{bench, BenchResult};
use wasi_train::data::rng::Pcg64;
use wasi_train::linalg::matrix::Mat;
use wasi_train::linalg::qr::gram_schmidt;
use wasi_train::linalg::subspace::SubspaceState;
use wasi_train::linalg::svd::svd;
use wasi_train::linalg::tucker::Tensor;
use wasi_train::wasi::asi::AsiCompressor;
use wasi_train::wasi::layer::{DenseLayer, WasiLayer};
use wasi_train::wasi::wsi::{powerlaw_factored, WsiFactors};

fn native_layer_benches(results: &mut Vec<BenchResult>) {
    // ViT-tiny fc1 dims (the compiled artifact's shape) and a ViT-B-ish
    // fc1 at reduced batch to keep the bench under a second per sample.
    for (tag, b, n, i, o, k) in [
        ("tiny-fc1 (16x65x128->512)", 16usize, 65usize, 128usize, 512usize, 45usize),
        ("vitb-fc1 (8x197x768->3072)", 8, 197, 768, 3072, 164),
    ] {
        let dims = [b, n, i];
        let mut rng = Pcg64::new(1);
        let x = Tensor::from_vec(&dims, rng.normal_vec(b * n * i));
        // Exact truncated factors from the powerlaw construction (avoids a
        // large SVD in bench setup; K matches the ε=0.8 paper-scale rank).
        let (lmat, rmat, w) = powerlaw_factored(o, i, 0.8, 2, k);

        let mut dense = DenseLayer::new(w);
        results.push(bench(&format!("dense fwd+bwd {tag}"), 1.0, || {
            let y = dense.forward(&x);
            let dy = Tensor::from_vec(&y.shape, y.data.clone());
            let _ = dense.backward(&dy);
        }));

        let factors = WsiFactors { l: lmat.clone(), r: rmat.clone() };
        let ranks = [b.min(8), n.min(16), i.min(24)];
        let asi = AsiCompressor::new(&dims, &ranks, 3);
        let mut wasi = WasiLayer::new(factors, asi);
        results.push(bench(&format!("WASI fwd+bwd {tag} K={k}"), 1.0, || {
            let y = wasi.forward(&x);
            let dy = Tensor::from_vec(&y.shape, y.data.clone());
            let _ = wasi.backward(&dy);
        }));

        let mut wasi2 = WasiLayer::new(
            WsiFactors { l: lmat, r: rmat },
            AsiCompressor::new(&dims, &ranks, 3),
        );
        results.push(bench(&format!("WASI refresh-only {tag}"), 0.5, || {
            wasi2.factors.refresh();
        }));
    }
}

fn linalg_benches(results: &mut Vec<BenchResult>) {
    use wasi_train::util::threadpool::{num_threads, set_num_threads};

    let mut rng = Pcg64::new(7);
    let a256 = Mat::random(256, 256, &mut rng);
    let b256 = Mat::random(256, 256, &mut rng);
    results.push(bench("matmul 256x256x256", 1.0, || {
        let _ = a256.matmul(&b256);
    }));

    // Kernel-layer thread sweep (results are bit-identical across
    // counts — this measures the wall-clock win only).
    let a512 = Mat::random(512, 512, &mut rng);
    let b512 = Mat::random(512, 512, &mut rng);
    set_num_threads(1);
    results.push(bench("matmul 512x512x512 threads=1", 1.0, || {
        let _ = a512.matmul(&b512);
    }));
    set_num_threads(0);
    results.push(
        bench(&format!("matmul 512x512x512 threads=auto({})", num_threads()), 1.0, || {
            let _ = a512.matmul(&b512);
        }),
    );
    let tall = Mat::random(512, 32, &mut rng);
    results.push(bench("gram_schmidt 512x32", 0.5, || {
        let _ = gram_schmidt(&tall);
    }));
    let m = Mat::random(128, 96, &mut rng);
    results.push(bench("jacobi svd 128x96", 1.0, || {
        let _ = svd(&m);
    }));
    let unf = Mat::random(128, 1040, &mut rng);
    let mut st = SubspaceState::random(128, 16, &mut rng);
    results.push(bench("subspace step 128x1040 r=16", 0.5, || {
        st.step(&unf);
    }));

    // Ablation (DESIGN.md §Perf): Gram-Schmidt vs Newton-Schulz
    // orthogonalization at WSI-refresh shapes.  NS is matmul-bound
    // (MXU-friendly on real TPUs); GS is what Algorithm 1 specifies.
    let wide = Mat::random(512, 48, &mut rng);
    results.push(bench("orth ablation: GS 512x48", 0.5, || {
        let _ = gram_schmidt(&wide);
    }));
    results.push(bench("orth ablation: NS 512x48 (8 it)", 0.5, || {
        let _ = newton_schulz(&wide, 8);
    }));
}

/// Newton-Schulz orthogonalization (pure matmuls) — the perf-pass
/// alternative to GS; mirrors python/compile/ops.py::orthogonalize_ns.
fn newton_schulz(a: &Mat, steps: usize) -> Mat {
    let norm1 = (0..a.cols)
        .map(|j| a.col_view(j).iter().map(|x| x.abs()).sum::<f32>())
        .fold(0.0f32, f32::max);
    let norminf = (0..a.rows)
        .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
        .fold(0.0f32, f32::max);
    let mut y = a.clone();
    y.scale(1.0 / (norm1 * norminf).sqrt().max(1e-12));
    let eye = Mat::eye(a.cols);
    for _ in 0..steps {
        let mut g = y.matmul_tn(&y);
        g.scale(-0.5);
        let mut m = eye.clone();
        m.scale(1.5);
        m.add_assign(&g);
        y = y.matmul(&m);
    }
    y
}

fn pjrt_benches(results: &mut Vec<BenchResult>) {
    let artifacts = std::env::var("WASI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("hotpath: artifacts not built; skipping PJRT benches");
        return;
    }
    let rt = match wasi_train::runtime::Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("hotpath: no PJRT client: {e:#}");
            return;
        }
    };
    let manifest = wasi_train::runtime::Manifest::load(&artifacts).unwrap();

    // L1 kernel microbench: pallas lowrank vs jnp reference vs dense.
    let mut rng = Pcg64::new(11);
    for kname in ["lowrank_pallas", "lowrank_ref", "dense", "power_pallas"] {
        let Some(entry) = manifest.kernels.get(kname) else { continue };
        let exe = match rt.load(&entry.hlo) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("hotpath: {kname}: {e:#}");
                continue;
            }
        };
        let inputs: Vec<(Vec<f32>, Vec<usize>)> = entry
            .shapes
            .values()
            .map(|shape| {
                let n: usize = shape.iter().product();
                (rng.normal_vec(n), shape.clone())
            })
            .collect();
        let refs: Vec<(&[f32], &[usize])> = inputs
            .iter()
            .map(|(d, s)| (d.as_slice(), s.as_slice()))
            .collect();
        // warmup/compile
        let _ = exe.run_f32(&refs);
        results.push(bench(&format!("PJRT kernel {kname}"), 1.0, || {
            let _ = exe.run_f32(&refs);
        }));
    }

    // End-to-end compiled steps.
    for name in ["vit_wasi_eps80", "vit_vanilla"] {
        let Ok(entry) = manifest.model(name) else { continue };
        let mut step = match wasi_train::runtime::TrainStep::load(&rt, entry) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("hotpath: {name}: {e:#}");
                continue;
            }
        };
        let mut task = wasi_train::data::synth::VisionTask::new(
            "bench", entry.classes, 32, 0.7, 8, 1);
        let (x, y, _) = task.batch_onehot(entry.batch);
        let _ = step.step(&x, &y, 0.01); // warmup
        results.push(bench(&format!("PJRT train step {name}"), 2.0, || {
            let _ = step.step(&x, &y, 0.01);
        }));
    }
}

fn main() {
    // WASI_BENCH_ONLY=native|linalg|pjrt narrows the run (perf iteration).
    let only = std::env::var("WASI_BENCH_ONLY").unwrap_or_default();
    let want = |s: &str| only.is_empty() || only == s;
    let mut results = Vec::new();
    if want("native") {
        native_layer_benches(&mut results);
    }
    if want("linalg") {
        linalg_benches(&mut results);
    }
    if want("pjrt") {
        pjrt_benches(&mut results);
    }
    println!("\n=== hotpath bench summary ===");
    for r in &results {
        println!("{}", r.report());
    }
}

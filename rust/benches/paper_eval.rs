//! `cargo bench --bench paper_eval` — regenerates EVERY table and figure
//! of the paper's evaluation section through the eval harness (quick
//! settings; use the `wasi-train eval` CLI with --steps for full runs).
//!
//! Custom harness (no criterion in the vendored crate set): each exhibit
//! is timed once end-to-end and its report is printed.

use wasi_train::bench::bench_once;
use wasi_train::eval::{self, EvalCtx};

fn main() {
    let artifacts = std::env::var("WASI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("paper_eval: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    let steps = std::env::var("WASI_EVAL_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let ctx = match EvalCtx::open(&artifacts, "eval_out", steps, true) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("paper_eval: cannot open session: {e:#}");
            return;
        }
    };
    let mut results = Vec::new();
    for name in eval::EXHIBITS {
        let mut body = String::new();
        let r = bench_once(name, || {
            body = eval::run(&ctx, name).unwrap_or_else(|e| format!("ERROR: {e:#}\n"));
        });
        println!("\n################ {name} ({:.1}s) ################", r.median_s);
        println!("{body}");
        results.push(r);
    }
    println!("\n=== paper_eval timing summary ===");
    for r in &results {
        println!("{}", r.report());
    }
}

//! The precision subsystem (DESIGN.md §Precision): numeric storage
//! formats for weights, selected per run via `--precision` /
//! [`crate::coordinator::FinetuneConfig`].
//!
//! Three formats exist.  **f32** is the reference everything else is
//! measured against.  **bf16** truncates weight storage to bfloat16
//! (8-bit exponent, 7-bit mantissa — f32's dynamic range at half the
//! bytes); training keeps f32 compute but rounds the stored parameter
//! vector to bf16 values after every optimizer step, so the trajectory
//! is exactly what a 2-byte weight store would produce.  **i8** is
//! per-tensor symmetric int8 quantization for inference only: each 2-D
//! GEMM weight tensor stores `round(w / s)` with one scale
//! `s = max|w| / 127`, activations quantize per-row at GEMM entry
//! ([`quantize_i8_rows`]), and the kernel layer runs true-integer
//! i8×i8→i32 dots with both scales applied once per output in the
//! epilogue (`linalg::kernels::gemm_nt_i8`).
//!
//! Legality matrix (enforced by `engine::train_engine_with` and
//! `serve::pool`): training {f32, bf16}; inference {f32, bf16, i8};
//! the HLO engine is f32-only — reduced precision requires the native
//! engine, whose flat vectors this module rewrites.

use std::str::FromStr;

use anyhow::{anyhow, Result};

/// Weight storage format for one run (CLI `--precision f32|bf16|i8`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Precision {
    /// IEEE single precision — the reference format.
    #[default]
    F32,
    /// bfloat16 weight storage, f32 compute (training + inference).
    Bf16,
    /// Per-tensor symmetric int8 weights (inference only).
    I8,
}

impl Precision {
    /// Bytes one stored weight element occupies in this format.
    pub fn bytes_per_elem(self) -> f64 {
        match self {
            Precision::F32 => 4.0,
            Precision::Bf16 => 2.0,
            Precision::I8 => 1.0,
        }
    }

    /// Whether the native train engine can store weights in this
    /// format (int8 is inference-only: SGD updates underflow a 1-byte
    /// grid long before the paper's LR schedule ends).
    pub fn trainable(self) -> bool {
        !matches!(self, Precision::I8)
    }
}

impl FromStr for Precision {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Precision> {
        match s {
            "f32" => Ok(Precision::F32),
            "bf16" => Ok(Precision::Bf16),
            "i8" | "int8" => Ok(Precision::I8),
            other => Err(anyhow!("unknown precision {other:?}; expected f32, bf16, or i8")),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::I8 => "i8",
        })
    }
}

// ---------------------------------------------------------------------------
// bfloat16
// ---------------------------------------------------------------------------

/// f32 → bf16 bits with round-to-nearest-even (the hardware rounding
/// mode); NaN is canonicalized so it stays NaN after truncation.
pub fn f32_to_bf16(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        // Keep the sign, force a quiet-NaN mantissa bit that survives
        // the truncation.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7fff + ((bits >> 16) & 1);
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// bf16 bits → the exactly-representable f32.
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round every element to its nearest bf16 value, in place (bf16
/// weight storage for the native train engine: values live in the f32
/// vector but are exactly representable in 2 bytes).
pub fn round_bf16_inplace(data: &mut [f32]) {
    for v in data.iter_mut() {
        *v = bf16_to_f32(f32_to_bf16(*v));
    }
}

/// Pack a slice to bf16 bits (compact inference weight storage).
pub fn pack_bf16(data: &[f32]) -> Vec<u16> {
    data.iter().map(|&v| f32_to_bf16(v)).collect()
}

// ---------------------------------------------------------------------------
// int8 per-tensor symmetric quantization
// ---------------------------------------------------------------------------

/// Per-tensor symmetric int8 quantization: `q = round(v / scale)`
/// clamped to `[-127, 127]`, `scale = max|v| / 127` (1.0 for an
/// all-zero tensor so dequantization stays exact).  Round-trip error is
/// bounded by `scale / 2` per element.
pub fn quantize_i8(data: &[f32]) -> (Vec<i8>, f32) {
    let maxabs = data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
    let q = data
        .iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (q, scale)
}

/// Dequantize int8 values back to f32 (`q * scale`).
pub fn dequantize_i8(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// Per-ROW symmetric int8 quantization of an `(rows x cols)` row-major
/// matrix: row `r` stores `round(v / s_r)` clamped to `[-127, 127]`
/// with its own `s_r = max|row| / 127` (1.0 for an all-zero row).
///
/// This is the *activation* quantizer for the true-integer GEMM
/// (`linalg::kernels::gemm_nt_i8`): activations vary wildly per sample,
/// so one tensor-wide scale would crush quiet rows to zero; one scale
/// per row keeps the `scale/2` round-trip bound local to each row
/// while the weight side keeps its per-tensor scale.
pub fn quantize_i8_rows(data: &[f32], rows: usize, cols: usize) -> (Vec<i8>, Vec<f32>) {
    debug_assert_eq!(data.len(), rows * cols);
    let mut q = vec![0i8; rows * cols];
    let mut scales = vec![1.0f32; rows];
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        let maxabs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
        scales[r] = scale;
        for (dst, &v) in q[r * cols..(r + 1) * cols].iter_mut().zip(row) {
            *dst = (v / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scales)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    #[test]
    fn precision_parses_and_prints() {
        assert_eq!("f32".parse::<Precision>().unwrap(), Precision::F32);
        assert_eq!("bf16".parse::<Precision>().unwrap(), Precision::Bf16);
        assert_eq!("i8".parse::<Precision>().unwrap(), Precision::I8);
        assert_eq!("int8".parse::<Precision>().unwrap(), Precision::I8);
        assert!("fp64".parse::<Precision>().is_err());
        for p in [Precision::F32, Precision::Bf16, Precision::I8] {
            assert_eq!(p.to_string().parse::<Precision>().unwrap(), p);
        }
        assert!(Precision::Bf16.trainable());
        assert!(!Precision::I8.trainable());
    }

    #[test]
    fn bf16_round_trip_is_within_relative_bound() {
        // 8 mantissa bits (7 stored + implicit) => relative error of
        // round-to-nearest is at most 2^-8 for normal values.
        let mut rng = Pcg64::new(5);
        let data: Vec<f32> = rng.normal_vec(4096);
        for &v in &data {
            let r = bf16_to_f32(f32_to_bf16(v));
            assert!(
                (r - v).abs() <= v.abs() / 256.0 + 1e-30,
                "{v} -> {r} exceeds the bf16 rounding bound"
            );
        }
    }

    #[test]
    fn bf16_exact_values_round_trip_exactly() {
        for v in [0.0f32, -0.0, 1.0, -2.0, 0.5, 1.5, 256.0, f32::INFINITY] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)).to_bits(), v.to_bits(), "{v}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // Round-to-nearest-even: 1 + 2^-8 is exactly between two bf16
        // values and must round to the even mantissa (1.0).
        let tie = 1.0f32 + 1.0 / 256.0;
        assert_eq!(bf16_to_f32(f32_to_bf16(tie)), 1.0);
        // Idempotence: a rounded value is a fixed point.
        let mut data: Vec<f32> = Pcg64::new(6).normal_vec(128);
        round_bf16_inplace(&mut data);
        let again: Vec<f32> = {
            let mut d = data.clone();
            round_bf16_inplace(&mut d);
            d
        };
        assert_eq!(data, again);
    }

    #[test]
    fn i8_round_trip_is_within_half_scale() {
        let mut rng = Pcg64::new(7);
        let data: Vec<f32> = rng.normal_vec(2048);
        let (q, scale) = quantize_i8(&data);
        let deq = dequantize_i8(&q, scale);
        let maxabs = data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!((scale - maxabs / 127.0).abs() < 1e-12);
        for (v, d) in data.iter().zip(&deq) {
            assert!(
                (v - d).abs() <= scale * 0.5 + 1e-6,
                "{v} -> {d} exceeds scale/2 = {}",
                scale * 0.5
            );
        }
    }

    #[test]
    fn i8_zero_tensor_quantizes_exactly() {
        let (q, scale) = quantize_i8(&[0.0; 16]);
        assert_eq!(scale, 1.0);
        assert!(q.iter().all(|&v| v == 0));
        assert_eq!(dequantize_i8(&q, scale), vec![0.0f32; 16]);
    }

    #[test]
    fn i8_row_quantization_bounds_each_row_independently() {
        let mut rng = Pcg64::new(17);
        let (rows, cols) = (5, 37);
        let mut data: Vec<f32> = rng.normal_vec(rows * cols);
        // One loud row and one all-zero row: per-tensor scaling would
        // crush the others; per-row scaling must keep every row within
        // its OWN scale/2 bound.
        for v in data[cols..2 * cols].iter_mut() {
            *v *= 1000.0;
        }
        for v in data[3 * cols..4 * cols].iter_mut() {
            *v = 0.0;
        }
        let (q, scales) = quantize_i8_rows(&data, rows, cols);
        assert_eq!(scales.len(), rows);
        assert_eq!(scales[3], 1.0, "all-zero row pins scale to 1.0");
        for r in 0..rows {
            let row = &data[r * cols..(r + 1) * cols];
            let maxabs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            if maxabs > 0.0 {
                assert!((scales[r] - maxabs / 127.0).abs() <= 1e-12 * maxabs.max(1.0));
            }
            for (x, &qq) in row.iter().zip(&q[r * cols..(r + 1) * cols]) {
                let back = f32::from(qq) * scales[r];
                assert!(
                    (x - back).abs() <= scales[r] * 0.5 + 1e-6,
                    "row {r}: {x} -> {back} exceeds scale/2 = {}",
                    scales[r] * 0.5
                );
            }
        }
        // Matches the per-tensor quantizer when the matrix is one row.
        let (q1, s1) = quantize_i8(&data[..cols]);
        let (qr, sr) = quantize_i8_rows(&data[..cols], 1, cols);
        assert_eq!(q1, qr);
        assert_eq!(s1, sr[0]);
    }
}

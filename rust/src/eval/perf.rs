//! `wasi-train bench` — the perf-trajectory harness.
//!
//! Times the zero-dependency demo→train→infer pipeline on both engine
//! kinds (the HLO engine is recorded as unavailable with its reason
//! when no backend can execute model HLO — the demo set ships no train
//! artifact on purpose), sweeps 1 vs N kernel-layer threads, measures
//! the SIMD microkernels against the forced-scalar backend, times the
//! {f32, bf16, i8} inference precisions (latency, weight bytes, top-1
//! agreement with f32), pages a Zipf population of per-user subspace
//! deltas through the variant store (compression, hit rate,
//! evict→reload latency + bit-identity), drives the socket front-end
//! at 10/100/1000 in-flight clients (solo vs micro-batched — the
//! batched/solo throughput ratio joins the gate), and emits the
//! machine-readable
//! `BENCH_native.json` that feeds the repo's perf record
//! (EXPERIMENTS.md §Perf) and the CI `bench-gate` comparison against
//! the committed `BENCH_baseline.json`.  Kernels are bit-deterministic
//! across thread counts AND SIMD backends, so both sweeps measure
//! wall-clock only.

use std::collections::HashMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::FinetuneConfig;
use crate::data::synth::VisionTask;
use crate::engine::demo::{write_demo_artifacts, DemoConfig};
use crate::engine::{
    train_engine, EngineKind, InferEngine, NativeInferEngine, NativeModelEngine, TrainEngine,
};
use crate::linalg::simd;
use crate::net::{read_frame, serve_listener, write_frame, NetConfig, MAX_FRAME_BYTES};
use crate::precision::Precision;
use crate::runtime::{Manifest, ModelEntry, Runtime};
use crate::serve::{InferRequest, JobSpec, Service, ServiceConfig};
use crate::scenario::{run_soak, SoakConfig};
use crate::util::json::{arr, finite_num, num, obj, str as jstr, Json};
use crate::util::stats::percentile;
use crate::util::table::Table;
use crate::util::threadpool::{num_threads, set_num_threads, thread_override};

/// Bench configuration (`wasi-train bench [--quick] [--steps N]
/// [--out FILE]`).
pub struct BenchConfig {
    pub quick: bool,
    pub steps: usize,
    pub out: PathBuf,
}

/// One thread arm's measurements.
struct Arm {
    threads: usize,
    train_s: f64,
    mean_step_ms: f64,
    infer_s: f64,
    infer_reps: usize,
}

fn bench_demo_config(quick: bool) -> DemoConfig {
    if quick {
        DemoConfig::default()
    } else {
        // Larger than the test fixture so the thread sweep has real
        // GEMM panels to win on (rows = batch · tokens = 592).
        DemoConfig {
            image: 24,
            patch: 4,
            dim: 64,
            depth: 3,
            mlp_ratio: 2,
            classes: 10,
            batch: 16,
            eps: 0.8,
            seed: 41,
        }
    }
}

fn run_native_arm(
    entry: &ModelEntry,
    threads: usize,
    steps: usize,
    infer_reps: usize,
) -> Result<Arm> {
    set_num_threads(threads);
    let mut eng = NativeModelEngine::load(entry)?;
    let side = entry
        .image_side()
        .ok_or_else(|| anyhow::anyhow!("bench model is not an image model"))?;
    let mut task = VisionTask::new("bench", entry.classes, side, 0.7, 8, 233);
    let (x, y, _) = task.batch_onehot(entry.batch);
    eng.step(&x, &y, 0.01)?; // warmup
    let t0 = Instant::now();
    for _ in 0..steps {
        eng.step(&x, &y, 0.01)?;
    }
    let train_s = t0.elapsed().as_secs_f64();

    let infer = NativeInferEngine::load(entry)?;
    infer.infer(eng.params(), &x)?; // warmup
    let t1 = Instant::now();
    for _ in 0..infer_reps {
        infer.infer(eng.params(), &x)?;
    }
    let infer_s = t1.elapsed().as_secs_f64();
    Ok(Arm {
        threads,
        train_s,
        mean_step_ms: train_s / steps as f64 * 1e3,
        infer_s,
        infer_reps,
    })
}

fn arm_json(a: &Arm) -> Json {
    obj(vec![
        ("threads", num(a.threads as f64)),
        ("train_seconds", num(a.train_s)),
        ("mean_step_ms", num(a.mean_step_ms)),
        ("infer_seconds", num(a.infer_s)),
        ("infer_reps", num(a.infer_reps as f64)),
    ])
}

/// SIMD-vs-scalar arms at the auto thread count: the same
/// train-and-infer workload with the kernel layer pinned to the scalar
/// backend, then on the detected ISA.  Results are bit-identical (the
/// parity pin), so this measures wall-clock only.
fn bench_simd(entry: &ModelEntry, steps: usize, infer_reps: usize) -> Result<(Json, f64)> {
    set_num_threads(0);
    let auto = num_threads();
    simd::set_force_scalar(true);
    let scalar = run_native_arm(entry, auto, steps, infer_reps);
    simd::set_force_scalar(false);
    let scalar = scalar?;
    let vector = run_native_arm(entry, auto, steps, infer_reps)?;
    let train_speedup = scalar.train_s / vector.train_s;
    let infer_speedup = scalar.infer_s / vector.infer_s;
    let json = obj(vec![
        ("isa", jstr(simd::isa_name())),
        ("scalar", arm_json(&scalar)),
        ("simd", arm_json(&vector)),
        ("train_speedup", num(train_speedup)),
        ("infer_speedup", num(infer_speedup)),
    ]);
    Ok((json, train_speedup))
}

/// One precision arm's measurements.
struct PrecArm {
    precision: Precision,
    infer_s: f64,
    infer_reps: usize,
    weight_bytes: usize,
    /// Fraction of top-1 predictions matching the f32 engine.
    top1_agreement: f64,
}

/// Time inference at each weight-storage precision over the demo
/// artifact and record weight bytes + top-1 agreement against f32.
fn bench_precision(entry: &ModelEntry, infer_reps: usize) -> Result<Vec<PrecArm>> {
    set_num_threads(0);
    let f32_engine = NativeInferEngine::load(entry)?;
    let params = entry.load_params()?;
    let side = entry
        .image_side()
        .ok_or_else(|| anyhow::anyhow!("bench model is not an image model"))?;
    let mut task = VisionTask::new("prec", entry.classes, side, 0.7, 8, 77);
    let (x, _, _) = task.batch_onehot(entry.batch);
    let f32_preds = f32_engine.predict(&params, &x)?;

    let mut arms = Vec::new();
    for precision in [Precision::F32, Precision::Bf16, Precision::I8] {
        let (infer_s, preds, weight_bytes) = if precision == Precision::F32 {
            f32_engine.infer(&params, &x)?; // warmup
            let t0 = Instant::now();
            for _ in 0..infer_reps {
                f32_engine.infer(&params, &x)?;
            }
            (t0.elapsed().as_secs_f64(), f32_preds.clone(), entry.params_len * 4)
        } else {
            let eng = NativeInferEngine::load_quantized(entry, precision)?;
            eng.infer_quantized(&x)?; // warmup
            let t0 = Instant::now();
            for _ in 0..infer_reps {
                eng.infer_quantized(&x)?;
            }
            let dt = t0.elapsed().as_secs_f64();
            let logits = eng.infer_quantized(&x)?;
            let preds = crate::engine::ops::argmax_rows(&logits, entry.classes);
            (dt, preds, eng.packed_bytes().unwrap_or(entry.params_len * 4))
        };
        let agree = preds.iter().zip(&f32_preds).filter(|(a, b)| a == b).count();
        arms.push(PrecArm {
            precision,
            infer_s,
            infer_reps,
            weight_bytes,
            top1_agreement: agree as f64 / f32_preds.len().max(1) as f64,
        });
    }
    Ok(arms)
}

/// Batched-GEMM amortization: per-request latency of solo (batch=1)
/// inference vs an 8-request coalesced batch, for f32 and the
/// true-integer int8 path.  The 4-row microtiles in `linalg::kernels`
/// walk each weight panel once per row group instead of once per
/// request, so the coalesced arm should win per request (`>= 1.0`
/// speedups — gated by scripts/bench_gate.py once the baseline is
/// armed).  Both arms see the same total sample count: the solo arm
/// runs `8 * reps` single-sample calls against the batched arm's
/// `reps` eight-sample calls.
fn bench_batched(entry: &ModelEntry, infer_reps: usize) -> Result<(Json, f64, f64)> {
    set_num_threads(0);
    const BATCH: usize = 8;
    let side = entry
        .image_side()
        .ok_or_else(|| anyhow::anyhow!("bench model is not an image model"))?;
    let mut task = VisionTask::new("batched", entry.classes, side, 0.7, 8, 91);
    let (xb, _, _) = task.batch_onehot(BATCH);
    let sample = xb.len() / BATCH;
    let x1 = xb[..sample].to_vec();
    let per_req = |total: f64| total / (infer_reps * BATCH) as f64;

    let f32_engine = NativeInferEngine::load(entry)?;
    let params = entry.load_params()?;
    f32_engine.infer(&params, &x1)?; // warmup
    let t0 = Instant::now();
    for _ in 0..infer_reps * BATCH {
        f32_engine.infer(&params, &x1)?;
    }
    let f32_solo = per_req(t0.elapsed().as_secs_f64());
    f32_engine.infer(&params, &xb)?; // warmup
    let t0 = Instant::now();
    for _ in 0..infer_reps {
        f32_engine.infer(&params, &xb)?;
    }
    let f32_batch = per_req(t0.elapsed().as_secs_f64());

    let i8_engine = NativeInferEngine::load_quantized(entry, Precision::I8)?;
    i8_engine.infer_quantized(&x1)?; // warmup
    let t0 = Instant::now();
    for _ in 0..infer_reps * BATCH {
        i8_engine.infer_quantized(&x1)?;
    }
    let i8_solo = per_req(t0.elapsed().as_secs_f64());
    i8_engine.infer_quantized(&xb)?; // warmup
    let t0 = Instant::now();
    for _ in 0..infer_reps {
        i8_engine.infer_quantized(&xb)?;
    }
    let i8_batch = per_req(t0.elapsed().as_secs_f64());

    let f32_speedup = f32_solo / f32_batch;
    let i8_speedup = i8_solo / i8_batch;
    let json = obj(vec![
        ("batch", num(BATCH as f64)),
        ("f32_solo_per_req_seconds", num(f32_solo)),
        ("f32_batch_per_req_seconds", num(f32_batch)),
        ("f32_batch_per_req_speedup", num(f32_speedup)),
        ("i8_solo_per_req_seconds", num(i8_solo)),
        ("i8_batch_per_req_seconds", num(i8_batch)),
        ("i8_batch_per_req_speedup", num(i8_speedup)),
    ]);
    Ok((json, f32_speedup, i8_speedup))
}

/// One serve arm: J jobs through a service with W workers.
struct ServeArm {
    workers: usize,
    jobs: usize,
    steps_per_job: usize,
    total_s: f64,
    jobs_per_sec: f64,
    p50_s: f64,
    p95_s: f64,
}

/// Bench the job service: submit `jobs` jobs (alternating variants so
/// concurrent workers train distinct models) and measure per-job
/// submit→done latency plus aggregate throughput, at 1 worker
/// (sequential floor) vs `max_workers`.
fn bench_serve(dir: &Path, models: &[String], quick: bool) -> Result<Vec<ServeArm>> {
    let steps = if quick { 3 } else { 8 };
    let jobs = if quick { 2 } else { 4 };
    let max_workers = num_threads().clamp(1, 4);
    let mut worker_arms = vec![1usize];
    if max_workers > 1 {
        worker_arms.push(max_workers);
    }
    let mut arms = Vec::new();
    for workers in worker_arms {
        let service = Service::start(ServiceConfig::new(dir.to_path_buf()).with_workers(workers))?;
        let t0 = Instant::now();
        let submitted: Vec<_> = (0..jobs)
            .map(|j| {
                let cfg = FinetuneConfig::builder()
                    .model(&models[j % models.len()])
                    .samples(32)
                    .steps(steps)
                    .seed(233 + j as u64)
                    .engine(EngineKind::Native)
                    .build();
                Ok((service.submit(JobSpec::new(cfg))?, Instant::now()))
            })
            .collect::<Result<_>>()?;
        // One watcher per job records its exact submit→done latency.
        let latencies: Vec<f64> = std::thread::scope(|s| {
            let service = &service;
            let handles: Vec<_> = submitted
                .iter()
                .map(|(id, at)| {
                    s.spawn(move || service.wait(*id).map(|_| at.elapsed().as_secs_f64()))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("watcher thread"))
                .collect::<Result<_>>()
        })?;
        let total_s = t0.elapsed().as_secs_f64();
        service.shutdown();
        arms.push(ServeArm {
            workers,
            jobs,
            steps_per_job: steps,
            total_s,
            jobs_per_sec: jobs as f64 / total_s,
            p50_s: percentile(&latencies, 50.0),
            p95_s: percentile(&latencies, 95.0),
        });
    }
    Ok(arms)
}

/// One high-concurrency socket arm's measurements.
struct NetArm {
    inflight: usize,
    mode: &'static str,
    requests: usize,
    connections: usize,
    total_s: f64,
    p50_ms: f64,
    p99_ms: f64,
}

/// One bench client: a pipelined framed connection holding `depth`
/// requests in flight, matching responses back to their send times by
/// the framing-layer id (responses may return out of order — the
/// dispatcher pool makes no ordering promise across requests).
fn run_net_client(
    addr: SocketAddr,
    model: &str,
    count: usize,
    depth: usize,
    seed0: u64,
) -> Result<Vec<f64>> {
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut sent_at: HashMap<usize, Instant> = HashMap::new();
    let mut latencies = Vec::with_capacity(count);
    let mut next = 0usize;
    while latencies.len() < count {
        while next < count && sent_at.len() < depth {
            // Seeds vary per request (distinct synthetic inputs); the
            // batch key deliberately ignores them, so concurrent
            // requests stay coalescible in the batched arm.
            let line = obj(vec![
                ("cmd", jstr("infer")),
                ("model", jstr(model.to_string())),
                ("engine", jstr("native")),
                ("seed", num((seed0 + next as u64) as f64)),
                ("id", num(next as f64)),
            ])
            .to_string();
            write_frame(&mut writer, line.as_bytes())?;
            sent_at.insert(next, Instant::now());
            next += 1;
        }
        let payload = read_frame(&mut reader, MAX_FRAME_BYTES)?
            .ok_or_else(|| anyhow!("server closed mid-bench"))?;
        let text = String::from_utf8_lossy(&payload);
        let resp = Json::parse(text.trim()).map_err(|e| anyhow!("bad bench response: {e}"))?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            return Err(anyhow!("bench infer failed: {}", resp.to_string()));
        }
        let id = resp
            .get("id")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("bench response without an id"))?;
        let t0 = sent_at
            .remove(&id)
            .ok_or_else(|| anyhow!("bench response for unknown id {id}"))?;
        latencies.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(latencies)
}

/// Socket front-end bench (DESIGN.md §Network front-end): p50/p99
/// infer latency and aggregate throughput at 10/100/1000 in-flight
/// over real loopback connections, solo (batching disabled) vs
/// micro-batched, both front-ends over ONE shared single-worker
/// service so the arms differ only in coalescing.  Batching is
/// bit-identical to solo serving (tests/net.rs), so the arms measure
/// wall-clock only; the batched/solo throughput ratio at 100 in-flight
/// joins the gate.
fn bench_net(dir: &Path, model: &str, quick: bool) -> Result<(Json, String)> {
    set_num_threads(0);
    let svc = Arc::new(Service::start(ServiceConfig::new(dir.to_path_buf()).with_workers(1))?);
    // Warm the pool so every arm measures serving, not the first load.
    let warm = InferRequest {
        model: model.to_string(),
        engine: EngineKind::Native,
        precision: Precision::F32,
        seed: 1,
        x: None,
    };
    svc.infer(None, &warm, None)?;

    let levels: [usize; 3] = [10, 100, 1000];
    let mut arms: Vec<NetArm> = Vec::new();
    let mut batched = (0u64, 0u64);
    for (mode, window_us, max_batch) in [("solo", 0u64, 1usize), ("batched", 400, 32)] {
        let net_cfg = NetConfig {
            listen: "127.0.0.1:0".into(),
            max_inflight: 4096,
            queue_cap: 8192,
            batch_window_us: window_us,
            max_batch,
            // One dispatcher per potential window-mate: batch size is
            // bounded by concurrent batcher entrants.
            dispatchers: 64,
        };
        let mut handle = serve_listener(svc.clone(), net_cfg)?;
        let addr = handle.addr();
        for &level in &levels {
            let requests =
                if quick { (level * 2).clamp(60, 1200) } else { (level * 4).clamp(200, 4000) };
            let conns = level.min(20);
            let depth = level.div_ceil(conns);
            let t0 = Instant::now();
            let latencies: Vec<f64> = std::thread::scope(|s| {
                let clients: Vec<_> = (0..conns)
                    .map(|c| {
                        let count = requests / conns + usize::from(c < requests % conns);
                        let seed0 = 1000 + (c as u64) * 10_000;
                        s.spawn(move || run_net_client(addr, model, count, depth, seed0))
                    })
                    .collect();
                clients
                    .into_iter()
                    .map(|h| h.join().expect("bench client thread"))
                    .collect::<Result<Vec<Vec<f64>>>>()
                    .map(|v| v.into_iter().flatten().collect())
            })?;
            let total_s = t0.elapsed().as_secs_f64();
            arms.push(NetArm {
                inflight: level,
                mode,
                requests,
                connections: conns,
                total_s,
                p50_ms: percentile(&latencies, 50.0),
                p99_ms: percentile(&latencies, 99.0),
            });
        }
        if mode == "batched" {
            let stats = handle.stats();
            batched = (stats.batches(), stats.infer_batched());
        }
        handle.shutdown();
    }
    svc.shutdown();

    let rate = |mode: &str| {
        let a = arms
            .iter()
            .find(|a| a.mode == mode && a.inflight == 100)
            .expect("both modes run the 100-in-flight level");
        a.requests as f64 / a.total_s
    };
    let ratio = rate("batched") / rate("solo");
    let (batches, batched_requests) = batched;
    let mean_batch = batched_requests as f64 / (batches as f64).max(1.0);
    let json = obj(vec![
        ("model", jstr(model.to_string())),
        ("workers", num(1.0)),
        ("dispatchers", num(64.0)),
        (
            "arms",
            arr(arms.iter().map(|a| {
                obj(vec![
                    ("inflight", num(a.inflight as f64)),
                    ("mode", jstr(a.mode)),
                    ("requests", num(a.requests as f64)),
                    ("connections", num(a.connections as f64)),
                    ("total_seconds", num(a.total_s)),
                    ("throughput_rps", num(a.requests as f64 / a.total_s)),
                    ("p50_ms", num(a.p50_ms)),
                    ("p99_ms", num(a.p99_ms)),
                ])
            })),
        ),
        (
            "batched",
            obj(vec![
                ("window_us", num(400.0)),
                ("max_batch", num(32.0)),
                ("batches", num(batches as f64)),
                ("batched_requests", num(batched_requests as f64)),
                ("mean_batch", num(mean_batch)),
            ]),
        ),
        ("batched_vs_solo_throughput_at_100", num(ratio)),
    ]);
    let summary = format!(
        "net: solo vs micro-batched over loopback at 10/100/1000 in-flight, \
         batched/solo throughput at 100 in-flight {ratio:.2}x, \
         mean batch {mean_batch:.1} across {batches} stacked call(s)\n"
    );
    Ok((json, summary))
}

/// Variant-store paging bench (DESIGN.md §Variant store): N synthetic
/// personalized users — the base's own subspace factors plus per-user
/// deterministic noise — paged under a budget sized for N/10 residents,
/// swept with Zipf-popular `get` traffic.  Records delta-vs-full
/// compression, hit rate, evict→reload latency, and the bit-identity
/// pin across a forced evict-everything pass.  Uses its own dim-128
/// demo set so factor compression reflects a realistically wide MLP,
/// not the tiny test fixture.
fn bench_store(quick: bool) -> Result<(Json, String)> {
    use crate::data::rng::Pcg64;
    use crate::store::{extract_delta, VariantStore};

    let dir = std::env::temp_dir().join(format!("wasi_bench_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let demo = DemoConfig {
        image: 16,
        patch: 4,
        dim: 128,
        depth: 2,
        mlp_ratio: 2,
        classes: 10,
        batch: 8,
        eps: 0.8,
        seed: 41,
    };
    let names = write_demo_artifacts(&dir, &demo)?;
    let manifest = Manifest::load(&dir)?;
    let model = names
        .iter()
        .find(|n| n.contains("wasi"))
        .cloned()
        .unwrap_or_else(|| names[0].clone());
    let entry = manifest.model(&model)?.clone();
    let base = entry.load_params()?;

    // Template record: the base's own factor tensors (a zero delta);
    // each user perturbs the factor values, never the frozen region.
    let template = extract_delta(&entry, &base, &base, Precision::F32)?;
    let users = if quick { 40 } else { 100 };
    let residents = (users / 10).max(1);
    let budget_bytes = residents * template.bytes();
    let store = VariantStore::open(&dir.join("store"), budget_bytes)?;
    for u in 0..users {
        let mut rec = template.clone();
        let mut rng = Pcg64::new(0x5702 + u as u64);
        for t in &mut rec.tensors {
            for v in &mut t.data {
                *v += (rng.next_f64() as f32 - 0.5) * 0.02;
            }
        }
        store.put(&format!("user-{u:04}"), rec)?;
    }

    // Zipf(1.1) get sweep; reload latency is measured on misses only.
    let requests = if quick { 400 } else { 2000 };
    let weights: Vec<f64> = (0..users).map(|r| 1.0 / ((r + 1) as f64).powf(1.1)).collect();
    let total: f64 = weights.iter().sum();
    let mut acc = 0.0;
    let cum: Vec<f64> = weights
        .iter()
        .map(|w| {
            acc += w / total;
            acc
        })
        .collect();
    let before = store.stats()?;
    let mut reload_ms = Vec::new();
    let mut rng = Pcg64::new(99);
    for _ in 0..requests {
        let roll = rng.next_f64();
        let rank = cum.iter().position(|c| roll <= *c).unwrap_or(users - 1);
        let key = format!("user-{rank:04}");
        let was_resident = store.is_resident(&key);
        let t0 = Instant::now();
        store.get(&key)?;
        if !was_resident {
            reload_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
    }
    let after = store.stats()?;
    // hits/misses/reloads describe the sweep; evictions are the store
    // lifetime total (paging starts during the put phase already).
    let hits = after.hits - before.hits;
    let misses = after.misses - before.misses;
    let reloads = after.reloads - before.reloads;
    let hit_rate = hits as f64 / requests as f64;

    // Bit-identity pin: the zero-copy overlay against the materialized
    // full vector, then again after evicting everything — the reloaded
    // record must reproduce the same logits bit for bit.
    let infer = NativeInferEngine::load(&entry)?;
    let side = entry
        .image_side()
        .ok_or_else(|| anyhow::anyhow!("store bench model is not an image model"))?;
    let mut task = VisionTask::new("store", entry.classes, side, 0.7, 8, 55);
    let (x, _, _) = task.batch_onehot(entry.batch);
    let key = "user-0000";
    let rec = store.get(key)?;
    let full = rec.apply(&base)?;
    let bits = |v: Vec<f32>| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
    let want = bits(infer.infer(&full, &x)?);
    let got = bits(infer.infer_overlay(&rec.overlay(&base)?, &x)?);
    store.evict_all();
    let again = bits(infer.infer_overlay(&store.get(key)?.overlay(&base)?, &x)?);
    let reload_bit_identical = want == got && want == again;

    let delta_bytes = template.bytes();
    let full_bytes = entry.params_len * 4;
    let (upg_full, upg_delta) = crate::coordinator::memory::users_per_gb(&entry);
    let compression = full_bytes as f64 / delta_bytes.max(1) as f64;
    let json = obj(vec![
        ("model", jstr(model.clone())),
        ("users", num(users as f64)),
        ("budget_residents", num(residents as f64)),
        ("budget_bytes", num(budget_bytes as f64)),
        ("requests", num(requests as f64)),
        ("hit_rate", num(hit_rate)),
        ("hits", num(hits as f64)),
        ("misses", num(misses as f64)),
        ("reloads", num(reloads as f64)),
        ("evictions", num(after.evictions as f64)),
        ("delta_bytes", num(delta_bytes as f64)),
        ("full_bytes", num(full_bytes as f64)),
        ("compression_ratio", num(compression)),
        ("users_per_gb_delta", num(upg_delta as f64)),
        ("users_per_gb_full", num(upg_full as f64)),
        ("reload_p50_ms", num(percentile(&reload_ms, 50.0))),
        ("reload_p95_ms", num(percentile(&reload_ms, 95.0))),
        ("reload_bit_identical", Json::Bool(reload_bit_identical)),
    ]);
    let summary = format!(
        "store: {users} users, {delta_bytes} B delta vs {full_bytes} B full ({compression:.1}x), \
         budget {residents} residents, hit rate {hit_rate:.2}, reload p95 {:.2} ms, \
         bit-identical across evict→reload: {reload_bit_identical}\n",
        percentile(&reload_ms, 95.0)
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok((json, summary))
}

/// Pass-pipeline bench (DESIGN.md §Pass pipeline).  Optimized vs
/// unoptimized executors over the vanilla demo variant at ONE kernel
/// thread — a single thread keeps the parallel layer inline, so the
/// counting global allocator (`util::alloc`, installed by `main.rs`)
/// sees only the executor's own heap traffic — plus the arena's
/// liveness footprint, prepacked weight-panel inference against
/// dequantize-on-the-fly at int8 on the wasi variant, and the serve
/// pool's packed-job cache hit rate.  Every arm is bit-identical to its
/// counterpart (the `tests/passes.rs` pins), so the `_ms` rows measure
/// wall-clock only; the allocation counts are structural and join the
/// gate's no-regress check.
fn bench_passes(
    dir: &Path,
    manifest: &Manifest,
    names: &[String],
    wasi_entry: &ModelEntry,
    steps: usize,
    infer_reps: usize,
) -> Result<(Json, String)> {
    use crate::engine::passes::PassSet;
    use crate::engine::{GraphExecutor, LayerGraph, PackedParams};
    use crate::util::alloc::allocation_count;

    set_num_threads(1);
    let vanilla = names
        .iter()
        .find(|n| !n.contains("wasi"))
        .cloned()
        .unwrap_or_else(|| names[0].clone());
    let entry = manifest.model(&vanilla)?.clone();
    let side = entry
        .image_side()
        .ok_or_else(|| anyhow::anyhow!("passes bench model is not an image model"))?;
    let mut task = VisionTask::new("passes", entry.classes, side, 0.7, 8, 311);
    let (x, y, _) = task.batch_onehot(entry.batch);

    // One full training step per iteration, driven exactly like
    // `NativeModelEngine::step` minus persistence; two warmup steps let
    // the arena and scratch buffers reach steady state first.
    let train_arm = |ps: PassSet| -> Result<(f64, f64)> {
        let mut exec = GraphExecutor::new_with(LayerGraph::from_entry(&entry)?, &entry, ps)?;
        let mut params = entry.load_params()?;
        let mut grads = vec![0.0f32; params.len()];
        for _ in 0..2 {
            let logits = exec.forward_train(&params, &x)?;
            let (_, _, dlogits) = exec.loss_and_grad(&logits, &y);
            grads.fill(0.0);
            exec.backward(&params, &dlogits, &mut grads)?;
            exec.update(&mut params, &grads, 0.01);
        }
        let a0 = allocation_count();
        let t0 = Instant::now();
        for _ in 0..steps {
            let logits = exec.forward_train(&params, &x)?;
            let (_, _, dlogits) = exec.loss_and_grad(&logits, &y);
            grads.fill(0.0);
            exec.backward(&params, &dlogits, &mut grads)?;
            exec.update(&mut params, &grads, 0.01);
        }
        let dt = t0.elapsed().as_secs_f64();
        let allocs = (allocation_count() - a0) as f64 / steps as f64;
        Ok((dt / steps as f64 * 1e3, allocs))
    };
    let (train_opt_ms, allocs_step_opt) = train_arm(PassSet::all())?;
    let (train_ref_ms, allocs_step_ref) = train_arm(PassSet::none())?;

    let infer_arm = |ps: PassSet| -> Result<(f64, f64)> {
        let exec = GraphExecutor::new_infer_with(LayerGraph::from_entry(&entry)?, &entry, ps)?;
        let params = entry.load_params()?;
        exec.infer(&params, &x, entry.batch)?; // warmup sizes the arena
        let a0 = allocation_count();
        let t0 = Instant::now();
        for _ in 0..infer_reps {
            exec.infer(&params, &x, entry.batch)?;
        }
        let dt = t0.elapsed().as_secs_f64();
        let allocs = (allocation_count() - a0) as f64 / infer_reps as f64;
        Ok((dt / infer_reps as f64 * 1e3, allocs))
    };
    let (infer_opt_ms, allocs_inf_opt) = infer_arm(PassSet::all())?;
    let (infer_ref_ms, allocs_inf_ref) = infer_arm(PassSet::none())?;

    // Liveness footprint of the optimized training program.
    let planned = GraphExecutor::new_with(LayerGraph::from_entry(&entry)?, &entry, PassSet::all())?;
    let report = planned
        .plan_report()
        .train
        .ok_or_else(|| anyhow::anyhow!("arena pass produced no training program"))?;
    let reuse = crate::costmodel::memory::arena_reuse_ratio(report.sum_elems, report.arena_elems);

    // Prepacked panels vs dequantize-on-the-fly: the wasi variant at
    // int8 (factor tensors are the GEMM weights there), same packed
    // record shape either way so only the panel path differs.
    let wparams = wasi_entry.load_params()?;
    let winfer = NativeInferEngine::load(wasi_entry)?;
    let wside = wasi_entry
        .image_side()
        .ok_or_else(|| anyhow::anyhow!("passes bench model is not an image model"))?;
    let mut wtask = VisionTask::new("panels", wasi_entry.classes, wside, 0.7, 8, 313);
    let (wx, _, _) = wtask.batch_onehot(wasi_entry.batch);
    let packed_on = PackedParams::pack_with(wasi_entry, &wparams, Precision::I8, PassSet::all())?;
    let packed_off = PackedParams::pack_with(wasi_entry, &wparams, Precision::I8, PassSet::none())?;
    let time_packed = |p: &PackedParams| -> Result<f64> {
        winfer.infer_packed(p, &wx)?; // warmup
        let t0 = Instant::now();
        for _ in 0..infer_reps {
            winfer.infer_packed(p, &wx)?;
        }
        Ok(t0.elapsed().as_secs_f64() / infer_reps as f64 * 1e3)
    };
    let prepacked_ms = time_packed(&packed_on)?;
    let repack_ms = time_packed(&packed_off)?;
    let prepack_speedup = repack_ms / prepacked_ms;

    // Packed-job cache (serve/pool.rs): 1 build + 7 reuses per key.
    let pool_entry = crate::serve::PoolEntry::open(dir)?;
    for _ in 0..8 {
        pool_entry.packed_for("bench-job", Precision::I8, || {
            PackedParams::pack(wasi_entry, &wparams, Precision::I8)
        })?;
    }
    let hits = pool_entry.prepack_hits() as f64;
    let misses = pool_entry.prepack_misses() as f64;
    let hit_rate = hits / (hits + misses).max(1.0);

    set_num_threads(0);
    let json = obj(vec![
        ("enabled", jstr(PassSet::all().to_string())),
        ("model", jstr(vanilla.clone())),
        ("arena_bytes", num(report.arena_elems as f64 * 4.0)),
        ("sum_buffer_bytes", num(report.sum_elems as f64 * 4.0)),
        ("arena_reuse_ratio", num(reuse)),
        ("intervals", num(report.buffers as f64)),
        ("allocations_per_step_optimized", num(allocs_step_opt)),
        ("allocations_per_step_unoptimized", num(allocs_step_ref)),
        ("allocations_per_infer_optimized", num(allocs_inf_opt)),
        ("allocations_per_infer_unoptimized", num(allocs_inf_ref)),
        ("train_step_optimized_ms", num(train_opt_ms)),
        ("train_step_unoptimized_ms", num(train_ref_ms)),
        ("infer_optimized_ms", num(infer_opt_ms)),
        ("infer_unoptimized_ms", num(infer_ref_ms)),
        ("infer_prepacked_ms", num(prepacked_ms)),
        ("infer_repack_ms", num(repack_ms)),
        ("prepack_infer_speedup", num(prepack_speedup)),
        ("prepack_panel_count", num(packed_on.panel_count() as f64)),
        ("prepack_panel_bytes", num(packed_on.panel_bytes() as f64)),
        ("prepack_cache_hit_rate", num(hit_rate)),
    ]);
    let summary = format!(
        "passes: arena {:.2} MB vs {:.2} MB unshared ({reuse:.2}x reuse, {} buffers), \
         allocs/step {allocs_step_opt:.0} vs {allocs_step_ref:.0}, \
         step {train_opt_ms:.1} vs {train_ref_ms:.1} ms, \
         prepacked int8 infer {prepacked_ms:.2} vs {repack_ms:.2} ms \
         ({prepack_speedup:.2}x), packed-job cache hit rate {hit_rate:.2}\n",
        crate::costmodel::memory::elems_to_mb(report.arena_elems as f64),
        crate::costmodel::memory::elems_to_mb(report.sum_elems as f64),
        report.buffers,
    );
    Ok((json, summary))
}

/// Run the bench, write `cfg.out`, and return a human-readable summary.
/// The process-global thread override is restored on every exit path.
pub fn run_bench(cfg: &BenchConfig) -> Result<String> {
    let prior_override = thread_override();
    let result = run_bench_inner(cfg);
    set_num_threads(prior_override);
    result
}

fn run_bench_inner(cfg: &BenchConfig) -> Result<String> {
    let auto = {
        set_num_threads(0);
        num_threads()
    };
    let steps = cfg.steps.max(1);
    let infer_reps = if cfg.quick { 5 } else { 20 };

    // 1. demo artifact generation (timed — it is part of the offline
    //    zero→train path the README advertises).
    let dir = std::env::temp_dir().join(format!(
        "wasi_bench_artifacts_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let t0 = Instant::now();
    let names = write_demo_artifacts(&dir, &bench_demo_config(cfg.quick))?;
    let demo_s = t0.elapsed().as_secs_f64();
    let manifest = Manifest::load(&dir)?;
    let model = names
        .iter()
        .find(|n| n.contains("wasi"))
        .cloned()
        .unwrap_or_else(|| names[0].clone());
    let entry = manifest.model(&model)?.clone();

    // 2. native engine: 1 thread vs auto.
    let mut arm_threads = vec![1usize];
    if auto > 1 {
        arm_threads.push(auto);
    }
    let mut arms = Vec::new();
    for &t in &arm_threads {
        arms.push(run_native_arm(&entry, t, steps, infer_reps)?);
    }
    let speedup = if arms.len() == 2 { arms[0].train_s / arms[1].train_s } else { 1.0 };

    // 2b. SIMD vs forced-scalar at the auto thread count.
    let (simd_json, simd_speedup) = bench_simd(&entry, steps, infer_reps)?;

    // 2c. inference precisions: latency, weight bytes, f32 agreement.
    let prec_arms = bench_precision(&entry, infer_reps)?;
    let f32_arm = &prec_arms[0];
    let i8_arm = prec_arms
        .iter()
        .find(|a| a.precision == Precision::I8)
        .expect("precision sweep always includes i8");
    let int8_vs_f32_speedup = f32_arm.infer_s / i8_arm.infer_s;
    let int8_weight_compression = f32_arm.weight_bytes as f64 / i8_arm.weight_bytes as f64;

    // 2d. batched-GEMM amortization: solo vs coalesced batch of 8.
    let (batched_json, f32_batch8_speedup, i8_batch8_speedup) =
        bench_batched(&entry, infer_reps)?;
    let precision_json = obj(vec![
        (
            "arms",
            arr(prec_arms.iter().map(|a| {
                obj(vec![
                    ("precision", jstr(a.precision.to_string())),
                    ("infer_seconds", num(a.infer_s)),
                    ("infer_reps", num(a.infer_reps as f64)),
                    ("weight_bytes", num(a.weight_bytes as f64)),
                    ("top1_agreement", num(a.top1_agreement)),
                ])
            })),
        ),
        ("int8_isa", jstr(simd::int8_isa_name())),
        ("int8_vs_f32_speedup", num(int8_vs_f32_speedup)),
        ("int8_weight_compression", num(int8_weight_compression)),
        ("batched", batched_json),
    ]);

    // 3. per-node attribution at the auto thread count — ONE profiled
    //    run feeds both the rendered table and the JSON record.
    set_num_threads(0);
    let prof_steps = if cfg.quick { 2usize } else { 4 };
    let profiled = super::latency::profile_nodes(&entry, prof_steps);
    let (node_table, node_json) = match &profiled {
        Ok(timings) => {
            let mut top: Vec<_> = timings.clone();
            top.sort_by(|a, b| {
                (b.fwd_s + b.bwd_s).partial_cmp(&(a.fwd_s + a.bwd_s)).unwrap()
            });
            let json = arr(top.iter().take(8).map(|t| {
                obj(vec![
                    ("node", jstr(t.label.clone())),
                    ("fwd_ms_per_step", num(t.fwd_s / prof_steps as f64 * 1e3)),
                    ("bwd_ms_per_step", num(t.bwd_s / prof_steps as f64 * 1e3)),
                ])
            }));
            let table = super::latency::render_node_table(&model, prof_steps, timings);
            (Some(table), json)
        }
        Err(_) => (None, arr([])),
    };

    // 4. the job service over the same artifact set: jobs/sec and
    //    submit→done latency at 1 worker vs N (distinct variants per
    //    worker, so the concurrent arm exercises real parallel jobs).
    set_num_threads(0);
    let serve_arms = bench_serve(&dir, &names, cfg.quick)?;

    // 4b. a tiny fixed-seed fault-free soak over the same artifact set:
    //     the scenario harness (DESIGN.md §Scenario harness) under a
    //     steady mixed workload, reduced to scalar telemetry.  Counts
    //     are structure-gated only; the `_ms`/`_seconds` keys join the
    //     wallclock gate like every other timing here.
    let mut soak_cfg = SoakConfig::quick(&dir);
    soak_cfg.events = if cfg.quick { 40 } else { 120 };
    soak_cfg.max_seconds = if cfg.quick { 30.0 } else { 120.0 };
    soak_cfg.variants = names.clone();
    let soak = run_soak(&soak_cfg)?;
    let soak_json = obj(vec![
        ("events", num(soak.events_replayed as f64)),
        ("jobs", num(soak.jobs.total() as f64)),
        ("invariant_violations", num(soak.violations.len() as f64)),
        ("queue_depth_max", num(soak.queue_depth_max() as f64)),
        ("soak_seconds", num(soak.soak_seconds)),
        ("p50_submit_to_done_ms", finite_num(soak.submit_to_done.p(50.0))),
        ("p95_submit_to_done_ms", finite_num(soak.submit_to_done.p(95.0))),
        ("infer_p50_ms", finite_num(soak.infer_roundtrip.p(50.0))),
    ]);

    // 4c. the variant store: delta compression, LRU hit rate under a
    //     Zipf user population, evict→reload latency + bit-identity.
    set_num_threads(0);
    let (store_json, store_summary) = bench_store(cfg.quick)?;

    // 4d. the optimization-pass pipeline: arena reuse, allocations per
    //     step, prepacked panels vs repacking, packed-job cache.
    let (passes_json, passes_summary) =
        bench_passes(&dir, &manifest, &names, &entry, steps, infer_reps)?;

    // 4e. the socket front-end: p50/p99 infer latency and throughput at
    //     10/100/1000 in-flight over real loopback connections, solo vs
    //     micro-batched over one shared single-worker service.
    let (net_json, net_summary) = bench_net(&dir, &model, cfg.quick)?;

    // 5. the HLO engine on the same artifact set (expected unavailable
    //    offline: the demo set ships no train artifact, and without
    //    PJRT the runtime cannot execute model HLO).
    let rt = Runtime::cpu()?;
    let hlo_json = match train_engine(&rt, &entry, EngineKind::Hlo) {
        Ok(_) => obj(vec![("engine", jstr("hlo")), ("available", Json::Bool(true))]),
        Err(e) => obj(vec![
            ("engine", jstr("hlo")),
            ("available", Json::Bool(false)),
            ("reason", jstr(format!("{e:#}"))),
        ]),
    };

    let native_json = obj(vec![
        ("engine", jstr("native")),
        ("available", Json::Bool(true)),
        ("arms", arr(arms.iter().map(arm_json))),
        ("thread_speedup", num(speedup)),
    ]);
    let serve_json = arr(serve_arms.iter().map(|a| {
        obj(vec![
            ("workers", num(a.workers as f64)),
            ("jobs", num(a.jobs as f64)),
            ("steps_per_job", num(a.steps_per_job as f64)),
            ("total_seconds", num(a.total_s)),
            ("jobs_per_sec", num(a.jobs_per_sec)),
            ("p50_submit_to_done_s", num(a.p50_s)),
            ("p95_submit_to_done_s", num(a.p95_s)),
        ])
    }));
    let out_json = obj(vec![
        ("bench", jstr("wasi-train bench")),
        ("quick", Json::Bool(cfg.quick)),
        ("model", jstr(model.clone())),
        ("steps", num(steps as f64)),
        ("host_auto_threads", num(auto as f64)),
        ("demo_seconds", num(demo_s)),
        ("engines", arr([native_json, hlo_json])),
        ("simd", simd_json),
        ("precision", precision_json),
        ("serve", serve_json),
        ("soak", soak_json),
        ("store", store_json),
        ("passes", passes_json),
        ("net", net_json),
        ("nodes", node_json),
    ]);
    std::fs::write(&cfg.out, out_json.to_string())
        .with_context(|| format!("writing {}", cfg.out.display()))?;

    // Human-readable summary.
    let mut t = Table::new(["engine", "threads", "train s", "ms/step", "infer s"])
        .title(format!("wasi-train bench — {model}, {steps} steps (demo gen {demo_s:.2}s)"));
    for a in &arms {
        t.row([
            "native".to_string(),
            a.threads.to_string(),
            format!("{:.2}", a.train_s),
            format!("{:.1}", a.mean_step_ms),
            format!("{:.2}", a.infer_s),
        ]);
    }
    let mut body = t.render();
    if arms.len() == 2 {
        body.push_str(&format!(
            "thread speedup (1 -> {}): {speedup:.2}x\n",
            arms[1].threads
        ));
    } else {
        body.push_str("single-core host: no thread sweep\n");
    }
    body.push_str(&format!(
        "simd train speedup (scalar -> {}): {simd_speedup:.2}x\n",
        simd::isa_name()
    ));
    let mut pt = Table::new(["precision", "infer s", "weight MB", "top-1 vs f32"])
        .title("inference precisions (native engine)".to_string());
    for a in &prec_arms {
        pt.row([
            a.precision.to_string(),
            format!("{:.3}", a.infer_s),
            format!("{:.2}", a.weight_bytes as f64 / (1024.0 * 1024.0)),
            format!("{:.3}", a.top1_agreement),
        ]);
    }
    body.push('\n');
    body.push_str(&pt.render());
    body.push_str(&format!(
        "int8 vs f32: {int8_vs_f32_speedup:.2}x latency, \
         {int8_weight_compression:.2}x weight compression ({} integer dots)\n",
        simd::int8_isa_name()
    ));
    body.push_str(&format!(
        "batch-8 per-request speedup: f32 {f32_batch8_speedup:.2}x, \
         int8 {i8_batch8_speedup:.2}x\n"
    ));
    let mut st = Table::new(["workers", "jobs", "steps/job", "jobs/s", "p50 s", "p95 s"])
        .title("serve scheduler — submit->done latency".to_string());
    for a in &serve_arms {
        st.row([
            a.workers.to_string(),
            a.jobs.to_string(),
            a.steps_per_job.to_string(),
            format!("{:.2}", a.jobs_per_sec),
            format!("{:.3}", a.p50_s),
            format!("{:.3}", a.p95_s),
        ]);
    }
    body.push('\n');
    body.push_str(&st.render());
    body.push_str(&format!(
        "soak: {} events in {:.2}s, {} jobs, queue depth max {}, \
         {} invariant violation(s)\n",
        soak.events_replayed,
        soak.soak_seconds,
        soak.jobs.total(),
        soak.queue_depth_max(),
        soak.violations.len()
    ));
    body.push_str(&store_summary);
    body.push_str(&passes_summary);
    body.push_str(&net_summary);
    match (&node_table, &profiled) {
        (Some(table), _) => {
            body.push('\n');
            body.push_str(table);
        }
        (None, Err(e)) => body.push_str(&format!("(node attribution skipped: {e:#})\n")),
        (None, Ok(_)) => {}
    }
    body.push_str(&format!("\nbench record -> {}\n", cfg.out.display()));
    let _ = std::fs::remove_dir_all(&dir);
    Ok(body)
}

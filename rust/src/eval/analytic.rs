//! Analytic exhibits: Fig. 2 (cost-model surfaces), Fig. 4 (activation
//! explained variance), Fig. 12 (WSI on conv), Tab. 1 (all-linear WASI at
//! paper scale).

use anyhow::Result;

use crate::costmodel::curves::fig2_sweep;
use crate::costmodel::layer_specs::{mcunet_tail, vit_b16_all_linear};
use crate::costmodel::{LayerDims, WasiRanks};
use crate::util::table::{si, Table};
use crate::wasi::wsi::{powerlaw, WsiFactors};

use super::EvalCtx;

/// Fig. 2: C/S training+inference over (layer dim, rank).
pub fn fig2(_ctx: &EvalCtx) -> Result<String> {
    let dims = [256usize, 512, 1024, 2048, 4096];
    let ranks = [8usize, 16, 32, 64, 128, 256];
    let pts = fig2_sweep(128, 197, &dims, &ranks);
    let mut t = Table::new(["dim", "rank", "C_train", "C_infer", "S_train", "S_infer"])
        .title("Fig 2 — compression/speedup surfaces (B=128, N=197, Eqs. 39-46)");
    for p in &pts {
        t.row([
            p.dim.to_string(),
            p.rank.to_string(),
            format!("{:.2}x", p.c_training),
            format!("{:.2}x", p.c_inference),
            format!("{:.2}x", p.s_training),
            format!("{:.2}x", p.s_inference),
        ]);
    }
    let mut body = t.render();
    body.push_str(
        "\nShape check (paper §3.4): compression/speedup grow with model dim at\n\
         fixed rank, and converge to ~1x as rank approaches full.\n",
    );
    Ok(body)
}

/// Fig. 4: explained variance of each activation mode (from the AOT
/// calibration batch's spectra in the manifest).
pub fn fig4(ctx: &EvalCtx) -> Result<String> {
    let manifest_path = ctx.session.manifest().dir.join("manifest.json");
    let text = std::fs::read_to_string(manifest_path)?;
    let j = crate::util::json::Json::parse(&text)?;
    let spectra = j
        .get("activation_spectra")
        .and_then(|v| v.as_obj())
        .ok_or_else(|| anyhow::anyhow!("manifest has no activation_spectra (rebuild artifacts)"))?;

    let mut t = Table::new(["layer", "mode", "sv1%", "sv2%", "sv3%", "sv4%", "top4cum%"])
        .title("Fig 4 — explained variance per singular value, per mode of A_i");
    for (layer, modes) in spectra.iter().take(4) {
        for (m, row) in modes.as_arr().unwrap_or(&[]).iter().enumerate() {
            let s = row.f64_vec()?;
            let total: f64 = s.iter().map(|v| v * v).sum();
            if total <= 0.0 {
                continue;
            }
            let pct: Vec<f64> = s.iter().map(|v| v * v / total * 100.0).collect();
            let top4: f64 = pct.iter().take(4).sum();
            let get = |i: usize| pct.get(i).copied().unwrap_or(0.0);
            t.row([
                layer.clone(),
                format!("{}", m + 1),
                format!("{:.1}", get(0)),
                format!("{:.1}", get(1)),
                format!("{:.1}", get(2)),
                format!("{:.1}", get(3)),
                format!("{:.1}", top4),
            ]);
        }
    }
    let mut body = t.render();
    body.push_str(
        "\nShape check (paper Fig. 4): most activation energy concentrates in the\n\
         first few singular values of every mode.\n",
    );
    Ok(body)
}

/// Fig. 12: WSI applied to the last 1-4 conv layers of an MCUNet-like
/// tail — weight memory vs reconstruction fidelity; at ε=0.9 memory can
/// EXCEED vanilla (the paper's negative result).
pub fn fig12(_ctx: &EvalCtx) -> Result<String> {
    let tail = mcunet_tail();
    let mut t = Table::new(["eps", "layers", "weight elems (WSI)", "weight elems (dense)", "ratio", "recon err"])
        .title("Fig 12 — WSI on conv (MCUNet-like tail, conv as O x I*k*k)");
    // Factorize each conv weight ONCE at a near-lossless threshold; per-ε
    // ranks then come from the shared spectrum (one SVD per layer total).
    let layers: Vec<_> = tail
        .iter()
        .rev()
        .enumerate()
        .map(|(idx, (_, o, ik2))| {
            let w = powerlaw(*o, *ik2, 0.35, 42 + idx as u64);
            let d = crate::linalg::svd::svd(&w);
            (w, d, *o, *ik2)
        })
        .collect();
    for eps in [0.75f64, 0.8, 0.9] {
        for n_layers in 1..=layers.len() {
            let mut wsi_elems = 0usize;
            let mut dense_elems = 0usize;
            let mut err_acc = 0.0f64;
            for (w, d, o, ik2) in layers.iter().take(n_layers) {
                let k = d.rank_for_energy(eps);
                wsi_elems += k * (o + ik2);
                dense_elems += o * ik2;
                let rec = d.reconstruct(k);
                err_acc += (rec.sub(w).frob_norm() / w.frob_norm()) as f64;
            }
            t.row([
                format!("{eps}"),
                n_layers.to_string(),
                wsi_elems.to_string(),
                dense_elems.to_string(),
                format!("{:.2}x", dense_elems as f64 / wsi_elems as f64),
                format!("{:.3}", err_acc / n_layers as f64),
            ]);
        }
    }
    let mut body = t.render();
    body.push_str(
        "\nShape check (paper Fig. 12): at eps=0.9 the optimal rank is high enough\n\
         that K(O+I) exceeds O*I on compact conv layers (ratio < 1) — WSI does\n\
         not pay off on already-compact convolutions.\n",
    );
    Ok(body)
}

/// Tab. 1: WASI on ALL linear layers (attention + MLP) of ViT-B/16 at
/// paper scale (analytic), plus the measured tiny-artifact counterpart.
pub fn tab1(ctx: &EvalCtx) -> Result<String> {
    let spec = vit_b16_all_linear(128);
    let mut t = Table::new(["eps", "TrainMem(MB)", "InferMem(MB)", "TrainFLOPs", "InferFLOPs"])
        .title("Tab 1 — WASI on all linears, ViT-B/16 scale (B=128; Eqs. 33-46)");
    for eps in [0.4f64, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
        let mut train_mem = 0.0;
        let mut infer_mem = 0.0;
        let mut train_fl = 0.0;
        let mut infer_fl = 0.0;
        for (_, l) in &spec.layers {
            if eps >= 1.0 {
                train_mem += l.vanilla_train_mem();
                infer_mem += l.m_vanilla_w();
                train_fl += l.vanilla_train_flops();
                infer_fl += l.f_vanilla();
            } else {
                let ranks = paper_scale_ranks(l, eps);
                train_mem += l.wasi_train_mem(&ranks);
                infer_mem += l.m_wasi_w(ranks.k);
                train_fl += l.wasi_train_flops(&ranks);
                infer_fl += l.f_wasi(ranks.k);
            }
        }
        t.row([
            format!("{eps}"),
            format!("{:.1}", train_mem * 4.0 / 1048576.0),
            format!("{:.1}", infer_mem * 4.0 / 1048576.0),
            si(train_fl),
            si(infer_fl),
        ]);
    }
    let mut body = t.render();

    // Measured counterpart on the tiny artifact, if present.
    if let Ok(entry) = ctx.session.manifest().model("vit_wasi_attn_eps80") {
        let mem = crate::coordinator::memory::account(entry);
        body.push_str(&format!(
            "\nMeasured tiny-artifact counterpart (vit_wasi_attn_eps80):\n\
             params {} elems, state {} elems, total train mem {:.2} MB\n",
            entry.params_len,
            entry.state_len,
            mem.total_mb()
        ));
    }
    body.push_str(
        "\nShape check (paper Tab. 1): memory and FLOPs grow monotonically with eps\n\
         and stay far below vanilla (eps=1.0) until eps→1.\n",
    );
    Ok(body)
}

/// Paper-scale rank model: a trained transformer's spectra decay roughly
/// like a power law; map ε to ranks through that spectrum (α fitted to
/// the tiny model's measured spectra).
pub fn paper_scale_ranks(l: &LayerDims, eps: f64) -> WasiRanks {
    let k = powerlaw_rank(l.i.min(l.o), eps);
    let r = [
        powerlaw_rank(l.b, eps),
        powerlaw_rank(l.n, eps),
        powerlaw_rank(l.i, eps),
    ];
    WasiRanks { k, r }
}

/// Rank at explained-variance ε for s_j ∝ j^-0.8 spectra of length n.
pub fn powerlaw_rank(n: usize, eps: f64) -> usize {
    let alpha = 0.8f64;
    let energy: Vec<f64> = (1..=n).map(|j| (j as f64).powf(-2.0 * alpha)).collect();
    let total: f64 = energy.iter().sum();
    let mut cum = 0.0;
    for (j, e) in energy.iter().enumerate() {
        cum += e;
        if cum / total >= eps {
            return j + 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn powerlaw_rank_monotone() {
        let mut prev = 0;
        for eps in [0.3, 0.5, 0.7, 0.9, 0.99] {
            let k = powerlaw_rank(768, eps);
            assert!(k >= prev);
            prev = k;
        }
        assert!(powerlaw_rank(768, 0.4) < 768 / 10);
    }

    #[test]
    fn tab1_ranks_compress() {
        let l = LayerDims { b: 128, n: 197, i: 768, o: 3072 };
        let r = paper_scale_ranks(&l, 0.8);
        assert!(r.k < 300);
        assert!(l.c_training(&r) > 2.0);
    }
}

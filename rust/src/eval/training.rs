//! Fine-tuning exhibits driven through the AOT/HLO path: Figs. 3a, 5, 6,
//! 7, 9, 10, 11.  Accuracy is *measured* (real training runs on the tiny
//! artifacts); the paper-scale memory/FLOPs axes come from the cost model
//! with the measured artifact-scale numbers shown beside them.

use anyhow::Result;

use crate::coordinator::memory::{account, vanilla_activations};
use crate::coordinator::{FinetuneConfig, FinetuneReport};
use crate::costmodel::layer_specs::{tinyllama, vit_b16};
use crate::costmodel::{LayerDims, WasiRanks};
use crate::engine::train_engine;
use crate::linalg::matrix::Mat;
use crate::linalg::svd::svd;
use crate::runtime::ModelEntry;
use crate::util::table::{si, Table};

use super::analytic::paper_scale_ranks;
use super::EvalCtx;

fn finetune(ctx: &EvalCtx, model: &str, dataset: &str, seed: u64) -> Result<FinetuneReport> {
    ctx.session.finetune(&FinetuneConfig {
        model: model.into(),
        dataset: dataset.into(),
        samples: ctx.samples,
        steps: ctx.steps,
        seed,
        verbose: false,
        engine: ctx.engine,
        ..FinetuneConfig::default()
    })
}

/// Measured artifact-scale memory/FLOPs row pieces for a variant.
fn measured_axes(entry: &ModelEntry) -> (f64, f64) {
    let mem = account(entry);
    let mut flops = 0.0;
    for (name, (oi, act)) in &entry.layer_dims {
        if oi.len() != 2 || act.len() < 2 {
            continue;
        }
        let l = LayerDims {
            b: entry.batch,
            n: act[act.len() - 2],
            i: act[act.len() - 1],
            o: oi[0],
        };
        if let (Some(&k), Some(r)) = (entry.weight_ranks.get(name), entry.asi_ranks.get(name)) {
            if r.len() == 3 {
                let ranks = WasiRanks { k, r: [r[0], r[1], r[2]] };
                flops += l.wasi_train_flops(&ranks);
                continue;
            }
        }
        flops += l.vanilla_train_flops();
    }
    (mem.total_mb(), flops)
}

/// Fig. 3a: singular-value / rank stability across fine-tuning.
pub fn fig3a(ctx: &EvalCtx) -> Result<String> {
    let entry = ctx.session.manifest().model("vit_vanilla")?;
    let mut step = train_engine(ctx.session.runtime(), entry, ctx.engine)?;
    let task = crate::data::synth::VisionTask::preset("pets-like", 233).unwrap();
    let mut task = if task.classes != entry.classes {
        crate::data::synth::VisionTask::new("pets-like", entry.classes, 32, 0.6, 10, 233)
    } else {
        task
    };
    let layer = "blocks.1.mlp.fc1.w";
    let snapshots = if ctx.quick { 4 } else { 6 };
    let steps_per = (ctx.steps / snapshots).max(5);
    let sched = crate::coordinator::CosineSchedule::paper_default(snapshots * steps_per);

    let mut t = Table::new(["snapshot", "K(eps=0.8)", "s1", "s2", "s3", "s4", "s8"])
        .title(format!(
            "Fig 3a — spectrum of {layer} while fine-tuning (vanilla, {} engine)",
            step.backend()
        ));
    let mut ranks = Vec::new();
    for snap in 0..snapshots {
        if snap > 0 {
            for s in 0..steps_per {
                let (x, _, labels) = task.batch_onehot(entry.batch);
                let mut y = vec![0.0f32; entry.batch * entry.classes];
                for (i, &c) in labels.iter().enumerate() {
                    y[i * entry.classes + c] = 1.0;
                }
                step.step(&x, &y, sched.lr((snap - 1) * steps_per + s))?;
            }
        }
        let (data, shape) = step
            .tensor(layer)
            .ok_or_else(|| anyhow::anyhow!("{layer} not in param spec"))?;
        let w = Mat::from_vec(shape[0], shape[1], data.to_vec());
        let d = svd(&w);
        let k = d.rank_for_energy(0.8);
        ranks.push(k);
        t.row([
            snap.to_string(),
            k.to_string(),
            format!("{:.3}", d.s[0]),
            format!("{:.3}", d.s[1]),
            format!("{:.3}", d.s[2]),
            format!("{:.3}", d.s[3]),
            format!("{:.3}", d.s.get(7).copied().unwrap_or(0.0)),
        ]);
    }
    let spread = ranks.iter().max().unwrap() - ranks.iter().min().unwrap();
    let mut body = t.render();
    body.push_str(&format!(
        "\nRank spread across snapshots: {spread} (paper Fig. 3a: ranks are stable\n\
         across epochs; spread should be a small fraction of K).\n"
    ));
    Ok(body)
}

/// Fig. 5: ViT on CIFAR-10-like — accuracy vs memory/FLOPs for WASI, ASI,
/// SVD-LLM, vanilla.  Accuracy measured via HLO fine-tunes.
pub fn fig5(ctx: &EvalCtx) -> Result<String> {
    fig_vit_panel(ctx, "cifar10-like", "Fig 5")
}

pub fn fig_vit_panel(ctx: &EvalCtx, dataset: &str, title: &str) -> Result<String> {
    let m = ctx.session.manifest();
    let mut rows: Vec<(String, f64, Option<FinetuneReport>, (f64, f64))> = Vec::new();

    let mut names: Vec<String> = Vec::new();
    for prefix in ["vit_wasi_eps", "vit_asi_eps", "vit_svdllm_eps"] {
        for entry in m.models.values() {
            if entry.name.starts_with(prefix)
                && !entry.name.contains("kernel")
                && !entry.name.contains("attn")
            {
                names.push(entry.name.clone());
            }
        }
    }
    names.push("vit_vanilla".into());
    if ctx.quick {
        names.retain(|n| n == "vit_vanilla" || n.ends_with("eps80"));
    }

    // The vanilla manifest entry carries no layer_dims; compute its FLOPs
    // from any WASI sibling's dims with the vanilla formulas.
    let vanilla_flops: f64 = m
        .vit_wasi_variants()
        .first()
        .map(|w| {
            w.layer_dims
                .values()
                .filter(|(oi, act)| oi.len() == 2 && act.len() >= 2)
                .map(|(oi, act)| {
                    LayerDims {
                        b: w.batch,
                        n: act[act.len() - 2],
                        i: act[act.len() - 1],
                        o: oi[0],
                    }
                    .vanilla_train_flops()
                })
                .sum()
        })
        .unwrap_or(0.0);

    for name in names {
        let entry = m.model(&name)?;
        let report = finetune(ctx, &name, dataset, 233)?;
        let mut axes = measured_axes(entry);
        if entry.layer_dims.is_empty() {
            axes.1 = vanilla_flops;
        }
        rows.push((name.clone(), entry.eps.unwrap_or(1.0), Some(report), axes));
    }

    let mut t = Table::new([
        "variant", "eps", "val acc", "TrainMem(MB)", "TrainFLOPs/step", "step ms",
    ])
    .title(format!("{title} — ViT on {dataset} (accuracy MEASURED via HLO fine-tune, {} steps)", ctx.steps));
    for (name, eps, report, (mem, flops)) in &rows {
        let r = report.as_ref().unwrap();
        t.row([
            name.clone(),
            format!("{eps}"),
            format!("{:.3}", r.val_accuracy),
            format!("{:.2}", mem),
            si(*flops),
            format!("{:.0}", r.mean_step_seconds * 1e3),
        ]);
    }
    let mut body = t.render();

    // Paper-scale analytic panel (ViT-B/16).
    let spec = vit_b16(128);
    let mut t2 = Table::new(["eps", "TrainMem(MB)", "TrainFLOPs", "InferMem(MB)", "InferFLOPs"])
        .title(format!("{title} (analytic, ViT-B/16 scale, MLP linears)"));
    for eps in [0.4f64, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
        let (mut tm, mut tf, mut im, mut if_) = (0.0, 0.0, 0.0, 0.0);
        for (_, l) in &spec.layers {
            if eps >= 1.0 {
                tm += l.vanilla_train_mem();
                tf += l.vanilla_train_flops();
                im += l.m_vanilla_w();
                if_ += l.f_vanilla();
            } else {
                let rk = paper_scale_ranks(l, eps);
                tm += l.wasi_train_mem(&rk);
                tf += l.wasi_train_flops(&rk);
                im += l.m_wasi_w(rk.k);
                if_ += l.f_wasi(rk.k);
            }
        }
        t2.row([
            format!("{eps}"),
            format!("{:.1}", tm * 4.0 / 1048576.0),
            si(tf),
            format!("{:.1}", im * 4.0 / 1048576.0),
            si(if_),
        ]);
    }
    body.push('\n');
    body.push_str(&t2.render());
    body.push_str(
        "\nShape checks (paper Fig. 5): WASI accuracy rises with eps toward the\n\
         vanilla point; WASI train memory is far below vanilla and below SVD-LLM\n\
         (which keeps full activations for its adapters); ASI matches vanilla\n\
         accuracy but saves less compute than WASI.\n",
    );
    Ok(body)
}

/// Fig. 6: SwinLite (4D activations) across datasets, WASI vs vanilla.
pub fn fig6(ctx: &EvalCtx) -> Result<String> {
    let datasets: &[&str] = if ctx.quick {
        &["cifar10-like"]
    } else {
        &["cifar10-like", "pets-like", "flowers-like", "cub-like"]
    };
    let mut t = Table::new(["dataset", "variant", "eps", "val acc", "TrainMem(MB)", "step ms"])
        .title("Fig 6 — SwinLite (4D activations) across datasets");
    for ds in datasets {
        for name in ["swinlite_wasi_eps60", "swinlite_wasi_eps80", "swinlite_vanilla"] {
            if !ctx.session.manifest().models.contains_key(name) {
                continue;
            }
            let entry = ctx.session.manifest().model(name)?;
            let r = finetune(ctx, name, ds, 233)?;
            let mem = account(entry);
            t.row([
                ds.to_string(),
                name.to_string(),
                entry.eps.map(|e| e.to_string()).unwrap_or_else(|| "1.0".into()),
                format!("{:.3}", r.val_accuracy),
                format!("{:.2}", mem.total_mb()),
                format!("{:.0}", r.mean_step_seconds * 1e3),
            ]);
        }
    }
    let mut body = t.render();
    body.push_str(
        "\nShape check (paper Fig. 6): WASI tracks vanilla accuracy with a fraction\n\
         of the training memory across datasets; SVD-LLM is absent by design —\n\
         its whitening is undefined for 4D activations (App. A.4).\n",
    );
    Ok(body)
}

/// Fig. 7: TinyDec (decoder-only) on the BoolQ-like task + the paper-scale
/// TinyLlama last-k sweep (analytic axes).
pub fn fig7(ctx: &EvalCtx) -> Result<String> {
    let mut body = String::new();
    let mut t = Table::new(["variant", "val acc", "TrainMem(MB)", "step ms"])
        .title("Fig 7 — TinyDec on BoolQ-like yes/no task (measured)");
    for name in ["tinydec_wasi_eps50", "tinydec_vanilla"] {
        if !ctx.session.manifest().models.contains_key(name) {
            continue;
        }
        let entry = ctx.session.manifest().model(name)?;
        // sequence task batches
        let mut task = crate::data::synth::SequenceTask::new(256, entry.input_dim, 233);
        let mut step = train_engine(ctx.session.runtime(), entry, ctx.engine)?;
        let sched = crate::coordinator::CosineSchedule::paper_default(ctx.steps);
        let mut accs = Vec::new();
        let t0 = std::time::Instant::now();
        for s in 0..ctx.steps {
            let (x, y, _) = task.batch_onehot(entry.batch);
            let out = step.step(&x, &y, sched.lr(s))?;
            accs.push(out.accuracy as f64);
        }
        let secs = t0.elapsed().as_secs_f64() / ctx.steps as f64;
        let tail = &accs[accs.len().saturating_sub(10)..];
        let acc = tail.iter().sum::<f64>() / tail.len() as f64;
        let mem = account(entry);
        t.row([
            name.to_string(),
            format!("{:.3}", acc),
            format!("{:.2}", mem.total_mb()),
            format!("{:.0}", secs * 1e3),
        ]);
    }
    body.push_str(&t.render());

    // Paper-scale TinyLlama-1.1B last-k sweep (analytic).
    let mut t2 = Table::new([
        "last k", "WASI ActMem(MB)", "WASI WeightMem(MB)", "WASI TrainFLOPs",
        "ActMem x", "WeightMem x", "TrainFLOPs x", "InferFLOPs x",
    ])
    .title("Fig 7 (analytic) — TinyLlama-1.1B, WASI eps=0.1, last-k-layer sweep");
    for k in 1..=5 {
        let spec = tinyllama(4, 512, k);
        let (mut v_am, mut w_am, mut v_wm, mut w_wm) = (0.0, 0.0, 0.0, 0.0);
        let (mut v_tf, mut w_tf, mut v_if, mut w_if) = (0.0, 0.0, 0.0, 0.0);
        for (_, l) in &spec.layers {
            let rk = paper_scale_ranks(l, 0.1);
            v_am += l.m_vanilla_a();
            w_am += l.m_wasi_a(&rk.r);
            v_wm += l.m_vanilla_w();
            w_wm += l.m_wasi_w(rk.k);
            v_tf += l.vanilla_train_flops();
            w_tf += l.wasi_train_flops(&rk);
            v_if += l.f_vanilla();
            w_if += l.f_wasi(rk.k);
        }
        t2.row([
            k.to_string(),
            format!("{:.2}", w_am * 4.0 / 1048576.0),
            format!("{:.2}", w_wm * 4.0 / 1048576.0),
            si(w_tf),
            format!("{:.1}x", v_am / w_am),
            format!("{:.1}x", v_wm / w_wm),
            format!("{:.1}x", v_tf / w_tf),
            format!("{:.1}x", v_if / w_if),
        ]);
    }
    body.push('\n');
    body.push_str(&t2.render());
    body.push_str(
        "\nShape check (paper Fig. 7): at eps=0.1 the activation/weight memory and\n\
         FLOPs ratios are very large (paper: up to 953x / 30x / 13x / 30x) and\n\
         WASI holds accuracy on the yes/no task.\n",
    );
    Ok(body)
}

/// Fig. 9: seed variance (233/234/235) for WASI ViT.
pub fn fig9(ctx: &EvalCtx) -> Result<String> {
    let model = "vit_wasi_eps80";
    let mut t = Table::new(["seed", "val acc", "final loss", "TrainMem(MB)"])
        .title("Fig 9 — variance across random seeds (WASI eps=0.8, pets-like)");
    let mut accs = Vec::new();
    let seeds: &[u64] = if ctx.quick { &[233, 234] } else { &[233, 234, 235] };
    for &seed in seeds {
        let r = finetune(ctx, model, "pets-like", seed)?;
        accs.push(r.val_accuracy);
        t.row([
            seed.to_string(),
            format!("{:.3}", r.val_accuracy),
            format!("{:.3}", r.final_loss),
            format!("{:.2}", r.memory.total_mb()),
        ]);
    }
    let mean = accs.iter().sum::<f64>() / accs.len() as f64;
    let var = accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>() / accs.len() as f64;
    let mut body = t.render();
    body.push_str(&format!(
        "\nmean acc {:.3}, std {:.4} — paper Fig. 9: variance across seeds is\n\
         minimal (WASI is built from deterministic SVD/GS/matmul components;\n\
         only the data order and ASI init differ).\n",
        mean,
        var.sqrt()
    ));
    Ok(body)
}

/// Fig. 10: ViT across multiple datasets (same panel as Fig. 5).
pub fn fig10(ctx: &EvalCtx) -> Result<String> {
    let datasets: &[&str] = if ctx.quick {
        &["pets-like"]
    } else {
        &["pets-like", "flowers-like", "cifar100-like"]
    };
    let mut body = String::new();
    for ds in datasets {
        body.push_str(&fig_vit_panel(ctx, ds, "Fig 10")?);
        body.push('\n');
    }
    Ok(body)
}

/// Fig. 11: SwinLite baselines on CIFAR-10-like; SVD-LLM excluded (4D).
pub fn fig11(ctx: &EvalCtx) -> Result<String> {
    let mut t = Table::new(["variant", "eps", "val acc", "TrainMem(MB)", "ActMem vs vanilla"])
        .title("Fig 11 — SwinLite method comparison on cifar10-like");
    for name in ["swinlite_wasi_eps60", "swinlite_wasi_eps80", "swinlite_vanilla"] {
        if !ctx.session.manifest().models.contains_key(name) {
            continue;
        }
        let entry = ctx.session.manifest().model(name)?;
        let r = finetune(ctx, name, "cifar10-like", 233)?;
        let mem = account(entry);
        let vanilla_act = vanilla_activations(entry).max(1);
        let ratio = vanilla_act as f64
            / (mem.activations + mem.asi_state).max(1) as f64;
        t.row([
            name.to_string(),
            entry.eps.map(|e| e.to_string()).unwrap_or_else(|| "1.0".into()),
            format!("{:.3}", r.val_accuracy),
            format!("{:.2}", mem.total_mb()),
            if entry.eps.is_some() { format!("{ratio:.1}x smaller") } else { "1.0x".into() },
        ]);
    }
    let mut body = t.render();
    body.push_str(
        "\nSVD-LLM row intentionally absent: \"Truncation-Aware Data Whitening\" is\n\
         only defined for 3D activations (paper App. A.4), and SwinLite's MLP\n\
         activations are 4D.\n",
    );
    Ok(body)
}

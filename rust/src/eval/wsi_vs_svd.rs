//! Fig. 3b: WSI (warm subspace iteration) vs full SVD at every step.
//!
//! Native-engine study on a single-layer classifier over the synthetic
//! pets-like task: both strategies factor the weight at threshold ε; the
//! SVD strategy re-decomposes the materialized W every step (the paper's
//! strawman), WSI does one warm refresh.  We report accuracy and total
//! decomposition FLOPs for each ε — the paper's claim is ~1.36x fewer
//! FLOPs at equal accuracy and ~+35% accuracy at equal FLOPs.

use anyhow::Result;

use crate::data::synth::VisionTask;
use crate::data::Pcg64;
use crate::linalg::matrix::Mat;
use crate::linalg::svd::svd;
use crate::util::table::{si, Table};
use crate::wasi::wsi::{powerlaw, WsiFactors};

use super::EvalCtx;

const DIM: usize = 96;   // feature dim (PCA-like random projection of pixels)
const CLASSES: usize = 10;

/// Project pixels down to DIM with a fixed random matrix (keeps the
/// native study cheap while preserving class structure).
fn project(x: &[f32], n: usize, proj: &Mat) -> Mat {
    let xm = Mat::from_vec(n, proj.cols, x.to_vec());
    xm.matmul_nt(proj)
}

fn softmax_ce_grad(logits: &Mat, labels: &[usize]) -> (f64, f64, Mat) {
    let n = logits.rows;
    let c = logits.cols;
    let mut dy = Mat::zeros(n, c);
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for i in 0..n {
        let row = logits.row(i);
        let m = row.iter().fold(f32::MIN, |a, &b| a.max(b));
        let exps: Vec<f64> = row.iter().map(|&v| ((v - m) as f64).exp()).collect();
        let z: f64 = exps.iter().sum();
        let mut best = 0;
        for j in 0..c {
            let p = exps[j] / z;
            dy.data[i * c + j] = ((p - if labels[i] == j { 1.0 } else { 0.0 }) / n as f64) as f32;
            if row[j] > row[best] {
                best = j;
            }
        }
        loss -= (exps[labels[i]] / z).ln() / n as f64;
        if best == labels[i] {
            correct += 1;
        }
    }
    (loss, correct as f64 / n as f64, dy)
}

/// SVD cost model for an (m, n) matrix (one-sided Jacobi ≈ c·m·n²).
fn svd_flops(m: usize, n: usize) -> f64 {
    12.0 * m as f64 * n as f64 * n.min(m) as f64
}

/// WSI refresh cost (Eq. 36).
fn wsi_flops(o: usize, i: usize, k: usize) -> f64 {
    4.0 * (i * o * k) as f64 + 2.0 * (o * k * k) as f64
}

pub fn fig3b(ctx: &EvalCtx) -> Result<String> {
    let steps = if ctx.quick { 40 } else { 80 };
    let batch = 64;
    let mut rng = Pcg64::new(77);
    let mut proj = Mat::random(DIM, 32 * 32 * 3, &mut rng);
    proj.scale(1.0 / (32.0 * 32.0 * 3.0f32).sqrt()); // unit-variance features
    // Mild spectrum decay so the eps grid spans K ≈ 2..9 of the 10-row
    // classifier head (the interesting under- to near-full-rank range).
    let w0 = powerlaw(CLASSES, DIM, 0.3, 5);
    const LR: f32 = 0.1;

    let mut t = Table::new(["eps", "K", "WSI acc", "SVD acc", "WSI decomp FLOPs", "SVD decomp FLOPs", "ratio"])
        .title("Fig 3b — WSI vs per-step SVD (native engine, single-layer classifier)");
    let mut ratios = Vec::new();
    for eps in [0.4f64, 0.5, 0.6, 0.7, 0.8, 0.9] {
        // --- WSI strategy: factored training + warm refresh -------------
        let (mut fac, _) = WsiFactors::init_svd(&w0, eps);
        let k = fac.k();
        let mut task = VisionTask::new("pets-like", CLASSES, 32, 0.6, 10, 233);
        let mut wsi_acc = 0.0;
        for s in 0..steps {
            let (x, labels) = task.batch(batch);
            let xf = project(&x, batch, &proj);
            let h = xf.matmul_nt(&fac.r);
            let logits = h.matmul_nt(&fac.l);
            let (_, acc, dy) = softmax_ce_grad(&logits, &labels);
            let dl = dy.matmul_tn(&h);   // dYᵀ H -> (O, K)
            let dh = dy.matmul(&fac.l);  // (B, K)
            let dr = dh.matmul_tn(&xf);  // dHᵀ X -> (K, I)
            fac.sgd_update(&dl, &dr, LR, 1e-4, true);
            if s >= steps - 10 {
                wsi_acc += acc / 10.0;
            }
        }
        let wsi_decomp = svd_flops(CLASSES, DIM) + steps as f64 * wsi_flops(CLASSES, DIM, k);

        // --- SVD strategy: dense training + truncated SVD every step ----
        let mut w = w0.clone();
        let mut task = VisionTask::new("pets-like", CLASSES, 32, 0.6, 10, 233);
        let mut svd_acc = 0.0;
        for s in 0..steps {
            let (x, labels) = task.batch(batch);
            let xf = project(&x, batch, &proj);
            // decompose every step, run forward truncated to the SAME
            // rank budget as WSI (matched-K comparison)
            let d = svd(&w);
            let trunc = d.reconstruct(k);
            let logits = xf.matmul_nt(&trunc);
            let (_, acc, dy) = softmax_ce_grad(&logits, &labels);
            let dw = dy.matmul_tn(&xf); // dYᵀ X -> (O, I)
            for (p, g) in w.data.iter_mut().zip(&dw.data) {
                *p -= LR * (g + 1e-4 * *p);
            }
            if s >= steps - 10 {
                svd_acc += acc / 10.0;
            }
        }
        let svd_decomp = steps as f64 * svd_flops(CLASSES, DIM);
        let ratio = svd_decomp / wsi_decomp;
        ratios.push(ratio);
        t.row([
            format!("{eps}"),
            k.to_string(),
            format!("{:.3}", wsi_acc),
            format!("{:.3}", svd_acc),
            si(wsi_decomp),
            si(svd_decomp),
            format!("{ratio:.2}x"),
        ]);
    }
    let mut body = t.render();
    let mean_ratio = ratios.iter().sum::<f64>() / ratios.len() as f64;
    body.push_str(&format!(
        "\nMean decomposition-FLOPs ratio (SVD/WSI): {mean_ratio:.2}x — paper Fig. 3b\n\
         reports WSI needing ~1.36x fewer FLOPs at matched accuracy; accuracies\n\
         above should be comparable between the two strategies at each eps.\n"
    ));
    Ok(body)
}

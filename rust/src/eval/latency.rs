//! Latency/energy exhibits: Fig. 8, Tab. 2, Tab. 3, Tab. 4.
//!
//! Per-iteration train and inference wallclock is MEASURED on this host
//! through the compiled HLO executables, then projected to each edge
//! board with the calibrated roofline (DESIGN.md §3 substitution).  The
//! paper's claims are ratios (WASI vs vanilla, per ε), which transfer.

use std::time::Instant;

use anyhow::Result;

use crate::costmodel::{LayerDims, WasiRanks};
use crate::device::energy::iteration_energy;
use crate::device::latency::project_time;
use crate::device::spec::{device, DeviceSpec};
use crate::engine::{infer_engine, train_engine, NativeModelEngine, NodeTiming, TrainEngine};
use crate::runtime::ModelEntry;
use crate::util::table::Table;

use super::EvalCtx;

/// Measured per-iteration (infer_s, train_s) for a variant.
pub fn measure_iteration(ctx: &EvalCtx, entry: &ModelEntry, reps: usize) -> Result<(f64, f64)> {
    // Non-image input dims mean token ids (tinydec artifacts).
    let side = entry.image_side();
    let is_seq = side.is_none();
    let mut task = crate::data::synth::VisionTask::new(
        "bench", entry.classes, side.unwrap_or(32), 0.7, 8, 233);
    let mut step = train_engine(ctx.session.runtime(), entry, ctx.engine)?;
    let infer = infer_engine(ctx.session.runtime(), entry, ctx.engine)?;

    let make_batch = |task: &mut crate::data::synth::VisionTask| -> (Vec<f32>, Vec<f32>) {
        if is_seq {
            let mut t = crate::data::synth::SequenceTask::new(256, entry.input_dim, 1);
            let (x, y, _) = t.batch_onehot(entry.batch);
            (x, y)
        } else {
            let (x, y, _) = task.batch_onehot(entry.batch);
            (x, y)
        }
    };

    // Warmup both paths (compilation already cached by Runtime).
    let (x, y) = make_batch(&mut task);
    step.step(&x, &y, 0.01)?;
    infer.infer(step.params(), &x)?;

    let mut train_t = Vec::new();
    let mut infer_t = Vec::new();
    for _ in 0..reps {
        let (x, y) = make_batch(&mut task);
        let t0 = Instant::now();
        step.step(&x, &y, 0.01)?;
        train_t.push(t0.elapsed().as_secs_f64());
        let t1 = Instant::now();
        infer.infer(step.params(), &x)?;
        infer_t.push(t1.elapsed().as_secs_f64());
    }
    Ok((
        crate::util::stats::median(&infer_t),
        crate::util::stats::median(&train_t),
    ))
}

/// Arithmetic intensity estimate for projecting (compute-heavy transformer
/// steps are matmul bound; AI >> machine balance on all boards).
const AI: f64 = 64.0;

fn host_gflops(ctx: &EvalCtx) -> f64 {
    // cache a quick calibration per run
    let _ = ctx;
    crate::device::calibrate::measure_gflops(192, 2)
}

struct LatRow {
    name: String,
    eps: f64,
    infer_host: f64,
    train_host: f64,
}

fn measure_sweep(ctx: &EvalCtx) -> Result<Vec<LatRow>> {
    let reps = if ctx.quick { 3 } else { 5 };
    let mut rows = Vec::new();
    let mut names: Vec<String> = ctx
        .session
        .manifest()
        .models
        .keys()
        .filter(|n| {
            (n.starts_with("vit_wasi_eps") || n.starts_with("vit_asi_eps"))
                && !n.contains("kernel")
                && !n.contains("attn")
        })
        .cloned()
        .collect();
    names.push("vit_vanilla".into());
    if ctx.quick {
        names.retain(|n| n == "vit_vanilla" || n.ends_with("eps80"));
    }
    for name in names {
        let entry = ctx.session.manifest().model(&name)?.clone();
        let (i, t) = measure_iteration(ctx, &entry, reps)?;
        rows.push(LatRow {
            name,
            eps: entry.eps.unwrap_or(1.0),
            infer_host: i,
            train_host: t,
        });
    }
    rows.sort_by(|a, b| (a.name.clone(), a.eps).partial_cmp(&(b.name.clone(), b.eps)).unwrap());
    Ok(rows)
}

/// Fig. 8: train/infer time per iteration vs ε (host-measured + Pi-5
/// projection), WASI vs vanilla.
pub fn fig8(ctx: &EvalCtx) -> Result<String> {
    let rows = measure_sweep(ctx)?;
    let hg = host_gflops(ctx);
    let pi5 = device("raspberry-pi-5").unwrap();
    let mut t = Table::new([
        "variant", "eps", "infer host(ms)", "train host(ms)", "infer Pi5(s)", "train Pi5(s)", "train speedup",
    ])
    .title(format!("Fig 8 — per-iteration latency (host measured, {hg:.1} GF/s; Pi-5 roofline projection)"));
    let vanilla_train = rows
        .iter()
        .find(|r| r.name == "vit_vanilla")
        .map(|r| r.train_host)
        .unwrap_or(f64::NAN);
    for r in rows.iter().filter(|r| !r.name.starts_with("vit_asi")) {
        t.row([
            r.name.clone(),
            format!("{}", r.eps),
            format!("{:.0}", r.infer_host * 1e3),
            format!("{:.0}", r.train_host * 1e3),
            format!("{:.2}", project_time(r.infer_host, hg, &pi5, AI)),
            format!("{:.2}", project_time(r.train_host, hg, &pi5, AI)),
            format!("{:.2}x", vanilla_train / r.train_host),
        ]);
    }
    let mut body = t.render();
    body.push_str(
        "\nShape check (paper Fig. 8): WASI time grows with eps and sits below\n\
         vanilla at paper-scale layer dims (~1.4x even at eps=0.9).  NOTE: at\n\
         the tiny artifact scale (D=128) the subspace-iteration overhead can\n\
         exceed the matmul savings — the crossover the paper's Fig. 2 predicts.\n\
         The paper-scale check below uses the native engine at ViT-B dims:\n\n",
    );
    body.push_str(&native_vitb_comparison(ctx));
    // Per-node attribution through the graph executor's tags, on the
    // first variant the native engine can reconstruct (fall through to
    // the next candidate when reconstruction fails).
    for name in ["vit_wasi_eps80", "vit_vanilla"] {
        let Ok(entry) = ctx.session.manifest().model(name) else { continue };
        match node_attribution(entry, if ctx.quick { 2 } else { 4 }) {
            Ok(table) => {
                body.push('\n');
                body.push_str(&table);
                break;
            }
            Err(e) => {
                body.push_str(&format!("\n(node attribution for {name} skipped: {e:#})\n"));
            }
        }
    }
    Ok(body)
}

/// Run `steps` profiled training steps and return the graph executor's
/// per-node wallclock tags — no shape re-derivation, the tags come
/// straight from the layer-graph IR (`engine::graph`).  Shared by fig8
/// and `wasi-train bench` (which also feeds the same timings into
/// `BENCH_native.json`).
pub fn profile_nodes(entry: &ModelEntry, steps: usize) -> Result<Vec<NodeTiming>> {
    let mut eng = NativeModelEngine::load(entry)?;
    eng.set_profiling(true);
    let side = entry.image_side().ok_or_else(|| {
        anyhow::anyhow!("model {} is not an image model", entry.name)
    })?;
    let mut task =
        crate::data::synth::VisionTask::new("nodes", entry.classes, side, 0.7, 8, 233);
    let (x, y, _) = task.batch_onehot(entry.batch);
    eng.step(&x, &y, 0.01)?; // warmup
    eng.reset_timings();
    for _ in 0..steps.max(1) {
        eng.step(&x, &y, 0.01)?;
    }
    Ok(eng.node_timings())
}

/// Render the per-node attribution table from profiled tags.
pub fn render_node_table(model: &str, steps: usize, timings: &[NodeTiming]) -> String {
    let steps = steps.max(1);
    let mut t = Table::new(["node", "feat", "fwd ms/step", "bwd ms/step", "total ms/step"])
        .title(format!("per-node latency attribution ({model}, {steps} steps)"));
    let mut fwd_total = 0.0f64;
    let mut bwd_total = 0.0f64;
    for nt in timings {
        let fwd = nt.fwd_s / steps as f64 * 1e3;
        let bwd = nt.bwd_s / steps as f64 * 1e3;
        fwd_total += fwd;
        bwd_total += bwd;
        t.row([
            nt.label.clone(),
            nt.out_features.to_string(),
            format!("{fwd:.3}"),
            format!("{bwd:.3}"),
            format!("{:.3}", fwd + bwd),
        ]);
    }
    t.row([
        "TOTAL".into(),
        "-".into(),
        format!("{fwd_total:.3}"),
        format!("{bwd_total:.3}"),
        format!("{:.3}", fwd_total + bwd_total),
    ]);
    t.render()
}

/// Per-node latency attribution: profile + render in one call.
pub fn node_attribution(entry: &ModelEntry, steps: usize) -> Result<String> {
    let timings = profile_nodes(entry, steps)?;
    Ok(render_node_table(&entry.name, steps, &timings))
}

/// Native-engine measured per-layer iteration time at ViT-B/16 fc1 dims —
/// real wallclock at the scale where the paper's speedup claim lives.
fn native_vitb_comparison(ctx: &EvalCtx) -> String {
    use crate::linalg::tucker::Tensor;
    use crate::wasi::asi::AsiCompressor;
    use crate::wasi::layer::{DenseLayer, WasiLayer};
    use crate::wasi::wsi::{powerlaw, WsiFactors};

    let (b, n, i, o) = if ctx.quick {
        (4usize, 197usize, 768usize, 3072usize)
    } else {
        (8, 197, 768, 3072)
    };
    let dims = [b, n, i];
    let mut rng = crate::data::Pcg64::new(41);
    let x = Tensor::from_vec(&dims, rng.normal_vec(b * n * i));
    let w = powerlaw(o, i, 0.8, 42);
    let reps = if ctx.quick { 2 } else { 4 };

    let mut t = Table::new(["engine", "eps", "K", "fwd+bwd (ms)", "vs dense"])
        .title("Fig 8 (native, ViT-B fc1 dims, real wallclock)");
    let dense_t = {
        let mut ts = Vec::new();
        let mut d = DenseLayer::new(w.clone());
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let y = d.forward(&x);
            let dy = Tensor::from_vec(&y.shape, y.data.clone());
            let _ = d.backward(&dy);
            ts.push(t0.elapsed().as_secs_f64());
        }
        crate::util::stats::median(&ts)
    };
    t.row([
        "dense".into(),
        "1.0".into(),
        "-".into(),
        format!("{:.0}", dense_t * 1e3),
        "1.00x".into(),
    ]);

    for eps in [0.4f64, 0.8] {
        let l = LayerDims { b, n, i, o };
        let ranks = crate::eval::analytic::paper_scale_ranks(&l, eps);
        // Exact truncated factors straight from the powerlaw construction
        // (what init_svd would return, without a 3072x768 SVD).
        let (lmat, rmat, _) = crate::wasi::wsi::powerlaw_factored(o, i, 0.8, 42, ranks.k);
        let k = lmat.cols;
        let factors = WsiFactors { l: lmat, r: rmat };
        let asi = AsiCompressor::new(&dims, &[ranks.r[0], ranks.r[1], ranks.r[2]], 7);
        let mut wasi = WasiLayer::new(factors, asi);
        let mut ts = Vec::new();
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let y = wasi.forward(&x);
            let dy = Tensor::from_vec(&y.shape, y.data.clone());
            let _ = wasi.backward(&dy);
            wasi.factors.refresh();
            ts.push(t0.elapsed().as_secs_f64());
        }
        let wt = crate::util::stats::median(&ts);
        t.row([
            "WASI".into(),
            format!("{eps}"),
            k.to_string(),
            format!("{:.0}", wt * 1e3),
            format!("{:.2}x faster", dense_t / wt),
        ]);
    }
    t.render()
}

/// Tab. 2: WASI vs ASI vs vanilla per-iteration time at each ε.
pub fn tab2(ctx: &EvalCtx) -> Result<String> {
    let rows = measure_sweep(ctx)?;
    let hg = host_gflops(ctx);
    let pi5 = device("raspberry-pi-5").unwrap();
    let proj = |s: f64| project_time(s, hg, &pi5, AI);

    let mut t = Table::new([
        "eps", "WASI inf(s)", "WASI tr(s)", "ASI inf(s)", "ASI tr(s)", "Van inf(s)", "Van tr(s)",
    ])
    .title("Tab 2 — Pi-5-projected per-iteration time: WASI vs ASI vs vanilla");
    let vanilla = rows.iter().find(|r| r.name == "vit_vanilla");
    let mut eps_values: Vec<f64> = rows
        .iter()
        .filter(|r| r.name.starts_with("vit_wasi_eps"))
        .map(|r| r.eps)
        .collect();
    eps_values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    eps_values.dedup();
    for eps in eps_values {
        let wasi = rows.iter().find(|r| r.name.starts_with("vit_wasi_eps") && r.eps == eps);
        let asi = rows.iter().find(|r| r.name.starts_with("vit_asi_eps") && r.eps == eps);
        let f = |o: Option<&LatRow>, train: bool| -> String {
            o.map(|r| format!("{:.2}", proj(if train { r.train_host } else { r.infer_host })))
                .unwrap_or_else(|| "-".into())
        };
        t.row([
            format!("{eps}"),
            f(wasi, false),
            f(wasi, true),
            f(asi, false),
            f(asi, true),
            "-".into(),
            "-".into(),
        ]);
    }
    if let Some(v) = vanilla {
        t.row([
            "1.0".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{:.2}", proj(v.infer_host)),
            format!("{:.2}", proj(v.train_host)),
        ]);
    }
    let mut body = t.render();
    body.push_str(
        "\nShape checks (paper Tab. 2): WASI < ASI at every eps (ASI keeps dense\n\
         weights, so it pays the full forward); ASI approaches/exceeds vanilla\n\
         at high eps; WASI stays below vanilla throughout.\n",
    );
    Ok(body)
}

/// Tab. 3: latency across edge devices (projected).
pub fn tab3(ctx: &EvalCtx) -> Result<String> {
    let rows = measure_sweep(ctx)?;
    let hg = host_gflops(ctx);
    let boards = ["jetson-orin", "jetson-nano", "raspberry-pi-4"];
    let mut t = Table::new(["eps", "Orin inf/tr (s)", "Nano inf/tr (s)", "Pi4 inf/tr (s)"])
        .title("Tab 3 — WASI per-iteration latency projected across edge devices");
    let mut print_rows: Vec<&LatRow> = rows
        .iter()
        .filter(|r| r.name.starts_with("vit_wasi_eps") || r.name == "vit_vanilla")
        .collect();
    print_rows.sort_by(|a, b| a.eps.partial_cmp(&b.eps).unwrap());
    for r in print_rows {
        let mut cells = vec![format!("{}", r.eps)];
        for b in boards {
            let dev = device(b).unwrap();
            cells.push(format!(
                "{:.2} / {:.2}",
                project_time(r.infer_host, hg, &dev, AI),
                project_time(r.train_host, hg, &dev, AI)
            ));
        }
        t.row(cells);
    }
    let mut body = t.render();
    body.push_str(
        "\nShape check (paper Tab. 3): Orin fastest, Nano slowest; every board\n\
         shows the same monotone-in-eps WASI curve below its vanilla row (eps=1).\n",
    );
    Ok(body)
}

/// Tab. 4: energy on Jetson Orin per ε.
pub fn tab4(ctx: &EvalCtx) -> Result<String> {
    let rows = measure_sweep(ctx)?;
    let hg = host_gflops(ctx);
    let orin = device("jetson-orin").unwrap();
    let mut t = Table::new(["eps", "Inference Energy (J)", "Training Energy (J)"])
        .title("Tab 4 — Jetson Orin energy per iteration (power model x projected time)");
    let mut print_rows: Vec<&LatRow> = rows
        .iter()
        .filter(|r| r.name.starts_with("vit_wasi_eps") || r.name == "vit_vanilla")
        .collect();
    print_rows.sort_by(|a, b| a.eps.partial_cmp(&b.eps).unwrap());
    for r in print_rows {
        let ti = project_time(r.infer_host, hg, &orin, AI);
        let tt = project_time(r.train_host, hg, &orin, AI);
        t.row([
            format!("{}", r.eps),
            format!("{:.2}", iteration_energy(&orin, ti)),
            format!("{:.2}", iteration_energy(&orin, tt)),
        ]);
    }
    let mut body = t.render();
    body.push_str(
        "\nShape check (paper Tab. 4): energy rises monotonically with eps and the\n\
         vanilla row (eps=1) is the most expensive for both passes.\n",
    );
    Ok(body)
}

/// Analytic per-layer roofline breakdown used by the hotpath bench.
pub fn layer_roofline(dev: &DeviceSpec, l: &LayerDims, ranks: &WasiRanks) -> (f64, f64) {
    let w_vanilla = crate::device::latency::Workload {
        flops: l.vanilla_train_flops(),
        bytes: (l.vanilla_train_mem()) * 4.0,
    };
    let w_wasi = crate::device::latency::Workload {
        flops: l.wasi_train_flops(ranks),
        bytes: (l.wasi_train_mem(ranks)) * 4.0,
    };
    (
        crate::device::latency::phase_time(dev, &w_vanilla),
        crate::device::latency::phase_time(dev, &w_wasi),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::analytic::paper_scale_ranks;

    #[test]
    fn roofline_prefers_wasi() {
        let dev = device("raspberry-pi-5").unwrap();
        let l = LayerDims { b: 128, n: 197, i: 768, o: 3072 };
        let ranks = paper_scale_ranks(&l, 0.8);
        let (v, w) = layer_roofline(&dev, &l, &ranks);
        assert!(w < v, "wasi {w} vs vanilla {v}");
    }
}

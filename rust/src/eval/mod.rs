//! Evaluation harness: regenerates every table and figure in the paper's
//! evaluation section (see DESIGN.md §5 for the exhibit → module map).
//!
//! Each exhibit is a function `(ctx) -> Result<String>` returning the
//! rendered tables; `run` dispatches by name and `run_all` sweeps them.
//! Results are also appended as JSON under `ctx.out_dir` so EXPERIMENTS.md
//! can cite exact numbers.

pub mod analytic;
pub mod latency;
pub mod perf;
pub mod training;
pub mod wsi_vs_svd;

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::coordinator::Session;
use crate::engine::EngineKind;

/// Shared evaluation context.
pub struct EvalCtx {
    pub session: Session,
    pub out_dir: PathBuf,
    /// Fine-tune steps per accuracy point (paper: 50 epochs; here a few
    /// hundred steps of the tiny models reach their accuracy plateau).
    pub steps: usize,
    /// Samples per synthetic dataset.
    pub samples: usize,
    pub quick: bool,
    /// Execution engine for the fine-tuning exhibits (`--engine`).
    pub engine: EngineKind,
}

impl EvalCtx {
    pub fn open(artifacts: &str, out_dir: &str, steps: usize, quick: bool) -> Result<Self> {
        std::fs::create_dir_all(out_dir)?;
        Ok(EvalCtx {
            session: Session::open(artifacts)?,
            out_dir: PathBuf::from(out_dir),
            steps,
            samples: if quick { 256 } else { 512 },
            quick,
            engine: EngineKind::Auto,
        })
    }

    /// Select the execution engine for model exhibits.
    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    pub fn save(&self, name: &str, body: &str) -> Result<()> {
        let path = self.out_dir.join(format!("{name}.txt"));
        std::fs::write(&path, body)?;
        Ok(())
    }
}

pub const EXHIBITS: &[&str] = &[
    "fig2", "fig3a", "fig3b", "fig4", "fig5", "fig6", "fig7", "fig8",
    "fig9", "fig10", "fig11", "fig12", "tab1", "tab2", "tab3", "tab4",
];

/// Run one exhibit by name.
pub fn run(ctx: &EvalCtx, name: &str) -> Result<String> {
    let body = match name {
        "fig2" => analytic::fig2(ctx)?,
        "fig3a" => training::fig3a(ctx)?,
        "fig3b" => wsi_vs_svd::fig3b(ctx)?,
        "fig4" => analytic::fig4(ctx)?,
        "fig5" => training::fig5(ctx)?,
        "fig6" => training::fig6(ctx)?,
        "fig7" => training::fig7(ctx)?,
        "fig8" => latency::fig8(ctx)?,
        "fig9" => training::fig9(ctx)?,
        "fig10" => training::fig10(ctx)?,
        "fig11" => training::fig11(ctx)?,
        "fig12" => analytic::fig12(ctx)?,
        "tab1" => analytic::tab1(ctx)?,
        "tab2" => latency::tab2(ctx)?,
        "tab3" => latency::tab3(ctx)?,
        "tab4" => latency::tab4(ctx)?,
        _ => return Err(anyhow!("unknown exhibit {name:?}; known: {EXHIBITS:?}")),
    };
    ctx.save(name, &body)?;
    Ok(body)
}

/// Run every exhibit, concatenating reports (used by `eval all` and the
/// paper_eval bench).
pub fn run_all(ctx: &EvalCtx) -> Result<String> {
    let mut out = String::new();
    for name in EXHIBITS {
        out.push_str(&format!("\n################ {name} ################\n"));
        match run(ctx, name) {
            Ok(body) => out.push_str(&body),
            Err(e) => out.push_str(&format!("ERROR: {e:#}\n")),
        }
    }
    Ok(out)
}

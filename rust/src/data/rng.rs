//! PCG64 (XSL-RR) pseudo-random generator + Box-Muller normals.
//!
//! Deterministic across platforms; everything data-related in the repo
//! seeds one of these so runs are exactly reproducible (the paper fixes
//! seed 233 for the same reason, App. B.2).

/// PCG-XSL-RR 128/64.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    cached_normal: Option<f32>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((seed as u128) << 1) | 1,
            cached_normal: None,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(0xcafe_f00d_d15e_a5e5_u128 ^ (seed as u128));
        rng.next_u64();
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (caches the second deviate).
    pub fn next_normal(&mut self) -> f32 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let mut u1 = self.next_f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some((r * theta.sin()) as f32);
        (r * theta.cos()) as f32
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_normal()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut rng = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(9);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}

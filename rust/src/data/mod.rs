//! Synthetic datasets + deterministic RNG (the paper's CIFAR/CUB/Flowers/
//! Pets/BoolQ stand-ins; see DESIGN.md §3 for the substitution argument).

pub mod loader;
pub mod rng;
pub mod synth;

pub use loader::Loader;
pub use rng::Pcg64;
pub use synth::{SequenceTask, VisionTask, DATASET_PRESETS};

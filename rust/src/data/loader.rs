//! Epoch-based loader over a materialized synthetic dataset.
//!
//! The paper fine-tunes on a fixed 80/20 train/val split for 50 epochs
//! (App. B.1); this loader materializes `n` samples once, then serves
//! shuffled mini-batches per epoch and a fixed validation set.

use super::rng::Pcg64;
use super::synth::VisionTask;

pub struct Loader {
    pub dim: usize,
    pub classes: usize,
    train_x: Vec<f32>,
    train_y: Vec<usize>,
    val_x: Vec<f32>,
    val_y: Vec<usize>,
    order: Vec<usize>,
    cursor: usize,
    rng: Pcg64,
}

impl Loader {
    /// Materialize `n` samples from a task, 80/20 split.
    pub fn from_task(task: &mut VisionTask, n: usize, seed: u64) -> Self {
        let (x, y) = task.batch(n);
        let dim = task.dim;
        let n_train = n * 4 / 5;
        let order: Vec<usize> = (0..n_train).collect();
        Loader {
            dim,
            classes: task.classes,
            train_x: x[..n_train * dim].to_vec(),
            train_y: y[..n_train].to_vec(),
            val_x: x[n_train * dim..].to_vec(),
            val_y: y[n_train..].to_vec(),
            order,
            cursor: 0,
            rng: Pcg64::new(seed ^ 0x10ad),
        }
    }

    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn val_len(&self) -> usize {
        self.val_y.len()
    }

    /// Next shuffled train mini-batch as (x, y_onehot).  Reshuffles and
    /// wraps at epoch boundaries; always returns exactly `batch` samples.
    pub fn next_batch(&mut self, batch: usize) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(batch * self.dim);
        let mut y = vec![0.0f32; batch * self.classes];
        for i in 0..batch {
            if self.cursor == 0 {
                self.rng.shuffle(&mut self.order);
            }
            let idx = self.order[self.cursor];
            self.cursor = (self.cursor + 1) % self.order.len();
            x.extend_from_slice(&self.train_x[idx * self.dim..(idx + 1) * self.dim]);
            y[i * self.classes + self.train_y[idx]] = 1.0;
        }
        (x, y)
    }

    /// Validation batches (fixed order), padded by wrapping.
    pub fn val_batch(&self, start: usize, batch: usize) -> (Vec<f32>, Vec<usize>) {
        let n = self.val_y.len();
        let mut x = Vec::with_capacity(batch * self.dim);
        let mut y = Vec::with_capacity(batch);
        for i in 0..batch {
            let idx = (start + i) % n;
            x.extend_from_slice(&self.val_x[idx * self.dim..(idx + 1) * self.dim]);
            y.push(self.val_y[idx]);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_80_20() {
        let mut task = VisionTask::preset("cifar10-like", 1).unwrap();
        let loader = Loader::from_task(&mut task, 100, 1);
        assert_eq!(loader.train_len(), 80);
        assert_eq!(loader.val_len(), 20);
    }

    #[test]
    fn batches_have_exact_size() {
        let mut task = VisionTask::preset("cifar10-like", 2).unwrap();
        let mut loader = Loader::from_task(&mut task, 50, 2);
        for _ in 0..7 {
            let (x, y) = loader.next_batch(16);
            assert_eq!(x.len(), 16 * loader.dim);
            assert_eq!(y.len(), 16 * loader.classes);
        }
    }

    #[test]
    fn epoch_covers_all_samples() {
        let mut task = VisionTask::preset("cifar10-like", 3).unwrap();
        let mut loader = Loader::from_task(&mut task, 40, 3);
        // one epoch = 32 train samples; collect two batches of 16
        let mut seen: Vec<f32> = Vec::new();
        for _ in 0..2 {
            seen.extend(loader.next_batch(16).0);
        }
        // all 32 distinct samples appear exactly once: compare first elems
        let mut firsts: Vec<i64> = seen
            .chunks(loader.dim)
            .map(|c| (c[0] * 1e6) as i64)
            .collect();
        firsts.sort();
        firsts.dedup();
        assert_eq!(firsts.len(), 32);
    }
}

//! Synthetic fine-tuning datasets (the CIFAR/CUB/Flowers/Pets/BoolQ
//! stand-ins, DESIGN.md §3).
//!
//! Each vision "dataset" draws per-class low-rank templates in pixel
//! space and emits `template[label] + sigma * noise`.  The low-rank class
//! structure is what gives activation maps the concentrated spectra the
//! paper measures (Fig. 4); difficulty is controlled by sigma, the
//! number of classes, and the template rank.  Presets mirror the paper's
//! five downstream datasets in relative difficulty.

use super::rng::Pcg64;

/// A named dataset preset: (name, classes, sigma, template_rank).
pub const DATASET_PRESETS: &[(&str, usize, f32, usize)] = &[
    ("cifar10-like", 10, 0.7, 8),
    ("cifar100-like", 100, 0.55, 12),
    ("cub-like", 200, 0.45, 16),
    ("flowers-like", 102, 0.5, 12),
    ("pets-like", 37, 0.6, 10),
];

/// Synthetic image-classification task emitting flat (image²·3,) samples.
pub struct VisionTask {
    pub name: String,
    pub classes: usize,
    pub dim: usize,
    sigma: f32,
    templates: Vec<f32>, // (classes, dim) row-major
    rng: Pcg64,
}

impl VisionTask {
    pub fn new(
        name: &str,
        classes: usize,
        image: usize,
        sigma: f32,
        template_rank: usize,
        seed: u64,
    ) -> Self {
        let dim = image * image * 3;
        let mut rng = Pcg64::new(seed);
        // templates = coefs (classes x rank) @ basis (rank x dim), unit RMS rows
        let basis: Vec<f32> = rng.normal_vec(template_rank * dim);
        let coefs: Vec<f32> = rng.normal_vec(classes * template_rank);
        let mut templates = vec![0.0f32; classes * dim];
        for c in 0..classes {
            for k in 0..template_rank {
                let w = coefs[c * template_rank + k];
                let row = &basis[k * dim..(k + 1) * dim];
                let out = &mut templates[c * dim..(c + 1) * dim];
                for (o, b) in out.iter_mut().zip(row) {
                    *o += w * b;
                }
            }
            let row = &mut templates[c * dim..(c + 1) * dim];
            let rms = (row.iter().map(|x| (x * x) as f64).sum::<f64>()
                / dim as f64)
                .sqrt()
                .max(1e-9) as f32;
            for x in row.iter_mut() {
                *x /= rms;
            }
        }
        VisionTask {
            name: name.to_string(),
            classes,
            dim,
            sigma,
            templates,
            rng,
        }
    }

    /// Instantiate one of the named presets at 32x32.
    pub fn preset(name: &str, seed: u64) -> Option<Self> {
        DATASET_PRESETS
            .iter()
            .find(|(n, _, _, _)| *n == name)
            .map(|&(n, classes, sigma, rank)| Self::new(n, classes, 32, sigma, rank, seed))
    }

    /// Emit a batch: (x flat (n*dim), labels (n)).
    pub fn batch(&mut self, n: usize) -> (Vec<f32>, Vec<usize>) {
        let mut x = vec![0.0f32; n * self.dim];
        let mut labels = vec![0usize; n];
        for i in 0..n {
            let c = self.rng.below(self.classes);
            labels[i] = c;
            let t = &self.templates[c * self.dim..(c + 1) * self.dim];
            let out = &mut x[i * self.dim..(i + 1) * self.dim];
            for (o, &tv) in out.iter_mut().zip(t) {
                *o = tv + self.sigma * self.rng.next_normal();
            }
        }
        (x, labels)
    }

    /// Batch with one-hot labels appended (the train-step input format).
    pub fn batch_onehot(&mut self, n: usize) -> (Vec<f32>, Vec<f32>, Vec<usize>) {
        let (x, labels) = self.batch(n);
        let mut y = vec![0.0f32; n * self.classes];
        for (i, &c) in labels.iter().enumerate() {
            y[i * self.classes + c] = 1.0;
        }
        (x, y, labels)
    }
}

/// BoolQ-like yes/no sequence task: the label is decided by which of two
/// marker motifs is embedded in the token stream.
pub struct SequenceTask {
    pub vocab: usize,
    pub seq: usize,
    motifs: [[usize; 4]; 2],
    rng: Pcg64,
}

impl SequenceTask {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let mut motifs = [[0usize; 4]; 2];
        for m in motifs.iter_mut() {
            for t in m.iter_mut() {
                *t = 1 + rng.below(vocab - 1);
            }
        }
        SequenceTask { vocab, seq, motifs, rng }
    }

    /// Emit (tokens as f32 (n*seq), y_onehot (n*2), labels).
    pub fn batch_onehot(&mut self, n: usize) -> (Vec<f32>, Vec<f32>, Vec<usize>) {
        let mut x = vec![0.0f32; n * self.seq];
        let mut y = vec![0.0f32; n * 2];
        let mut labels = vec![0usize; n];
        for i in 0..n {
            let label = self.rng.below(2);
            labels[i] = label;
            y[i * 2 + label] = 1.0;
            let row = &mut x[i * self.seq..(i + 1) * self.seq];
            for t in row.iter_mut() {
                *t = self.rng.below(self.vocab) as f32;
            }
            let pos = self.rng.below(self.seq - 4);
            for (j, &tok) in self.motifs[label].iter().enumerate() {
                row[pos + j] = tok as f32;
            }
        }
        (x, y, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist() {
        for (name, classes, _, _) in DATASET_PRESETS {
            let task = VisionTask::preset(name, 1).unwrap();
            assert_eq!(task.classes, *classes);
            assert_eq!(task.dim, 32 * 32 * 3);
        }
    }

    #[test]
    fn batch_shapes_and_labels() {
        let mut t = VisionTask::preset("cifar10-like", 5).unwrap();
        let (x, y, labels) = t.batch_onehot(8);
        assert_eq!(x.len(), 8 * 3072);
        assert_eq!(y.len(), 8 * 10);
        for (i, &c) in labels.iter().enumerate() {
            assert!(c < 10);
            assert_eq!(y[i * 10 + c], 1.0);
            assert_eq!(y[i * 10..(i + 1) * 10].iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn same_seed_same_data() {
        let mut a = VisionTask::preset("pets-like", 233).unwrap();
        let mut b = VisionTask::preset("pets-like", 233).unwrap();
        assert_eq!(a.batch(4).0, b.batch(4).0);
    }

    #[test]
    fn class_templates_are_distinguishable() {
        // Same-class samples must be closer than cross-class on average.
        let mut t = VisionTask::new("x", 2, 8, 0.3, 4, 9);
        let (x, labels) = t.batch(64);
        let dim = t.dim;
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(p, q)| ((p - q) * (p - q)) as f64).sum()
        };
        let (mut same, mut cross, mut ns, mut nc) = (0.0, 0.0, 0, 0);
        for i in 0..16 {
            for j in (i + 1)..16 {
                let d = dist(&x[i * dim..(i + 1) * dim], &x[j * dim..(j + 1) * dim]);
                if labels[i] == labels[j] {
                    same += d;
                    ns += 1;
                } else {
                    cross += d;
                    nc += 1;
                }
            }
        }
        if ns > 0 && nc > 0 {
            assert!(same / ns as f64 <= cross / nc as f64);
        }
    }

    #[test]
    fn sequence_task_marks_motifs() {
        let mut t = SequenceTask::new(64, 16, 3);
        let (x, y, labels) = t.batch_onehot(10);
        assert_eq!(x.len(), 160);
        assert_eq!(y.len(), 20);
        assert!(labels.iter().all(|&l| l < 2));
    }
}

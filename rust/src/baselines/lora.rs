//! LoRA baseline: Y = X Wᵀ + X Aᵀ Bᵀ with frozen W, trainable A (r, I),
//! B (O, r).  Training memory = full W + adapters + full activations;
//! inference = merged (identical to vanilla) — the §2 "Low-rank Adapters"
//! drawbacks WASI is contrasted against.

use crate::data::rng::Pcg64;
use crate::linalg::matrix::Mat;
use crate::linalg::tucker::Tensor;

pub struct LoraLayer {
    pub w: Mat,       // frozen (O, I)
    pub a: Mat,       // (r, I)
    pub b: Mat,       // (O, r)
    pub alpha: f32,
    saved_x: Option<Tensor>,
}

impl LoraLayer {
    /// Standard init: A ~ N(0, 1/r), B = 0 (adapter starts as identity).
    pub fn new(w: Mat, rank: usize, alpha: f32, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let i = w.cols;
        let o = w.rows;
        let mut a = Mat::random(rank, i, &mut rng);
        a.scale(1.0 / (rank as f32).sqrt());
        LoraLayer { w, a, b: Mat::zeros(o, rank), alpha, saved_x: None }
    }

    fn scale(&self) -> f32 {
        self.alpha / self.a.rows as f32
    }

    pub fn forward(&mut self, x: &Tensor) -> Tensor {
        let i = *x.shape.last().unwrap();
        let rows = x.numel() / i;
        let xf = Mat::from_vec(rows, i, x.data.clone());
        let mut y = xf.matmul_nt(&self.w);
        let xa = xf.matmul_nt(&self.a); // (rows, r)
        let xab = xa.matmul_nt(&self.b); // (rows, O)
        let s = self.scale();
        for (yv, &dv) in y.data.iter_mut().zip(&xab.data) {
            *yv += s * dv;
        }
        self.saved_x = Some(x.clone());
        let mut shape = x.shape.clone();
        *shape.last_mut().unwrap() = self.w.rows;
        Tensor::from_vec(&shape, y.data)
    }

    /// Returns (dX, dA, dB); W is frozen.
    pub fn backward(&mut self, dy: &Tensor) -> (Tensor, Mat, Mat) {
        let x = self.saved_x.take().expect("forward before backward");
        let i = *x.shape.last().unwrap();
        let o = self.w.rows;
        let rows = x.numel() / i;
        let xf = Mat::from_vec(rows, i, x.data.clone());
        let dyf = Mat::from_vec(rows, o, dy.data.clone());
        let s = self.scale();
        // dB = s · dYᵀ (X Aᵀ)
        let xa = xf.matmul_nt(&self.a);
        let mut db = dyf.matmul_tn(&xa);
        db.scale(s);
        // dA = s · (Bᵀ dY)ᵀ X = s · (dY B)ᵀ X
        let dyb = dyf.matmul(&self.b); // (rows, r)
        let mut da = dyb.matmul_tn(&xf); // (r, I)
        da.scale(s);
        // dX = dY W + s · dY B A
        let mut dx = dyf.matmul(&self.w);
        let dyba = dyb.matmul(&self.a);
        for (d, &v) in dx.data.iter_mut().zip(&dyba.data) {
            *d += s * v;
        }
        (Tensor::from_vec(&x.shape, dx.data), da, db)
    }

    pub fn sgd(&mut self, da: &Mat, db: &Mat, lr: f32) {
        for (p, g) in self.a.data.iter_mut().zip(&da.data) {
            *p -= lr * g;
        }
        for (p, g) in self.b.data.iter_mut().zip(&db.data) {
            *p -= lr * g;
        }
    }

    /// Training weight-memory (elements): frozen W + both adapters.
    pub fn weight_elems(&self) -> usize {
        self.w.data.len() + self.a.data.len() + self.b.data.len()
    }

    /// Merge the adapter into W (inference deployment — same cost as vanilla).
    pub fn merge(&self) -> Mat {
        let mut w = self.w.clone();
        let s = self.scale();
        let ba = self.b.matmul(&self.a); // (O, I)
        for (p, &d) in w.data.iter_mut().zip(&ba.data) {
            *p += s * d;
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_as_identity_adapter() {
        let mut rng = Pcg64::new(1);
        let w = Mat::random(6, 8, &mut rng);
        let mut l = LoraLayer::new(w.clone(), 2, 16.0, 2);
        let x = Tensor::from_vec(&[2, 3, 8], rng.normal_vec(48));
        let y = l.forward(&x);
        let mut dense = crate::wasi::layer::DenseLayer::new(w);
        let yd = dense.forward(&x);
        for (a, b) in y.data.iter().zip(&yd.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn memory_exceeds_vanilla() {
        let mut rng = Pcg64::new(3);
        let w = Mat::random(16, 16, &mut rng);
        let l = LoraLayer::new(w, 4, 16.0, 4);
        assert!(l.weight_elems() > 16 * 16);
    }

    #[test]
    fn adapter_learns_residual() {
        // Teach the adapter to cancel W (target = 0 map).
        let mut rng = Pcg64::new(5);
        let w = Mat::random(4, 6, &mut rng);
        let mut l = LoraLayer::new(w, 4, 8.0, 6);
        let x = Tensor::from_vec(&[8, 1, 6], rng.normal_vec(48));
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..200 {
            let y = l.forward(&x);
            let loss: f64 = y.data.iter().map(|v| (v * v) as f64).sum();
            let dy = Tensor::from_vec(&y.shape, y.data.iter().map(|v| 2.0 * v).collect());
            let (_, da, db) = l.backward(&dy);
            l.sgd(&da, &db, 0.003);
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.5, "{last} vs {first:?}");
    }

    #[test]
    fn merge_matches_forward() {
        let mut rng = Pcg64::new(7);
        let w = Mat::random(5, 7, &mut rng);
        let mut l = LoraLayer::new(w, 3, 16.0, 8);
        // random adapters
        l.a = Mat::random(3, 7, &mut rng);
        l.b = Mat::random(5, 3, &mut rng);
        let x = Tensor::from_vec(&[1, 4, 7], rng.normal_vec(28));
        let y = l.forward(&x);
        let merged = l.merge();
        let mut dense = crate::wasi::layer::DenseLayer::new(merged);
        let ym = dense.forward(&x);
        for (a, b) in y.data.iter().zip(&ym.data) {
            assert!((a - b).abs() < 1e-3);
        }
    }
}

//! ASI-only baseline (Nguyen et al. 2025): dense weights + ASI-compressed
//! activations.  Saves training activation memory like WASI, but keeps
//! the full architecture — inference is identical to vanilla, and at high
//! ε the per-iteration subspace-iteration overhead makes training SLOWER
//! than vanilla (paper Tab. 2's ASI column).

use crate::linalg::matrix::Mat;
use crate::linalg::tucker::Tensor;
use crate::wasi::asi::AsiCompressor;
use crate::wasi::lowrank_grad::lowrank_grad_3d;

pub struct AsiOnlyLayer {
    pub w: Mat, // (O, I), dense
    pub asi: AsiCompressor,
    saved: Option<crate::wasi::asi::CompressedActivation>,
}

impl AsiOnlyLayer {
    pub fn new(w: Mat, asi: AsiCompressor) -> Self {
        AsiOnlyLayer { w, asi, saved: None }
    }

    /// Dense forward (Eq. 1) but stores only the compressed activation.
    pub fn forward(&mut self, x: &Tensor) -> Tensor {
        let i = *x.shape.last().unwrap();
        let rows = x.numel() / i;
        let xf = Mat::from_vec(rows, i, x.data.clone());
        let y = xf.matmul_nt(&self.w);
        self.saved = Some(self.asi.compress(x));
        let mut shape = x.shape.clone();
        *shape.last_mut().unwrap() = self.w.rows;
        Tensor::from_vec(&shape, y.data)
    }

    /// dW from the compressed activation (f_LR with the full dY — the
    /// original Eqs. 15-18 orientation); dX = dY W exactly.
    pub fn backward(&mut self, dy: &Tensor) -> (Tensor, Mat) {
        let c = self.saved.take().expect("forward before backward");
        let o = self.w.rows;
        let rows = dy.numel() / o;
        let dyf = Mat::from_vec(rows, o, dy.data.clone());
        let dx = dyf.matmul(&self.w);
        let dw = lowrank_grad_3d(&c.core, &c.factors[0], &c.factors[1], &c.factors[2], dy);
        let mut xshape = dy.shape.clone();
        *xshape.last_mut().unwrap() = self.w.cols;
        (Tensor::from_vec(&xshape, dx.data), dw)
    }

    pub fn sgd(&mut self, dw: &Mat, lr: f32, wd: f32) {
        for (p, g) in self.w.data.iter_mut().zip(&dw.data) {
            *p -= lr * (g + wd * *p);
        }
    }

    pub fn saved_bytes(&self) -> usize {
        self.saved
            .as_ref()
            .map(|c| (c.core.numel() + c.factors.iter().map(|f| f.data.len()).sum::<usize>()) * 4)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;
    use crate::wasi::wsi::powerlaw;

    #[test]
    fn grads_approach_dense_as_ranks_grow() {
        let mut rng = Pcg64::new(1);
        let dims = [6usize, 10, 16];
        let x = Tensor::from_vec(&dims, rng.normal_vec(dims.iter().product()));
        let dy = Tensor::from_vec(&[6, 10, 12], rng.normal_vec(720));
        let w = powerlaw(12, 16, 1.0, 2);

        let mut errs = Vec::new();
        for ranks in [[2usize, 3, 4], [4, 6, 8], [6, 10, 16]] {
            let mut layer = AsiOnlyLayer::new(w.clone(), AsiCompressor::new(&dims, &ranks, 3));
            // burn in bases
            for _ in 0..4 {
                layer.forward(&x);
                layer.saved = Some(layer.asi.compress(&x));
            }
            layer.forward(&x);
            let (_, dw) = layer.backward(&dy);
            let exact = crate::wasi::lowrank_grad::dense_grad(&x, &dy);
            let err = dw.sub(&exact).frob_norm() / exact.frob_norm();
            errs.push(err);
        }
        assert!(errs[0] > errs[2], "errors {errs:?}");
        assert!(errs[2] < 1e-3, "full-rank error {}", errs[2]);
    }

    #[test]
    fn memory_less_than_dense_activation() {
        let dims = [8usize, 32, 64];
        let mut rng = Pcg64::new(4);
        let x = Tensor::from_vec(&dims, rng.normal_vec(dims.iter().product()));
        let w = powerlaw(48, 64, 1.0, 5);
        let mut layer = AsiOnlyLayer::new(w, AsiCompressor::new(&dims, &[4, 8, 12], 6));
        layer.forward(&x);
        assert!(layer.saved_bytes() < x.numel() * 4);
    }
}

//! SVD-LLM baseline (Wang et al. 2024; paper App. A.4).
//!
//! Truncation-aware data whitening: S = chol(X Xᵀ) over a calibration
//! activation X (summed over batch), SVD of W S, truncate to K, split as
//!   W'(u) = U_K Σ_K^{1/2},   W'(v) = Σ_K^{1/2} V_Kᵀ S⁻¹,
//! then fine-tune with LoRA adapters on top (α=16, r=8 — the paper's
//! setup, App. B.1).  Only defined for 3D activations: `whiten` takes the
//! (N, I) batch-summed activation and there is deliberately no 4D path
//! (that is the Appendix-A.4 limitation WASI escapes; `fig11`/`fig6`
//! exclude SVD-LLM for SwinLite exactly like the paper does).

use anyhow::{Context, Result};

use crate::linalg::cholesky::{cholesky, invert_lower};
use crate::linalg::matrix::Mat;
use crate::linalg::svd::svd;

/// The compressed pair (W'(u), W'(v)) with W̃ = W'(u) W'(v).
#[derive(Debug, Clone)]
pub struct SvdLlmFactors {
    pub wu: Mat, // (O, K)
    pub wv: Mat, // (K, I)
}

/// Whitening matrix S from a calibration activation X (N, I):
/// S = cholesky(Xᵀ X + λI)  (λ ridge for numerical PD).
pub fn whiten(x: &Mat, ridge: f32) -> Result<Mat> {
    let mut g = x.matmul_tn(x); // (I, I)
    for i in 0..g.rows {
        *g.at_mut(i, i) += ridge;
    }
    cholesky(&g).context("whitening Gram not PD")
}

/// Compress W (O, I) at target rank K with whitening S (paper Eqs. 47-48).
pub fn compress(w: &Mat, s: &Mat, k: usize) -> SvdLlmFactors {
    let ws = w.matmul(s); // (O, I)
    let d = svd(&ws);
    let k = k.min(d.s.len());
    let (o, i) = (w.rows, w.cols);
    let mut wu = Mat::zeros(o, k);
    let mut wv_pre = Mat::zeros(k, i);
    for j in 0..k {
        let sq = d.s[j].max(0.0).sqrt();
        for r in 0..o {
            wu.data[r * k + j] = d.u.at(r, j) * sq;
        }
        for c in 0..i {
            wv_pre.data[j * i + c] = sq * d.vt.at(j, c);
        }
    }
    // W'(v) = Σ^{1/2} V_Kᵀ S⁻¹
    let s_inv = invert_lower(s);
    let wv = wv_pre.matmul(&s_inv);
    SvdLlmFactors { wu, wv }
}

impl SvdLlmFactors {
    pub fn k(&self) -> usize {
        self.wu.cols
    }

    pub fn materialize(&self) -> Mat {
        self.wu.matmul(&self.wv)
    }

    /// Weight memory in elements (the two factors).
    pub fn weight_elems(&self) -> usize {
        self.wu.data.len() + self.wv.data.len()
    }
}

/// Rank for a target compression ratio (the paper drives SVD-LLM by the
/// ratios WASI achieves at each ε, App. B.1).
pub fn rank_for_ratio(o: usize, i: usize, ratio: f64) -> usize {
    // K (O + I) = O I / ratio  =>  K = O I / (ratio (O + I))
    (((o * i) as f64 / (ratio * (o + i) as f64)).floor() as usize).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;
    use crate::wasi::wsi::powerlaw;

    #[test]
    fn whitening_makes_transformed_activation_orthonormalish() {
        let mut rng = Pcg64::new(1);
        let x = Mat::random(40, 8, &mut rng); // (N, I)
        let s = whiten(&x, 1e-3).unwrap();
        // (X S⁻ᵀ) should have identity Gram: Xᵀ X = S Sᵀ.
        let g = x.matmul_tn(&x);
        let rec = s.matmul_nt(&s);
        for (a, b) in g.data.iter().zip(&rec.data) {
            assert!((a - b).abs() < 1e-2 * g.frob_norm(), "{a} vs {b}");
        }
    }

    #[test]
    fn full_rank_compress_reconstructs() {
        let mut rng = Pcg64::new(2);
        let w = Mat::random(10, 8, &mut rng);
        let x = Mat::random(30, 8, &mut rng);
        let s = whiten(&x, 1e-3).unwrap();
        let f = compress(&w, &s, 8);
        let rec = f.materialize();
        let rel = rec.sub(&w).frob_norm() / w.frob_norm();
        assert!(rel < 1e-2, "rel {rel}");
    }

    #[test]
    fn truncation_error_grows_as_rank_falls() {
        let w = powerlaw(24, 20, 1.0, 3);
        let mut rng = Pcg64::new(4);
        let x = Mat::random(50, 20, &mut rng);
        let s = whiten(&x, 1e-3).unwrap();
        let mut prev = 0.0f32;
        for k in [20usize, 10, 4, 2] {
            let f = compress(&w, &s, k);
            let rel = f.materialize().sub(&w).frob_norm() / w.frob_norm();
            assert!(rel >= prev - 1e-4, "k={k}: {rel} < {prev}");
            prev = rel;
        }
    }

    #[test]
    fn ratio_rank_math() {
        let k = rank_for_ratio(3072, 768, 4.0);
        // K(O+I)*4 == O*I  =>  K = 3072*768/(4*3840) = 153.6 -> 153
        assert_eq!(k, 153);
        assert!(rank_for_ratio(8, 8, 1000.0) >= 1);
    }
}

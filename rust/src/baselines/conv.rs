//! Minimal conv substrate for the Fig. 12 WSI-on-convolution study
//! (MCUNet-like tail).  A conv layer's weight (O, I, k, k) is reshaped to
//! (O, I·k·k) and WSI factorization applies verbatim; the forward runs as
//! im2col + matmul — exactly how the compact-CNN on-device stacks the
//! paper cites implement conv on CPUs.

use crate::linalg::matrix::Mat;

/// im2col for NHWC input, stride 1, same padding, square kernel k.
pub fn im2col(x: &[f32], h: usize, w: usize, c: usize, k: usize) -> Mat {
    let pad = k / 2;
    let rows = h * w;
    let cols = c * k * k;
    let mut out = Mat::zeros(rows, cols);
    for oy in 0..h {
        for ox in 0..w {
            let row = oy * w + ox;
            let mut col = 0;
            for ky in 0..k {
                for kx in 0..k {
                    let iy = oy as isize + ky as isize - pad as isize;
                    let ix = ox as isize + kx as isize - pad as isize;
                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                        let base = ((iy as usize) * w + ix as usize) * c;
                        for ch in 0..c {
                            out.data[row * cols + col + ch] = x[base + ch];
                        }
                    }
                    col += c;
                }
            }
        }
    }
    out
}

/// Conv layer with a WSI-factorable weight.
pub struct ConvLayer {
    pub weight: Mat, // (O, I*k*k)
    pub k: usize,
    pub c_in: usize,
}

impl ConvLayer {
    pub fn new(weight: Mat, k: usize, c_in: usize) -> Self {
        assert_eq!(weight.cols, c_in * k * k);
        ConvLayer { weight, k, c_in }
    }

    /// Forward for one NHWC image; returns (H*W, O) feature map.
    pub fn forward(&self, x: &[f32], h: usize, w: usize) -> Mat {
        let cols = im2col(x, h, w, self.c_in, self.k);
        cols.matmul_nt(&self.weight)
    }

    /// Factored forward through WSI factors (L, R) of the reshaped weight.
    pub fn forward_factored(&self, x: &[f32], h: usize, w: usize,
                            l: &Mat, r: &Mat) -> Mat {
        let cols = im2col(x, h, w, self.c_in, self.k);
        let hmid = cols.matmul_nt(r); // (H*W, K)
        hmid.matmul_nt(l)             // (H*W, O)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;
    use crate::wasi::wsi::{powerlaw, WsiFactors};

    #[test]
    fn im2col_identity_kernel() {
        // k=1: im2col is the identity layout.
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect(); // 2x2x3
        let m = im2col(&x, 2, 2, 3, 1);
        assert_eq!(m.rows, 4);
        assert_eq!(m.cols, 3);
        assert_eq!(m.data, x);
    }

    #[test]
    fn conv_matches_direct_3x3() {
        // hand-check one output pixel of a 3x3 conv on a 3x3 single-channel image
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let w = Mat::from_vec(1, 9, vec![0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        let conv = ConvLayer::new(w, 3, 1);
        let y = conv.forward(&x, 3, 3);
        // identity kernel: output == input
        for (a, b) in y.data.iter().zip(&x) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn factored_conv_close_at_high_eps() {
        let mut rng = Pcg64::new(1);
        let c_in = 4;
        let k = 3;
        let w = powerlaw(8, c_in * k * k, 1.2, 2);
        let conv = ConvLayer::new(w.clone(), k, c_in);
        let (f, _) = WsiFactors::init_svd(&w, 0.99);
        let x: Vec<f32> = rng.normal_vec(6 * 6 * c_in);
        let exact = conv.forward(&x, 6, 6);
        let fact = conv.forward_factored(&x, 6, 6, &f.l, &f.r);
        let rel = fact.sub(&exact).frob_norm() / exact.frob_norm();
        assert!(rel < 0.15, "rel {rel}");
    }
}

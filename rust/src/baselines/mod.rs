//! Comparator methods the paper evaluates against (§4.1):
//!
//! * `vanilla`  — dense training (the DenseLayer lives in wasi::layer;
//!   re-exported here for symmetry), Eqs. 1-3.
//! * `lora`     — frozen dense W + trainable low-rank adapter (Hu et al.
//!   2022); memory grows (W AND adapter), inference unchanged.
//! * `svdllm`   — truncation-aware data whitening + truncated SVD + LoRA
//!   adapters (Wang et al. 2024, App. A.4) — 3D activations only.
//! * `amc`      — activation-map compression by full HOSVD every
//!   iteration under an ε threshold (Nguyen et al. 2024): WASI's direct
//!   ancestor and the source of its rank budgets.
//! * `asi_only` — ASI on activations with dense weights (Nguyen et al.
//!   2025): compresses training memory but not the architecture.

pub mod amc;
pub mod asi_only;
pub mod conv;
pub mod lora;
pub mod svdllm;

pub use crate::wasi::layer::DenseLayer;

//! AMC baseline (Nguyen et al. 2024): activation-map compression by FULL
//! truncated HOSVD at every iteration, rank chosen per-iteration by the
//! explained-variance threshold ε.
//!
//! This is the method ASI/WASI improve on: same memory savings, but the
//! per-iteration HOSVD costs a full SVD per mode (the "up to 252×" compute
//! overhead ASI removes) and the ranks fluctuate with the data, which is
//! what breaks fixed-memory deployment (§2).

use crate::linalg::tucker::{energy_ranks, hosvd, Tensor};

pub struct AmcCompressor {
    pub eps: f64,
    pub last_ranks: Vec<usize>,
}

impl AmcCompressor {
    pub fn new(eps: f64) -> Self {
        AmcCompressor { eps, last_ranks: Vec::new() }
    }

    /// Full HOSVD at threshold ε; returns (core, factors, memory_elems).
    pub fn compress(&mut self, a: &Tensor) -> (Tensor, Vec<crate::linalg::matrix::Mat>, usize) {
        let ranks = energy_ranks(a, self.eps);
        let (core, factors) = hosvd(a, &ranks);
        let mem = core.numel() + factors.iter().map(|f| f.data.len()).sum::<usize>();
        self.last_ranks = ranks;
        (core, factors, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;
    use crate::linalg::tucker::tucker_reconstruct;

    #[test]
    fn reconstruction_error_bounded_by_eps() {
        let mut rng = Pcg64::new(1);
        let t = Tensor::from_vec(&[6, 10, 12], rng.normal_vec(720));
        let mut amc = AmcCompressor::new(0.9);
        let (core, factors, _) = amc.compress(&t);
        let rec = tucker_reconstruct(&core, &factors);
        let mut err = 0.0f64;
        for (a, b) in rec.data.iter().zip(&t.data) {
            err += ((a - b) * (a - b)) as f64;
        }
        // HOSVD error is bounded by sum of per-mode tail energies: with
        // eps=0.9 per mode, total relative energy error <= 3 * 0.1.
        let rel = err / (t.frob_norm() as f64).powi(2);
        assert!(rel < 0.35, "relative energy error {rel}");
    }

    #[test]
    fn ranks_fluctuate_with_data() {
        // The deployment problem ASI fixes: different batches -> different
        // ranks under the same ε.
        let mut amc = AmcCompressor::new(0.8);
        let mut rng = Pcg64::new(2);
        // strongly low-rank batch
        let core = Tensor::from_vec(&[2, 2, 2], rng.normal_vec(8));
        let u0 = crate::linalg::matrix::Mat::random(8, 2, &mut rng);
        let u1 = crate::linalg::matrix::Mat::random(9, 2, &mut rng);
        let u2 = crate::linalg::matrix::Mat::random(10, 2, &mut rng);
        let lowrank = crate::linalg::tucker::tucker_reconstruct(&core, &[u0, u1, u2]);
        amc.compress(&lowrank);
        let r_low = amc.last_ranks.clone();
        // full-rank noise batch
        let noise = Tensor::from_vec(&[8, 9, 10], rng.normal_vec(720));
        amc.compress(&noise);
        let r_noise = amc.last_ranks.clone();
        assert!(r_low.iter().sum::<usize>() < r_noise.iter().sum::<usize>(),
                "{r_low:?} vs {r_noise:?}");
    }

    #[test]
    fn higher_eps_higher_memory() {
        let mut rng = Pcg64::new(3);
        let t = Tensor::from_vec(&[6, 8, 10], rng.normal_vec(480));
        let mut prev = 0usize;
        for eps in [0.4, 0.6, 0.8, 0.95] {
            let mut amc = AmcCompressor::new(eps);
            let (_, _, mem) = amc.compress(&t);
            assert!(mem >= prev);
            prev = mem;
        }
    }
}

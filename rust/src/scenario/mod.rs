//! Workload scenario harness: the correctness backstop for the serving
//! core (`wasi-train soak`, DESIGN.md §Scenario harness).
//!
//! The paper's deployment story is a long-lived on-device process
//! personalizing continuously while serving inference.  Production
//! on-device stacks live or die by behaviour under *messy* workloads —
//! cancel storms, interleaved train/infer traffic, cache pressure — so
//! this module drives a [`crate::serve::Service`] with replayed or
//! synthesized adversarial traffic and checks the serving invariants
//! under load:
//!
//! * [`trace`] — the JSON-lines trace format (record + replay): any
//!   failing run is reproducible from its trace file;
//! * [`generator`] — deterministic seeded workload synthesis (Zipf
//!   variant × precision mix, exponential arrivals);
//! * [`faults`] — the [`FaultPlan`]: cancel storms and worker death
//!   delivered through the service's [`crate::serve::FaultHook`],
//!   pool eviction and malformed frames delivered as trace events,
//!   and variant-store budget pressure (`evict-budget`) driven by the
//!   soak itself: delta-persist every factored-variant job under a
//!   resident budget far below the job count, then assert the paging
//!   invariants (no eviction-caused failures, exactly-once reloads,
//!   bit-identical predictions across evict→reload), plus connection
//!   churn (`conn-churn`): infer traffic routed over a real loopback
//!   socket front-end ([`crate::net`]) with abrupt disconnects,
//!   half-closes, and slow readers — no dispatcher may wedge and
//!   every accepted job still reaches exactly one terminal state;
//! * [`telemetry`] — queue-depth series, pool occupancy, latency
//!   histograms, and the [`SoakReport`] (`SOAK_report.json`);
//! * [`soak`] — the bounded driver tying it together.

pub mod faults;
pub mod generator;
pub mod soak;
pub mod telemetry;
pub mod trace;

pub use faults::{FaultPlan, PlanHook};
pub use generator::{generate, GeneratorConfig};
pub use soak::{run_soak, run_soak_to, SoakConfig, EVICT_BUDGET_RESIDENTS};
pub use telemetry::{LatencyStats, SoakReport};
pub use trace::{read_trace, write_trace, TraceEvent, TraceOp};

//! The bounded soak driver: replay (or synthesize) a workload trace
//! against a live [`Service`], inject the planned faults, check the
//! serving invariants, and measure telemetry into a [`SoakReport`].
//!
//! Invariants checked (violations end up in `report.violations`; a
//! healthy soak reports NONE):
//!
//! * every submitted job emits **exactly one** terminal event
//!   (Done/Failed) on its stream — "exactly one party writes each
//!   terminal state", under contention;
//! * every job failure is an *expected* one: a cancellation (client
//!   cancel or cancel storm), a contained worker-death panic on a job
//!   the plan scheduled to die, or a shutdown kill on a truncated run;
//! * pool inference never fails;
//! * malformed protocol frames answer in-band (parseable `ok:false`
//!   lines, never a dropped frame or a session kill);
//! * the infer cache loads each (variant, precision) entry **exactly
//!   once** (plus one rebuild per eviction when the eviction fault is
//!   active);
//! * the service drains to idle: empty queue, nothing running, after
//!   the last job settles.
//!
//! Determinism: the event sequence is a pure function of the trace
//! (itself a pure function of the seed when generated), and the
//! invariant outcomes are timing-robust — which jobs *complete* vs
//! *cancel* may vary with scheduling, but every outcome is classified
//! against the plan, so a clean run is clean on every machine.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::FinetuneConfig;
use crate::engine::EngineKind;
use crate::precision::Precision;
use crate::serve::{handle_line, Flow, InferRequest, JobId, JobSpec, Service, ServiceConfig};
use crate::util::json::Json;

use super::faults::{silence_injected_panics, FaultPlan, PlanHook};
use super::generator::{generate, GeneratorConfig};
use super::telemetry::SoakReport;
use super::trace::{read_trace, write_trace, TraceEvent, TraceOp};

/// One soak run's parameters.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Artifact directory the service serves from.
    pub artifacts: PathBuf,
    pub workers: usize,
    /// Events to generate when no input trace is given.
    pub events: usize,
    /// Wallclock cap in seconds; hitting it truncates the run (marked
    /// in the report) instead of hanging CI.
    pub max_seconds: f64,
    pub seed: u64,
    /// Variants to spread load over; empty = the demo pair.
    pub variants: Vec<String>,
    pub faults: FaultPlan,
    /// Replay this trace instead of generating one.
    pub trace_in: Option<PathBuf>,
    /// Record the (generated or replayed) trace here.
    pub trace_out: Option<PathBuf>,
    /// Honor the trace's `at_ms` gaps in real time; off = replay as
    /// fast as the driver can issue events (CI quick mode).
    pub pace: bool,
}

impl SoakConfig {
    /// The CI quick soak: ~120 events, 2 workers, fixed seed.
    pub fn quick(artifacts: impl Into<PathBuf>) -> SoakConfig {
        SoakConfig {
            artifacts: artifacts.into(),
            workers: 2,
            events: 120,
            max_seconds: 60.0,
            seed: 233,
            variants: Vec::new(),
            faults: FaultPlan::none(),
            trace_in: None,
            trace_out: None,
            pace: false,
        }
    }
}

/// What one job's watcher thread observed from its event stream.
struct JobWatch {
    id: JobId,
    terminals: usize,
    done_latency_ms: Option<f64>,
    error: Option<String>,
}

/// Run one soak to completion and return its report.  Errors are
/// *setup* failures (bad artifact dir, unreadable trace); workload
/// failures are violations inside the report, not `Err`s.
pub fn run_soak(cfg: &SoakConfig) -> Result<SoakReport> {
    let variants: Vec<String> = if cfg.variants.is_empty() {
        vec!["vit_demo_wasi_eps80".into(), "vit_demo_vanilla".into()]
    } else {
        cfg.variants.clone()
    };
    let trace: Vec<TraceEvent> = match &cfg.trace_in {
        Some(path) => read_trace(path)?,
        None => {
            let mut gcfg = GeneratorConfig::new(variants, cfg.events, cfg.seed);
            gcfg.evict = cfg.faults.evict;
            gcfg.malformed = cfg.faults.malformed;
            generate(&gcfg)
        }
    };
    if let Some(path) = &cfg.trace_out {
        write_trace(path, &trace)?;
    }

    if cfg.faults.worker_death {
        silence_injected_panics();
    }
    let mut scfg = ServiceConfig::new(cfg.artifacts.clone()).with_workers(cfg.workers);
    if cfg.faults.service_side() {
        scfg = scfg.with_faults(std::sync::Arc::new(PlanHook::new(cfg.faults)));
    }
    let svc = Service::start(scfg)?;
    let entry = svc.default_entry()?;

    let mut report = SoakReport {
        seed: cfg.seed,
        faults: cfg.faults.to_string(),
        workers: cfg.workers.max(1),
        events_total: trace.len(),
        ..SoakReport::default()
    };
    let start = Instant::now();
    // (variant, precision) pairs pool inference actually touched — the
    // exactly-once load invariant is checked against this set.
    let mut infer_keys: BTreeSet<(String, Precision)> = BTreeSet::new();

    let watches: Vec<JobWatch> = std::thread::scope(|s| {
        let mut submit_ids: Vec<Option<JobId>> = Vec::new();
        let mut watchers = Vec::new();
        for ev in &trace {
            let elapsed = start.elapsed().as_secs_f64();
            if elapsed > cfg.max_seconds {
                report.truncated = true;
                break;
            }
            if cfg.pace {
                let target_s = ev.at_ms / 1e3;
                if target_s > elapsed {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        target_s - elapsed,
                    ));
                }
            }
            report
                .queue_depth
                .push((start.elapsed().as_secs_f64() * 1e3, svc.queue_depth()));
            match &ev.op {
                TraceOp::Submit { model, steps, samples, seed, precision } => {
                    report.ops.submits += 1;
                    let fcfg = FinetuneConfig::builder()
                        .model(model.clone())
                        .steps(*steps)
                        .samples(*samples)
                        .seed(*seed)
                        .lr0(0.1)
                        .engine(EngineKind::Native)
                        .precision(*precision)
                        .build();
                    match svc.submit(JobSpec::new(fcfg)) {
                        Err(e) => {
                            submit_ids.push(None);
                            report
                                .violations
                                .push(format!("submit of {model:?} rejected: {e:#}"));
                        }
                        Ok(id) => {
                            submit_ids.push(Some(id));
                            let rx = svc.take_events(id);
                            let submitted = Instant::now();
                            watchers.push(s.spawn(move || {
                                let mut w = JobWatch {
                                    id,
                                    terminals: 0,
                                    done_latency_ms: None,
                                    error: None,
                                };
                                let Some(rx) = rx else { return w };
                                for ev in rx.iter() {
                                    match ev {
                                        crate::serve::JobEvent::Done { .. } => {
                                            w.terminals += 1;
                                            w.done_latency_ms = Some(
                                                submitted.elapsed().as_secs_f64() * 1e3,
                                            );
                                        }
                                        crate::serve::JobEvent::Failed { error, .. } => {
                                            w.terminals += 1;
                                            w.error = Some(error);
                                        }
                                        _ => {}
                                    }
                                }
                                w
                            }));
                        }
                    }
                }
                TraceOp::Infer { model, precision, seed } => {
                    report.ops.infers += 1;
                    infer_keys.insert((model.clone(), *precision));
                    let req = InferRequest {
                        model: model.clone(),
                        engine: EngineKind::Auto,
                        precision: *precision,
                        seed: *seed,
                        x: None,
                    };
                    let t0 = Instant::now();
                    match svc.infer(None, &req, None) {
                        Ok(out) => {
                            report
                                .infer_roundtrip
                                .push(t0.elapsed().as_secs_f64() * 1e3);
                            if out.preds.is_empty() {
                                report.violations.push(format!(
                                    "infer on {model:?} ({precision}) returned no predictions"
                                ));
                            }
                        }
                        Err(e) => report.violations.push(format!(
                            "infer on {model:?} ({precision}) failed: {e:#}"
                        )),
                    }
                }
                TraceOp::Cancel { submit } => {
                    report.ops.cancels += 1;
                    if let Some(Some(id)) = submit_ids.get(*submit) {
                        let _ = svc.cancel(*id);
                    }
                }
                TraceOp::Forget { submit } => {
                    report.ops.forgets += 1;
                    if let Some(Some(id)) = submit_ids.get(*submit) {
                        let _ = svc.forget(*id);
                    }
                }
                TraceOp::Evict { model, precision } => {
                    report.ops.evicts += 1;
                    let _ = entry.evict_infer(model, *precision);
                }
                TraceOp::Frame { line } => {
                    report.ops.frames += 1;
                    let mut sink: Vec<u8> = Vec::new();
                    match handle_line(&svc, line.trim(), &mut sink) {
                        Err(e) => report
                            .violations
                            .push(format!("frame {line:?} I/O error: {e}")),
                        Ok(flow) => {
                            if flow == Flow::Shutdown {
                                report.violations.push(format!(
                                    "frame {line:?} triggered a session shutdown"
                                ));
                            }
                            let text = String::from_utf8_lossy(&sink);
                            let lines: Vec<&str> =
                                text.lines().filter(|l| !l.trim().is_empty()).collect();
                            if lines.is_empty() {
                                report.violations.push(format!(
                                    "frame {line:?} was silently dropped (no response)"
                                ));
                            }
                            for l in lines {
                                let ok = Json::parse(l)
                                    .ok()
                                    .and_then(|v| v.get("ok").and_then(|o| o.as_bool()));
                                if ok.is_none() {
                                    report.violations.push(format!(
                                        "frame {line:?} drew a non-protocol response {l:?}"
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            report.events_replayed += 1;
        }
        if report.truncated {
            // Cap hit: stop the service so in-flight jobs settle fast
            // (their watchers observe the shutdown/cancel terminal).
            svc.shutdown();
        }
        watchers
            .into_iter()
            .map(|h| h.join().unwrap_or(JobWatch {
                id: JobId(0),
                terminals: 0,
                done_latency_ms: None,
                error: Some("watcher thread panicked".into()),
            }))
            .collect()
    });

    // All watchers joined => every submitted job reached its terminal
    // transition; classify outcomes and check exactly-one-terminal.
    for w in &watches {
        if w.terminals != 1 {
            report.violations.push(format!(
                "job {} emitted {} terminal events (exactly 1 required)",
                w.id, w.terminals
            ));
        }
        match (&w.error, w.done_latency_ms) {
            (None, Some(ms)) => {
                report.jobs.done += 1;
                report.submit_to_done.push(ms);
            }
            (Some(e), _) if e.contains("cancelled") => report.jobs.cancelled += 1,
            (Some(e), _) if e.contains("worker panicked") => {
                report.jobs.panicked += 1;
                if !cfg.faults.kills_job(w.id) {
                    report.violations.push(format!(
                        "job {} hit an UNPLANNED worker panic: {e}",
                        w.id
                    ));
                }
            }
            (Some(e), _) if e.contains("shut down") => {
                report.jobs.shutdown += 1;
                if !report.truncated {
                    report.violations.push(format!(
                        "job {} was shutdown-killed in a non-truncated run: {e}",
                        w.id
                    ));
                }
            }
            (Some(e), _) => {
                report.jobs.unexpected += 1;
                report
                    .violations
                    .push(format!("job {} failed unexpectedly: {e}", w.id));
            }
            (None, None) => {
                report.jobs.unexpected += 1;
                report.violations.push(format!(
                    "job {} ended with neither report nor error",
                    w.id
                ));
            }
        }
    }

    // Drain-to-idle: with every job terminal, nothing may remain queued
    // or running.
    if svc.queue_depth() != 0 {
        report
            .violations
            .push(format!("service did not drain: queue depth {}", svc.queue_depth()));
    }
    if svc.running_count() != 0 {
        report.violations.push(format!(
            "service did not drain: {} jobs still running",
            svc.running_count()
        ));
    }

    // Exactly-once loads: without evictions the pool must have built
    // precisely one engine per touched (variant, precision); each
    // eviction licenses at most one rebuild.
    report.pool_loads = entry.infer_loads();
    report.pool_evictions = entry.infer_evictions();
    report.pool_occupancy = entry
        .cached_infer_keys()
        .into_iter()
        .map(|(m, p)| (m, p.to_string()))
        .collect();
    let used = infer_keys.len() as u64;
    if report.pool_evictions == 0 {
        if report.pool_loads != used {
            report.violations.push(format!(
                "pool loaded {} engines for {} distinct (variant, precision) keys",
                report.pool_loads, used
            ));
        }
    } else if report.pool_loads > used + report.pool_evictions {
        report.violations.push(format!(
            "pool loaded {} engines for {} keys + {} evictions",
            report.pool_loads, used, report.pool_evictions
        ));
    }

    svc.shutdown();
    report.soak_seconds = start.elapsed().as_secs_f64();
    Ok(report)
}

/// Convenience used by `wasi-train bench` and the CLI: run and also
/// write the JSON report when `out` is given.
pub fn run_soak_to(cfg: &SoakConfig, out: Option<&std::path::Path>) -> Result<SoakReport> {
    let report = run_soak(cfg)?;
    if let Some(path) = out {
        std::fs::write(path, format!("{}\n", report.to_json()))
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))?;
    }
    Ok(report)
}

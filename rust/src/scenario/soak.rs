//! The bounded soak driver: replay (or synthesize) a workload trace
//! against a live [`Service`], inject the planned faults, check the
//! serving invariants, and measure telemetry into a [`SoakReport`].
//!
//! Invariants checked (violations end up in `report.violations`; a
//! healthy soak reports NONE):
//!
//! * every submitted job emits **exactly one** terminal event
//!   (Done/Failed) on its stream — "exactly one party writes each
//!   terminal state", under contention;
//! * every job failure is an *expected* one: a cancellation (client
//!   cancel or cancel storm), a contained worker-death panic on a job
//!   the plan scheduled to die, or a shutdown kill on a truncated run;
//! * pool inference never fails;
//! * malformed protocol frames answer in-band (parseable `ok:false`
//!   lines, never a dropped frame or a session kill);
//! * the infer cache loads each (variant, precision) entry **exactly
//!   once** (plus one rebuild per eviction when the eviction fault is
//!   active);
//! * the service drains to idle: empty queue, nothing running, after
//!   the last job settles.
//!
//! Determinism: the event sequence is a pure function of the trace
//! (itself a pure function of the seed when generated), and the
//! invariant outcomes are timing-robust — which jobs *complete* vs
//! *cancel* may vary with scheduling, but every outcome is classified
//! against the plan, so a clean run is clean on every machine.

use std::collections::BTreeSet;
use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::memory::delta_bytes;
use crate::coordinator::FinetuneConfig;
use crate::engine::EngineKind;
use crate::net::{read_frame, serve_listener, write_frame, NetConfig, MAX_FRAME_BYTES};
use crate::precision::Precision;
use crate::runtime::Manifest;
use crate::serve::{
    handle_line, Flow, InferRequest, JobId, JobSpec, JobState, Service, ServiceConfig,
};
use crate::util::json::{self, Json};

use super::faults::{silence_injected_panics, FaultPlan, PlanHook};
use super::generator::{generate, GeneratorConfig};
use super::telemetry::SoakReport;
use super::trace::{read_trace, write_trace, TraceEvent, TraceOp};

/// One soak run's parameters.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Artifact directory the service serves from.
    pub artifacts: PathBuf,
    pub workers: usize,
    /// Events to generate when no input trace is given.
    pub events: usize,
    /// Wallclock cap in seconds; hitting it truncates the run (marked
    /// in the report) instead of hanging CI.
    pub max_seconds: f64,
    pub seed: u64,
    /// Variants to spread load over; empty = the demo pair.
    pub variants: Vec<String>,
    pub faults: FaultPlan,
    /// Replay this trace instead of generating one.
    pub trace_in: Option<PathBuf>,
    /// Record the (generated or replayed) trace here.
    pub trace_out: Option<PathBuf>,
    /// Honor the trace's `at_ms` gaps in real time; off = replay as
    /// fast as the driver can issue events (CI quick mode).
    pub pace: bool,
    /// Variant-store directory for delta persistence; `None` with the
    /// evict-budget fault armed auto-provisions `<artifacts>/soak_store`.
    pub store: Option<PathBuf>,
    /// Store resident budget in MiB (0 = derive a pressure budget of
    /// [`EVICT_BUDGET_RESIDENTS`] delta records when evict-budget is
    /// armed, unbounded otherwise).
    pub memory_budget_mb: usize,
    /// Route infer traffic through a real loopback socket front-end
    /// ([`crate::net::serve_listener`]) instead of in-process calls.
    /// The conn-churn fault implies this and additionally abuses the
    /// connections (abrupt disconnect, half-close, slow reader).
    pub listen: bool,
}

/// Resident-set capacity (in delta records) the evict-budget fault
/// derives when no explicit `--memory-budget-mb` is given — far below
/// the delta jobs a soak persists, so paging MUST happen.
pub const EVICT_BUDGET_RESIDENTS: usize = 4;

impl SoakConfig {
    /// The CI quick soak: ~120 events, 2 workers, fixed seed.
    pub fn quick(artifacts: impl Into<PathBuf>) -> SoakConfig {
        SoakConfig {
            artifacts: artifacts.into(),
            workers: 2,
            events: 120,
            max_seconds: 60.0,
            seed: 233,
            variants: Vec::new(),
            faults: FaultPlan::none(),
            trace_in: None,
            trace_out: None,
            pace: false,
            store: None,
            memory_budget_mb: 0,
            listen: false,
        }
    }
}

/// A framed protocol client over one soak-owned connection.
struct SoakClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl SoakClient {
    fn connect(addr: SocketAddr) -> std::io::Result<SoakClient> {
        let writer = TcpStream::connect(addr)?;
        let reader = BufReader::new(writer.try_clone()?);
        Ok(SoakClient { writer, reader })
    }

    fn send(&mut self, line: &str) -> std::io::Result<()> {
        write_frame(&mut self.writer, line.as_bytes())
    }

    fn recv(&mut self) -> std::result::Result<String, String> {
        match read_frame(&mut self.reader, MAX_FRAME_BYTES) {
            Ok(Some(payload)) => Ok(String::from_utf8_lossy(&payload).into_owned()),
            Ok(None) => Err("connection closed before the response".into()),
            Err(e) => Err(format!("recv: {e}")),
        }
    }
}

/// Route one infer over the socket front-end, applying the planned
/// connection churn.  `Ok(Some(response))` round-tripped; `Ok(None)`
/// means the churn variant deliberately abandoned the response.  `Err`
/// is a violation — the front-end must keep serving through churn.
fn socket_infer(
    addr: SocketAddr,
    client: &mut Option<SoakClient>,
    line: &str,
    churn: Option<u64>,
) -> std::result::Result<Option<String>, String> {
    match churn {
        // Abrupt disconnect: dedicated connection, send, drop without
        // reading.  The request still executes server-side; only this
        // throwaway connection's response is lost.
        Some(0) => {
            let mut c = SoakClient::connect(addr).map_err(|e| format!("connect: {e}"))?;
            c.send(line).map_err(|e| format!("send: {e}"))?;
            Ok(None)
        }
        // Half-close: send, close the write half, still read the
        // response — EOF at a frame boundary must not kill the reply.
        Some(1) => {
            let mut c = SoakClient::connect(addr).map_err(|e| format!("connect: {e}"))?;
            c.send(line).map_err(|e| format!("send: {e}"))?;
            let _ = c.writer.shutdown(Shutdown::Write);
            c.recv().map(Some)
        }
        // Slow reader (Some(_)) or plain round trip (None), both over
        // the persistent connection; any error drops it so the next
        // infer reconnects instead of wedging the run.
        churn => {
            if client.is_none() {
                *client =
                    Some(SoakClient::connect(addr).map_err(|e| format!("connect: {e}"))?);
            }
            let c = client.as_mut().expect("client connected above");
            let result = match c.send(line) {
                Err(e) => Err(format!("send: {e}")),
                Ok(()) => {
                    if churn.is_some() {
                        std::thread::sleep(Duration::from_millis(30));
                    }
                    c.recv().map(Some)
                }
            };
            if result.is_err() {
                *client = None;
            }
            result
        }
    }
}

/// What one job's watcher thread observed from its event stream.
struct JobWatch {
    id: JobId,
    terminals: usize,
    done_latency_ms: Option<f64>,
    error: Option<String>,
}

/// Run one soak to completion and return its report.  Errors are
/// *setup* failures (bad artifact dir, unreadable trace); workload
/// failures are violations inside the report, not `Err`s.
pub fn run_soak(cfg: &SoakConfig) -> Result<SoakReport> {
    let variants: Vec<String> = if cfg.variants.is_empty() {
        vec!["vit_demo_wasi_eps80".into(), "vit_demo_vanilla".into()]
    } else {
        cfg.variants.clone()
    };
    let trace: Vec<TraceEvent> = match &cfg.trace_in {
        Some(path) => read_trace(path)?,
        None => {
            let mut gcfg = GeneratorConfig::new(variants, cfg.events, cfg.seed);
            gcfg.evict = cfg.faults.evict;
            gcfg.malformed = cfg.faults.malformed;
            generate(&gcfg)
        }
    };
    if let Some(path) = &cfg.trace_out {
        write_trace(path, &trace)?;
    }

    if cfg.faults.worker_death {
        silence_injected_panics();
    }
    // Variant-store setup: an explicit dir, or (evict-budget fault) an
    // auto-provisioned one under the artifact directory.
    let store_dir: Option<PathBuf> = match cfg.store.clone() {
        Some(dir) => Some(dir),
        None if cfg.faults.evict_budget => {
            let dir = cfg.artifacts.join("soak_store");
            // Auto-provisioned: start from a clean slate so counters
            // and disk stats reflect THIS run only.
            let _ = std::fs::remove_dir_all(&dir);
            Some(dir)
        }
        None => None,
    };
    // Bytes one delta record of the largest factored variant charges —
    // the unit the pressure budget and the capacity checks price in.
    let mut record_bytes = 0usize;
    let mut scfg = ServiceConfig::new(cfg.artifacts.clone()).with_workers(cfg.workers);
    if let Some(dir) = &store_dir {
        let manifest = Manifest::load(&cfg.artifacts)?;
        record_bytes = variants
            .iter()
            .filter_map(|v| manifest.model(v).ok())
            .map(delta_bytes)
            .max()
            .unwrap_or(0);
        let budget_bytes = if cfg.memory_budget_mb > 0 {
            cfg.memory_budget_mb << 20
        } else if cfg.faults.evict_budget {
            record_bytes * EVICT_BUDGET_RESIDENTS
        } else {
            0
        };
        scfg = scfg.with_store(dir, budget_bytes);
    }
    if cfg.faults.service_side() {
        scfg = scfg.with_faults(std::sync::Arc::new(PlanHook::new(cfg.faults)));
    }
    let svc = Arc::new(Service::start(scfg)?);
    let entry = svc.default_entry()?;
    // Socket mode: `--listen`, or implied by the conn-churn fault —
    // infer traffic then rides a real loopback front-end so the soak
    // exercises framing, admission, and micro-batching under load.
    let socket_mode = cfg.listen || cfg.faults.conn_churn;
    let net_front = if socket_mode {
        let net_cfg = NetConfig {
            listen: "127.0.0.1:0".into(),
            max_inflight: 256,
            queue_cap: 1024,
            batch_window_us: 200,
            max_batch: 8,
            dispatchers: 0,
        };
        Some(serve_listener(svc.clone(), net_cfg)?)
    } else {
        None
    };
    let net_addr = net_front.as_ref().map(|h| h.addr());
    let mut net_client: Option<SoakClient> = None;
    let mut socket_infers = 0u64;
    let mut churned = 0u64;
    // Variants with a subspace — the only ones a delta job can persist.
    let factored: BTreeSet<String> = variants
        .iter()
        .filter(|v| {
            entry
                .manifest
                .model(v)
                .map(|m| !m.weight_ranks.is_empty())
                .unwrap_or(false)
        })
        .cloned()
        .collect();
    let persist_deltas = svc.store().is_some();

    let mut report = SoakReport {
        seed: cfg.seed,
        faults: cfg.faults.to_string(),
        workers: cfg.workers.max(1),
        events_total: trace.len(),
        ..SoakReport::default()
    };
    let start = Instant::now();
    // (variant, precision) pairs pool inference actually touched — the
    // exactly-once load invariant is checked against this set.
    let mut infer_keys: BTreeSet<(String, Precision)> = BTreeSet::new();
    // Jobs submitted with persist:"delta" — the evict-budget post-pass
    // verifies each finished one bit-identical across evict→reload.
    let mut delta_jobs: Vec<(JobId, String)> = Vec::new();

    let watches: Vec<JobWatch> = std::thread::scope(|s| {
        let mut submit_ids: Vec<Option<JobId>> = Vec::new();
        let mut watchers = Vec::new();
        for ev in &trace {
            let elapsed = start.elapsed().as_secs_f64();
            if elapsed > cfg.max_seconds {
                report.truncated = true;
                break;
            }
            if cfg.pace {
                let target_s = ev.at_ms / 1e3;
                if target_s > elapsed {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        target_s - elapsed,
                    ));
                }
            }
            report
                .queue_depth
                .push((start.elapsed().as_secs_f64() * 1e3, svc.queue_depth()));
            match &ev.op {
                TraceOp::Submit { model, steps, samples, seed, precision } => {
                    report.ops.submits += 1;
                    let fcfg = FinetuneConfig::builder()
                        .model(model.clone())
                        .steps(*steps)
                        .samples(*samples)
                        .seed(*seed)
                        .lr0(0.1)
                        .engine(EngineKind::Native)
                        .precision(*precision)
                        .build();
                    let mut spec = JobSpec::new(fcfg);
                    // With a store attached, every factored-variant job
                    // persists as a delta record (vanilla variants have
                    // no subspace and keep the retained-full path).
                    spec.persist_delta = persist_deltas && factored.contains(model);
                    let persisted = spec.persist_delta;
                    match svc.submit(spec) {
                        Err(e) => {
                            submit_ids.push(None);
                            report
                                .violations
                                .push(format!("submit of {model:?} rejected: {e:#}"));
                        }
                        Ok(id) => {
                            submit_ids.push(Some(id));
                            if persisted {
                                delta_jobs.push((id, model.clone()));
                            }
                            let rx = svc.take_events(id);
                            let submitted = Instant::now();
                            watchers.push(s.spawn(move || {
                                let mut w = JobWatch {
                                    id,
                                    terminals: 0,
                                    done_latency_ms: None,
                                    error: None,
                                };
                                let Some(rx) = rx else { return w };
                                for ev in rx.iter() {
                                    match ev {
                                        crate::serve::JobEvent::Done { .. } => {
                                            w.terminals += 1;
                                            w.done_latency_ms = Some(
                                                submitted.elapsed().as_secs_f64() * 1e3,
                                            );
                                        }
                                        crate::serve::JobEvent::Failed { error, .. } => {
                                            w.terminals += 1;
                                            w.error = Some(error);
                                        }
                                        _ => {}
                                    }
                                }
                                w
                            }));
                        }
                    }
                }
                TraceOp::Infer { model, precision, seed } => {
                    report.ops.infers += 1;
                    infer_keys.insert((model.clone(), *precision));
                    if let Some(addr) = net_addr {
                        // Socket path: framed request with an id, the
                        // response validated like the in-process one.
                        socket_infers += 1;
                        let churn = if cfg.faults.conn_churn && report.ops.infers % 6 == 0 {
                            churned += 1;
                            Some((report.ops.infers as u64 / 6) % 3)
                        } else {
                            None
                        };
                        let line = json::obj(vec![
                            ("cmd", json::str("infer")),
                            ("model", json::str(model.clone())),
                            ("engine", json::str("auto")),
                            ("precision", json::str(precision.to_string())),
                            ("seed", json::num(*seed as f64)),
                            ("id", json::num(report.ops.infers as f64)),
                        ])
                        .to_string();
                        let t0 = Instant::now();
                        match socket_infer(addr, &mut net_client, &line, churn) {
                            Ok(None) => {} // abrupt churn abandons the response by design
                            Ok(Some(resp)) => {
                                report
                                    .infer_roundtrip
                                    .push(t0.elapsed().as_secs_f64() * 1e3);
                                let v = Json::parse(&resp).ok();
                                let ok = v
                                    .as_ref()
                                    .and_then(|v| v.get("ok").and_then(|o| o.as_bool()))
                                    .unwrap_or(false);
                                let preds = v
                                    .as_ref()
                                    .and_then(|v| v.get("preds").and_then(|p| p.as_arr()))
                                    .map(|a| !a.is_empty())
                                    .unwrap_or(false);
                                if !ok || !preds {
                                    report.violations.push(format!(
                                        "socket infer on {model:?} ({precision}) drew a bad \
                                         response: {resp}"
                                    ));
                                }
                            }
                            Err(e) => report.violations.push(format!(
                                "socket infer on {model:?} ({precision}) failed: {e}"
                            )),
                        }
                    } else {
                        let req = InferRequest {
                            model: model.clone(),
                            engine: EngineKind::Auto,
                            precision: *precision,
                            seed: *seed,
                            x: None,
                        };
                        let t0 = Instant::now();
                        match svc.infer(None, &req, None) {
                            Ok(out) => {
                                report
                                    .infer_roundtrip
                                    .push(t0.elapsed().as_secs_f64() * 1e3);
                                if out.preds.is_empty() {
                                    report.violations.push(format!(
                                        "infer on {model:?} ({precision}) returned no predictions"
                                    ));
                                }
                            }
                            Err(e) => report.violations.push(format!(
                                "infer on {model:?} ({precision}) failed: {e:#}"
                            )),
                        }
                    }
                }
                TraceOp::Cancel { submit } => {
                    report.ops.cancels += 1;
                    if let Some(Some(id)) = submit_ids.get(*submit) {
                        let _ = svc.cancel(*id);
                    }
                }
                TraceOp::Forget { submit } => {
                    report.ops.forgets += 1;
                    if let Some(Some(id)) = submit_ids.get(*submit) {
                        let _ = svc.forget(*id);
                    }
                }
                TraceOp::Evict { model, precision } => {
                    report.ops.evicts += 1;
                    let _ = entry.evict_infer(model, *precision);
                }
                TraceOp::Frame { line } => {
                    report.ops.frames += 1;
                    let mut sink: Vec<u8> = Vec::new();
                    match handle_line(&svc, line.trim(), &mut sink) {
                        Err(e) => report
                            .violations
                            .push(format!("frame {line:?} I/O error: {e}")),
                        Ok(flow) => {
                            if flow == Flow::Shutdown {
                                report.violations.push(format!(
                                    "frame {line:?} triggered a session shutdown"
                                ));
                            }
                            let text = String::from_utf8_lossy(&sink);
                            let lines: Vec<&str> =
                                text.lines().filter(|l| !l.trim().is_empty()).collect();
                            if lines.is_empty() {
                                report.violations.push(format!(
                                    "frame {line:?} was silently dropped (no response)"
                                ));
                            }
                            for l in lines {
                                let ok = Json::parse(l)
                                    .ok()
                                    .and_then(|v| v.get("ok").and_then(|o| o.as_bool()));
                                if ok.is_none() {
                                    report.violations.push(format!(
                                        "frame {line:?} drew a non-protocol response {l:?}"
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            report.events_replayed += 1;
        }
        if report.truncated {
            // Cap hit: stop the service so in-flight jobs settle fast
            // (their watchers observe the shutdown/cancel terminal).
            svc.shutdown();
        }
        watchers
            .into_iter()
            .map(|h| h.join().unwrap_or(JobWatch {
                id: JobId(0),
                terminals: 0,
                done_latency_ms: None,
                error: Some("watcher thread panicked".into()),
            }))
            .collect()
    });

    // Quiesce the socket front-end before the invariant checks: churn
    // leaves abandoned requests mid-execution server-side, and the
    // exactly-once pool accounting below must observe their completed
    // loads.  The drained stats land in the report.
    if let Some(mut handle) = net_front {
        drop(net_client);
        let stats = handle.stats();
        handle.shutdown();
        let mut m = match stats.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("NetStats::to_json returns an object"),
        };
        m.insert("socket_infers".to_string(), json::num(socket_infers as f64));
        m.insert("churned_connections".to_string(), json::num(churned as f64));
        report.net = Some(Json::Obj(m));
    }

    // All watchers joined => every submitted job reached its terminal
    // transition; classify outcomes and check exactly-one-terminal.
    for w in &watches {
        if w.terminals != 1 {
            report.violations.push(format!(
                "job {} emitted {} terminal events (exactly 1 required)",
                w.id, w.terminals
            ));
        }
        match (&w.error, w.done_latency_ms) {
            (None, Some(ms)) => {
                report.jobs.done += 1;
                report.submit_to_done.push(ms);
            }
            (Some(e), _) if e.contains("cancelled") => report.jobs.cancelled += 1,
            (Some(e), _) if e.contains("worker panicked") => {
                report.jobs.panicked += 1;
                if !cfg.faults.kills_job(w.id) {
                    report.violations.push(format!(
                        "job {} hit an UNPLANNED worker panic: {e}",
                        w.id
                    ));
                }
            }
            (Some(e), _) if e.contains("shut down") => {
                report.jobs.shutdown += 1;
                if !report.truncated {
                    report.violations.push(format!(
                        "job {} was shutdown-killed in a non-truncated run: {e}",
                        w.id
                    ));
                }
            }
            (Some(e), _) => {
                report.jobs.unexpected += 1;
                report
                    .violations
                    .push(format!("job {} failed unexpectedly: {e}", w.id));
            }
            (None, None) => {
                report.jobs.unexpected += 1;
                report.violations.push(format!(
                    "job {} ended with neither report nor error",
                    w.id
                ));
            }
        }
    }

    // Drain-to-idle: with every job terminal, nothing may remain queued
    // or running.
    if svc.queue_depth() != 0 {
        report
            .violations
            .push(format!("service did not drain: queue depth {}", svc.queue_depth()));
    }
    if svc.running_count() != 0 {
        report.violations.push(format!(
            "service did not drain: {} jobs still running",
            svc.running_count()
        ));
    }

    // Exactly-once loads: without evictions the pool must have built
    // precisely one engine per touched (variant, precision); each
    // eviction licenses at most one rebuild.
    report.pool_loads = entry.infer_loads();
    report.pool_evictions = entry.infer_evictions();
    report.pool_occupancy = entry
        .cached_infer_keys()
        .into_iter()
        .map(|(m, p)| (m, p.to_string()))
        .collect();
    let used = infer_keys.len() as u64;
    if report.pool_evictions == 0 {
        if report.pool_loads != used {
            report.violations.push(format!(
                "pool loaded {} engines for {} distinct (variant, precision) keys",
                report.pool_loads, used
            ));
        }
    } else if report.pool_loads > used + report.pool_evictions {
        report.violations.push(format!(
            "pool loaded {} engines for {} keys + {} evictions",
            report.pool_loads, used, report.pool_evictions
        ));
    }

    // Variant-store invariants (DESIGN.md §Variant store): the budget
    // actually paged, no request fails because of an eviction, and
    // every finished delta job predicts bit-identically across a forced
    // evict-everything pass.
    if let Some(store) = svc.store() {
        if let Ok(s) = store.stats() {
            if record_bytes > 0 && store.budget_bytes() > 0 {
                let capacity = (store.budget_bytes() / record_bytes).max(1);
                if s.puts as usize > capacity && s.evictions == 0 {
                    report.violations.push(format!(
                        "store accepted {} puts with a {}-record budget but never evicted",
                        s.puts, capacity
                    ));
                }
                if s.resident > capacity {
                    report.violations.push(format!(
                        "store resident set ({} records) exceeds the budget capacity ({})",
                        s.resident, capacity
                    ));
                }
            }
        }
        for (id, model) in &delta_jobs {
            if !matches!(svc.status(*id), Some(JobState::Done(_))) {
                continue; // cancelled/killed/forgotten jobs have no record
            }
            let req = InferRequest {
                model: model.clone(),
                engine: EngineKind::Auto,
                precision: Precision::F32,
                seed: 97,
                x: None,
            };
            let before = match svc.infer(None, &req, Some(*id)) {
                Ok(out) => out,
                Err(e) => {
                    report
                        .violations
                        .push(format!("delta infer on job {id} failed: {e:#}"));
                    continue;
                }
            };
            store.evict_all();
            match svc.infer(None, &req, Some(*id)) {
                Err(e) => report.violations.push(format!(
                    "delta infer on job {id} failed after eviction: {e:#}"
                )),
                Ok(after) if after.preds != before.preds => {
                    report.violations.push(format!(
                        "job {id} predictions changed across evict→reload"
                    ))
                }
                Ok(_) => report.store_verified += 1,
            }
        }
        if let Ok(s) = store.stats() {
            if s.reloads > s.evictions {
                report.violations.push(format!(
                    "store reloaded {} times but only evicted {} — a key was \
                     loaded more than exactly-once per eviction",
                    s.reloads, s.evictions
                ));
            }
            report.store = Some(s);
        }
    }

    svc.shutdown();
    report.soak_seconds = start.elapsed().as_secs_f64();
    Ok(report)
}

/// Convenience used by `wasi-train bench` and the CLI: run and also
/// write the JSON report when `out` is given.
pub fn run_soak_to(cfg: &SoakConfig, out: Option<&std::path::Path>) -> Result<SoakReport> {
    let report = run_soak(cfg)?;
    if let Some(path) = out {
        std::fs::write(path, format!("{}\n", report.to_json()))
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))?;
    }
    Ok(report)
}

//! Fault plan: which adversarial behaviours a soak run injects, and
//! the [`FaultHook`] implementation that delivers the service-side ones
//! (cancel storms at step boundaries, worker death mid-job).
//!
//! Driver-side faults (pool eviction-under-use, malformed protocol
//! frames) are *trace events* — the generator mixes them in when the
//! plan enables them — so every fault a run experienced is visible in
//! its recorded trace.  Service-side faults key off the **job id**
//! (`id % N == k`), not pickup order, so which jobs get hit is a pure
//! function of the trace, independent of worker scheduling.

use std::sync::OnceLock;

use anyhow::{anyhow, Result};

use crate::serve::{FaultAction, FaultHook, JobId};

/// Which fault classes a soak run injects.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Cancel every 5th job (ids ≡ 3 mod 5) at its second step — a
    /// deterministic cancel storm hitting jobs mid-run.
    pub cancel_storm: bool,
    /// Panic the worker of every 7th job (ids ≡ 4 mod 7) at its first
    /// step — worker death mid-job; the service must contain it.
    pub worker_death: bool,
    /// Mix pool-eviction events into the generated trace.
    pub evict: bool,
    /// Mix malformed protocol frames into the generated trace.
    pub malformed: bool,
    /// Run the variant store under budget pressure: delta-persist every
    /// factored-variant job, size the resident budget below the job
    /// count, and assert the paging invariants — no request fails
    /// because of an eviction, reloads never exceed evictions, and
    /// predictions are bit-identical before and after a forced
    /// evict-everything pass.
    pub evict_budget: bool,
    /// Route infer traffic through the socket front-end and abuse the
    /// connections: abrupt disconnect mid-request, half-close after
    /// send, and slow readers.  The soak asserts no dispatcher wedges
    /// and the serving invariants hold regardless.
    pub conn_churn: bool,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn all() -> FaultPlan {
        FaultPlan {
            cancel_storm: true,
            worker_death: true,
            evict: true,
            malformed: true,
            evict_budget: true,
            conn_churn: true,
        }
    }

    /// Parse a comma-separated fault list: `cancel-storm`,
    /// `worker-death`, `evict`, `malformed`, `evict-budget`,
    /// `conn-churn`, plus the shorthands `all` and `none`.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::none();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            match part {
                "cancel-storm" => plan.cancel_storm = true,
                "worker-death" => plan.worker_death = true,
                "evict" => plan.evict = true,
                "malformed" => plan.malformed = true,
                "evict-budget" => plan.evict_budget = true,
                "conn-churn" => plan.conn_churn = true,
                "all" => plan = FaultPlan::all(),
                "none" => plan = FaultPlan::none(),
                other => {
                    return Err(anyhow!(
                        "unknown fault {other:?}; expected cancel-storm, worker-death, \
                         evict, malformed, evict-budget, conn-churn, all, or none"
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// True when the plan needs a [`FaultHook`] wired into the service.
    pub fn service_side(&self) -> bool {
        self.cancel_storm || self.worker_death
    }

    /// Would this plan cancel the given job? (The soak driver uses this
    /// to classify a job's `cancelled` outcome as expected.)
    pub fn storms_job(&self, id: JobId) -> bool {
        self.cancel_storm && id.0 % 5 == 3
    }

    /// Would this plan kill the given job's worker?
    pub fn kills_job(&self, id: JobId) -> bool {
        self.worker_death && id.0 % 7 == 4
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut parts = Vec::new();
        if self.cancel_storm {
            parts.push("cancel-storm");
        }
        if self.worker_death {
            parts.push("worker-death");
        }
        if self.evict {
            parts.push("evict");
        }
        if self.malformed {
            parts.push("malformed");
        }
        if self.evict_budget {
            parts.push("evict-budget");
        }
        if self.conn_churn {
            parts.push("conn-churn");
        }
        if parts.is_empty() {
            f.write_str("none")
        } else {
            f.write_str(&parts.join(","))
        }
    }
}

/// The service-side [`FaultHook`] a soak run installs.  Worker death
/// takes precedence over the storm when a job matches both schedules.
pub struct PlanHook {
    plan: FaultPlan,
}

impl PlanHook {
    pub fn new(plan: FaultPlan) -> PlanHook {
        PlanHook { plan }
    }
}

impl FaultHook for PlanHook {
    fn on_step(&self, job: JobId, step: usize) -> FaultAction {
        if self.plan.kills_job(job) && step == 1 {
            return FaultAction::Panic;
        }
        if self.plan.storms_job(job) && step == 2 {
            return FaultAction::Cancel;
        }
        FaultAction::None
    }
}

/// Install a process-wide panic hook that swallows the *injected*
/// worker-death panics (their message carries "injected worker death")
/// and forwards everything else to the previous hook.  Installed once
/// and never removed — restoring a hook races with concurrent tests,
/// and the filter is inert outside fault injection.
pub fn silence_injected_panics() {
    static INSTALLED: OnceLock<()> = OnceLock::new();
    INSTALLED.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .unwrap_or("");
            if !msg.contains("injected worker death") {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_rejects_unknown() {
        assert_eq!(FaultPlan::parse("none").unwrap(), FaultPlan::none());
        assert_eq!(FaultPlan::parse("all").unwrap(), FaultPlan::all());
        let p = FaultPlan::parse("cancel-storm, worker-death").unwrap();
        assert!(p.cancel_storm && p.worker_death && !p.evict && !p.malformed);
        assert!(!p.evict_budget && !p.conn_churn);
        assert_eq!(p.to_string(), "cancel-storm,worker-death");
        let p = FaultPlan::parse("evict-budget").unwrap();
        assert!(p.evict_budget && !p.cancel_storm && !p.evict);
        assert_eq!(p.to_string(), "evict-budget");
        let p = FaultPlan::parse("conn-churn").unwrap();
        assert!(p.conn_churn && !p.evict_budget && !p.malformed);
        assert_eq!(p.to_string(), "conn-churn");
        assert_eq!(FaultPlan::parse(&FaultPlan::all().to_string()).unwrap(), FaultPlan::all());
        assert_eq!(FaultPlan::none().to_string(), "none");
        assert!(FaultPlan::parse("cancel_storm").is_err());
    }

    #[test]
    fn schedules_are_deterministic_by_job_id() {
        let plan = FaultPlan::all();
        let hook = PlanHook::new(plan);
        assert_eq!(hook.on_step(JobId(3), 2), FaultAction::Cancel);
        assert_eq!(hook.on_step(JobId(3), 1), FaultAction::None);
        assert_eq!(hook.on_step(JobId(4), 1), FaultAction::Panic);
        assert_eq!(hook.on_step(JobId(5), 2), FaultAction::None);
        // A job on both schedules dies rather than cancels (id 18 ≡ 3
        // mod 5 and ≡ 4 mod 7) — precedence is fixed, not racy.
        assert_eq!(hook.on_step(JobId(18), 1), FaultAction::Panic);
        assert!(plan.storms_job(JobId(18)) && plan.kills_job(JobId(18)));
        // No faults planned -> never fires.
        let quiet = PlanHook::new(FaultPlan::none());
        assert_eq!(quiet.on_step(JobId(3), 2), FaultAction::None);
        assert_eq!(quiet.on_step(JobId(4), 1), FaultAction::None);
    }
}

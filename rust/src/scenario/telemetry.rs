//! Soak telemetry: latency histograms, queue-depth series, pool
//! occupancy, and the [`SoakReport`] that serializes all of it as
//! `SOAK_report.json` (field definitions in DESIGN.md §Scenario
//! harness).

use crate::util::json::{arr, finite_num, num, obj, str as jstr, Json};
use crate::util::stats::percentile;

/// Upper bucket edges (ms) of the fixed log2 latency histogram; one
/// extra overflow bucket follows.  Fixed edges keep the report's
/// structure host-independent — only counts vary with machine speed.
const HIST_EDGES_MS: [f64; 17] = [
    0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0,
    2048.0, 4096.0, 8192.0, 16384.0,
];

/// A latency sample set with percentile + histogram serialization.
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn push(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn p(&self, pct: f64) -> f64 {
        percentile(&self.samples_ms, pct)
    }

    /// `{count, p50_ms, p95_ms, p99_ms, histogram: {le_ms, counts}}`;
    /// empty sets serialize percentiles as null (never NaN — the file
    /// must stay parseable JSON).
    pub fn to_json(&self) -> Json {
        let mut counts = vec![0u64; HIST_EDGES_MS.len() + 1];
        for s in &self.samples_ms {
            let idx = HIST_EDGES_MS
                .iter()
                .position(|e| s <= e)
                .unwrap_or(HIST_EDGES_MS.len());
            counts[idx] += 1;
        }
        obj(vec![
            ("count", num(self.count() as f64)),
            ("p50_ms", finite_num(self.p(50.0))),
            ("p95_ms", finite_num(self.p(95.0))),
            ("p99_ms", finite_num(self.p(99.0))),
            (
                "histogram",
                obj(vec![
                    ("le_ms", arr(HIST_EDGES_MS.iter().map(|e| num(*e)))),
                    ("counts", arr(counts.iter().map(|c| num(*c as f64)))),
                ]),
            ),
        ])
    }
}

/// How many of each trace op the driver executed.
#[derive(Debug, Default, Clone)]
pub struct OpCounts {
    pub submits: usize,
    pub infers: usize,
    pub cancels: usize,
    pub forgets: usize,
    pub evicts: usize,
    pub frames: usize,
}

impl OpCounts {
    pub fn total(&self) -> usize {
        self.submits + self.infers + self.cancels + self.forgets + self.evicts + self.frames
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("submits", num(self.submits as f64)),
            ("infers", num(self.infers as f64)),
            ("cancels", num(self.cancels as f64)),
            ("forgets", num(self.forgets as f64)),
            ("evicts", num(self.evicts as f64)),
            ("frames", num(self.frames as f64)),
        ])
    }
}

/// Terminal-outcome classification across all submitted jobs.
#[derive(Debug, Default, Clone)]
pub struct JobOutcomes {
    pub done: usize,
    /// Failed with a cancellation error (client cancel or cancel storm).
    pub cancelled: usize,
    /// Failed with a contained worker panic (worker-death fault).
    pub panicked: usize,
    /// Failed because the service shut down first (truncated runs).
    pub shutdown: usize,
    /// Any other failure — counted AND reported as a violation.
    pub unexpected: usize,
}

impl JobOutcomes {
    pub fn total(&self) -> usize {
        self.done + self.cancelled + self.panicked + self.shutdown + self.unexpected
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("done", num(self.done as f64)),
            ("cancelled", num(self.cancelled as f64)),
            ("panicked", num(self.panicked as f64)),
            ("shutdown", num(self.shutdown as f64)),
            ("unexpected", num(self.unexpected as f64)),
        ])
    }
}

/// Everything a soak run measured, serialized as `SOAK_report.json`.
#[derive(Debug, Default, Clone)]
pub struct SoakReport {
    pub seed: u64,
    pub faults: String,
    pub workers: usize,
    /// Events in the trace vs. events actually executed (fewer when the
    /// wallclock cap truncated the run).
    pub events_total: usize,
    pub events_replayed: usize,
    pub truncated: bool,
    pub soak_seconds: f64,
    pub ops: OpCounts,
    pub jobs: JobOutcomes,
    /// (ms since start, queue depth) sampled before each event.
    pub queue_depth: Vec<(f64, usize)>,
    /// Final (variant, precision) keys resident in the infer cache.
    pub pool_occupancy: Vec<(String, String)>,
    pub pool_loads: u64,
    pub pool_evictions: u64,
    pub submit_to_done: LatencyStats,
    pub infer_roundtrip: LatencyStats,
    /// Variant-store counters at end of run (`None` = no store
    /// attached; the `store` key is then absent from the JSON).
    pub store: Option<crate::store::StoreStats>,
    /// Delta jobs whose predictions were verified bit-identical across
    /// a forced evict-everything pass (evict-budget fault).
    pub store_verified: usize,
    /// Socket front-end telemetry (`None` = in-process soak; present
    /// when `--listen` or the conn-churn fault routed infer traffic
    /// over real sockets): the [`crate::net::NetStats`] counters plus
    /// the driver's socket/churn op counts.
    pub net: Option<Json>,
    /// Invariant violations; a healthy soak ends with this EMPTY.
    pub violations: Vec<String>,
}

impl SoakReport {
    pub fn queue_depth_max(&self) -> usize {
        self.queue_depth.iter().map(|(_, d)| *d).max().unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        // Downsample the depth series to ~64 points (stride-sampled,
        // deterministic for a given series) — the max is exact.
        let stride = (self.queue_depth.len() / 64).max(1);
        let series: Vec<Json> = self
            .queue_depth
            .iter()
            .step_by(stride)
            .map(|(ms, d)| arr([num(*ms), num(*d as f64)]))
            .collect();
        let mut fields = vec![
            ("seed", num(self.seed as f64)),
            ("faults", jstr(self.faults.clone())),
            ("workers", num(self.workers as f64)),
            ("events_total", num(self.events_total as f64)),
            ("events_replayed", num(self.events_replayed as f64)),
            ("truncated", Json::Bool(self.truncated)),
            ("soak_seconds", finite_num(self.soak_seconds)),
            ("ops", self.ops.to_json()),
            ("jobs", self.jobs.to_json()),
            (
                "queue_depth",
                obj(vec![
                    ("max", num(self.queue_depth_max() as f64)),
                    ("samples", num(self.queue_depth.len() as f64)),
                    ("series", Json::Arr(series)),
                ]),
            ),
            (
                "pool",
                obj(vec![
                    ("loads", num(self.pool_loads as f64)),
                    ("evictions", num(self.pool_evictions as f64)),
                    (
                        "occupancy",
                        arr(self.pool_occupancy.iter().map(|(m, p)| {
                            obj(vec![("model", jstr(m.clone())), ("precision", jstr(p.clone()))])
                        })),
                    ),
                ]),
            ),
            ("submit_to_done", self.submit_to_done.to_json()),
            ("infer_roundtrip", self.infer_roundtrip.to_json()),
        ];
        if let Some(s) = &self.store {
            let mut store = crate::serve::store_stat_fields(s);
            store.push(("verified_jobs", num(self.store_verified as f64)));
            fields.push(("store", obj(store)));
        }
        if let Some(n) = &self.net {
            fields.push(("net", n.clone()));
        }
        fields.push((
            "violations",
            arr(self.violations.iter().map(|v| jstr(v.clone()))),
        ));
        obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stats_histogram_and_percentiles() {
        let mut l = LatencyStats::default();
        for ms in [0.1, 0.3, 1.5, 3.0, 100.0, 20_000.0] {
            l.push(ms);
        }
        let j = l.to_json();
        assert_eq!(j.get("count").and_then(|v| v.as_usize()), Some(6));
        assert!(j.get("p50_ms").and_then(|v| v.as_f64()).is_some());
        let counts = j
            .get("histogram")
            .and_then(|h| h.get("counts"))
            .unwrap()
            .f64_vec()
            .unwrap();
        assert_eq!(counts.len(), HIST_EDGES_MS.len() + 1);
        assert_eq!(counts.iter().sum::<f64>(), 6.0);
        assert_eq!(counts[0], 1.0, "0.1ms lands in the first bucket");
        assert_eq!(*counts.last().unwrap(), 1.0, "20s lands in overflow");
    }

    #[test]
    fn empty_stats_serialize_null_not_nan() {
        let j = LatencyStats::default().to_json();
        assert_eq!(j.get("p50_ms"), Some(&Json::Null));
        // The serialized form must be parseable JSON.
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn report_serializes_and_reparses() {
        let mut r = SoakReport {
            seed: 233,
            faults: "cancel-storm,worker-death".into(),
            workers: 2,
            events_total: 10,
            events_replayed: 10,
            ..SoakReport::default()
        };
        r.queue_depth = (0..200).map(|i| (i as f64, i % 7)).collect();
        r.pool_occupancy.push(("vit_demo_vanilla".into(), "i8".into()));
        r.submit_to_done.push(12.0);
        r.violations.push("example".into());
        let j = r.to_json();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("queue_depth").and_then(|q| q.get("max")).and_then(|v| v.as_usize()), Some(6));
        let series = back
            .get("queue_depth")
            .and_then(|q| q.get("series"))
            .and_then(|v| v.as_arr())
            .unwrap();
        assert!(series.len() <= 67, "downsampled series stays bounded");
        assert_eq!(
            back.get("violations").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(1)
        );
        assert!(back.get("store").is_none(), "no store attached, no store key");
        r.store = Some(crate::store::StoreStats {
            puts: 3,
            evictions: 2,
            ..Default::default()
        });
        r.store_verified = 1;
        let back = Json::parse(&r.to_json().to_string()).unwrap();
        let s = back.get("store").unwrap();
        assert_eq!(s.get("puts").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(s.get("evictions").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(s.get("verified_jobs").and_then(|v| v.as_usize()), Some(1));
    }
}

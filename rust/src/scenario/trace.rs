//! Workload trace format: the JSON-lines record/replay layer of the
//! scenario harness (DESIGN.md §Scenario harness).
//!
//! A trace is an ordered list of timestamped operations against a
//! [`crate::serve::Service`].  Cancel/forget events target jobs by
//! **submit ordinal** (the k-th submit in the trace, 0-based) rather
//! than by `JobId`, so a recorded trace replays identically against a
//! fresh service whose ids start over.  One JSON object per line:
//!
//! ```json
//! {"at_ms":12.5,"op":"submit","model":"vit_demo_vanilla","steps":4,
//!  "samples":32,"seed":7,"precision":"bf16"}
//! {"at_ms":14.0,"op":"infer","model":"vit_demo_vanilla","precision":"i8","seed":3}
//! {"at_ms":20.0,"op":"cancel","submit":0}
//! ```
//!
//! `f64` timestamps round-trip exactly (Rust's float `Display` is
//! shortest-roundtrip), so a written trace re-reads bit-identically.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::precision::Precision;
use crate::util::json::{num, obj, str as jstr, Json};

/// One operation against the service under soak.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceOp {
    /// Enqueue a fine-tune job.
    Submit { model: String, steps: usize, samples: usize, seed: u64, precision: Precision },
    /// Pool inference on the driver thread.
    Infer { model: String, precision: Precision, seed: u64 },
    /// Cancel the job created by the trace's `submit`-th submit event.
    Cancel { submit: usize },
    /// Forget that job (a no-op unless it is already terminal).
    Forget { submit: usize },
    /// Evict a (variant, precision) entry from the shared infer cache
    /// (the eviction-under-use fault).
    Evict { model: String, precision: Precision },
    /// Push a raw protocol frame through `serve::proto::handle_line`
    /// (the malformed-frame fault; the response must be in-band).
    Frame { line: String },
}

/// A timestamped [`TraceOp`]; `at_ms` is milliseconds since soak start
/// (honored when pacing is enabled, recorded either way).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at_ms: f64,
    pub op: TraceOp,
}

impl TraceEvent {
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&'static str, Json)> = vec![("at_ms", num(self.at_ms))];
        match &self.op {
            TraceOp::Submit { model, steps, samples, seed, precision } => {
                fields.push(("op", jstr("submit")));
                fields.push(("model", jstr(model.clone())));
                fields.push(("steps", num(*steps as f64)));
                fields.push(("samples", num(*samples as f64)));
                fields.push(("seed", num(*seed as f64)));
                fields.push(("precision", jstr(precision.to_string())));
            }
            TraceOp::Infer { model, precision, seed } => {
                fields.push(("op", jstr("infer")));
                fields.push(("model", jstr(model.clone())));
                fields.push(("precision", jstr(precision.to_string())));
                fields.push(("seed", num(*seed as f64)));
            }
            TraceOp::Cancel { submit } => {
                fields.push(("op", jstr("cancel")));
                fields.push(("submit", num(*submit as f64)));
            }
            TraceOp::Forget { submit } => {
                fields.push(("op", jstr("forget")));
                fields.push(("submit", num(*submit as f64)));
            }
            TraceOp::Evict { model, precision } => {
                fields.push(("op", jstr("evict")));
                fields.push(("model", jstr(model.clone())));
                fields.push(("precision", jstr(precision.to_string())));
            }
            TraceOp::Frame { line } => {
                fields.push(("op", jstr("frame")));
                fields.push(("line", jstr(line.clone())));
            }
        }
        obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<TraceEvent> {
        let at_ms = v
            .req("at_ms")?
            .as_f64()
            .ok_or_else(|| anyhow!("\"at_ms\" must be a number"))?;
        let op_name = v
            .req("op")?
            .as_str()
            .ok_or_else(|| anyhow!("\"op\" must be a string"))?;
        let model = |key: &str| -> Result<String> {
            Ok(v.req(key)?
                .as_str()
                .ok_or_else(|| anyhow!("{key:?} must be a string"))?
                .to_string())
        };
        let uint = |key: &str| -> Result<usize> {
            v.req(key)?
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as usize)
                .ok_or_else(|| anyhow!("{key:?} must be a non-negative integer"))
        };
        let precision = || -> Result<Precision> {
            v.req("precision")?
                .as_str()
                .ok_or_else(|| anyhow!("\"precision\" must be a string"))?
                .parse()
        };
        let op = match op_name {
            "submit" => TraceOp::Submit {
                model: model("model")?,
                steps: uint("steps")?,
                samples: uint("samples")?,
                seed: uint("seed")? as u64,
                precision: precision()?,
            },
            "infer" => TraceOp::Infer {
                model: model("model")?,
                precision: precision()?,
                seed: uint("seed")? as u64,
            },
            "cancel" => TraceOp::Cancel { submit: uint("submit")? },
            "forget" => TraceOp::Forget { submit: uint("submit")? },
            "evict" => TraceOp::Evict { model: model("model")?, precision: precision()? },
            "frame" => TraceOp::Frame { line: model("line")? },
            other => return Err(anyhow!("unknown trace op {other:?}")),
        };
        Ok(TraceEvent { at_ms, op })
    }
}

/// Serialize a trace as JSON-lines text (one event per line).
pub fn to_lines(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&ev.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Parse a JSON-lines trace (blank lines skipped); errors carry the
/// offending line number.
pub fn from_lines(text: &str) -> Result<Vec<TraceEvent>> {
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
        events.push(
            TraceEvent::from_json(&v).with_context(|| format!("trace line {}", i + 1))?,
        );
    }
    Ok(events)
}

pub fn write_trace(path: &Path, events: &[TraceEvent]) -> Result<()> {
    std::fs::write(path, to_lines(events))
        .with_context(|| format!("writing trace {}", path.display()))
}

pub fn read_trace(path: &Path) -> Result<Vec<TraceEvent>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    from_lines(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                at_ms: 0.0,
                op: TraceOp::Submit {
                    model: "vit_demo_vanilla".into(),
                    steps: 4,
                    samples: 32,
                    seed: 7,
                    precision: Precision::Bf16,
                },
            },
            TraceEvent {
                at_ms: 1.25,
                op: TraceOp::Infer {
                    model: "vit_demo_wasi_eps80".into(),
                    precision: Precision::I8,
                    seed: 3,
                },
            },
            TraceEvent { at_ms: 2.5000001, op: TraceOp::Cancel { submit: 0 } },
            TraceEvent { at_ms: 3.0, op: TraceOp::Forget { submit: 0 } },
            TraceEvent {
                at_ms: 4.0,
                op: TraceOp::Evict {
                    model: "vit_demo_wasi_eps80".into(),
                    precision: Precision::I8,
                },
            },
            TraceEvent {
                at_ms: 5.0,
                op: TraceOp::Frame { line: "{\"cmd\":\"bogus\"}".into() },
            },
        ]
    }

    #[test]
    fn trace_roundtrips_bit_exactly() {
        let events = sample();
        let text = to_lines(&events);
        let back = from_lines(&text).unwrap();
        assert_eq!(events, back);
        // And a second serialization is byte-identical (f64 Display is
        // shortest-roundtrip; objects serialize deterministically).
        assert_eq!(text, to_lines(&back));
    }

    #[test]
    fn trace_rejects_malformed_lines() {
        assert!(from_lines("{\"at_ms\":0.0,\"op\":\"nope\"}\n").is_err());
        assert!(from_lines("{\"op\":\"cancel\",\"submit\":0}\n").is_err()); // no at_ms
        let err = from_lines("{}\nnot json\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 1"), "{err:#}");
        // Negative ordinals and fractional steps are rejected.
        assert!(from_lines("{\"at_ms\":0,\"op\":\"cancel\",\"submit\":-1}\n").is_err());
        assert!(from_lines(
            "{\"at_ms\":0,\"op\":\"submit\",\"model\":\"m\",\"steps\":1.5,\
             \"samples\":32,\"seed\":1,\"precision\":\"f32\"}\n"
        )
        .is_err());
    }
}

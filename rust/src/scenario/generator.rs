//! Deterministic workload synthesis: a seeded generator emits mixed
//! submit/infer/cancel/forget trace events with exponential
//! inter-arrival times over a Zipf-distributed population of
//! (variant, precision) pairs — the "many users, few hot variants"
//! shape of on-device personalization traffic.
//!
//! Everything is a pure function of [`GeneratorConfig`]: the same
//! config (same seed) produces the same [`TraceEvent`] sequence,
//! which is what makes a failing soak reproducible from its trace.

use crate::data::rng::Pcg64;
use crate::precision::Precision;

use super::trace::{TraceEvent, TraceOp};

/// Knobs for the synthetic workload.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// Number of events to emit.
    pub events: usize,
    /// PRNG seed; the whole trace is a pure function of this config.
    pub seed: u64,
    /// Variant names to spread load over (Zipf-ranked in this order).
    pub variants: Vec<String>,
    /// Mean gap between events in milliseconds (exponential arrivals).
    pub mean_interarrival_ms: f64,
    /// Zipf exponent over the variant × precision population (0 =
    /// uniform; ~1 = classic "one hot user" skew).
    pub zipf_exponent: f64,
    /// Training steps per submitted job, sampled uniformly inclusive.
    pub steps_range: (usize, usize),
    /// Samples per job (fixed; the job's synthetic dataset size).
    pub samples: usize,
    /// Mix in pool-eviction events (the eviction-under-use fault).
    pub evict: bool,
    /// Mix in malformed protocol frames (the malformed-frame fault).
    pub malformed: bool,
}

impl GeneratorConfig {
    /// Defaults sized for the CI quick soak: small jobs, hot arrivals.
    pub fn new(variants: Vec<String>, events: usize, seed: u64) -> GeneratorConfig {
        GeneratorConfig {
            events,
            seed,
            variants,
            mean_interarrival_ms: 4.0,
            zipf_exponent: 1.0,
            steps_range: (3, 8),
            samples: 32,
            evict: false,
            malformed: false,
        }
    }
}

/// Malformed frames cycled through by the generator (each must draw an
/// in-band `ok:false`, pinned by the proto fuzz tests).
const BAD_FRAMES: &[&str] = &[
    "this is not json",
    "{\"cmd\":\"submit\"",
    "{\"cmd\":\"frobnicate\"}",
    "{\"cmd\":\"submit\",\"model\":\"m\",\"step\":5}",
    "{\"cmd\":\"infer\",\"model\":\"m\",\"x\":[1e999]}",
    "{\"cmd\":\"status\",\"job\":-3}",
];

/// Generate a trace.  Cancel/forget events target earlier submits by
/// ordinal; until the first submit exists they degrade to infers, so
/// every emitted event is executable.
pub fn generate(cfg: &GeneratorConfig) -> Vec<TraceEvent> {
    assert!(!cfg.variants.is_empty(), "generator needs at least one variant");
    let mut rng = Pcg64::new(cfg.seed);

    // Zipf over the variant × {f32, bf16, i8} population: rank r gets
    // weight (r+1)^-s; sampling walks the cumulative table.
    let population: Vec<(usize, Precision)> = (0..cfg.variants.len())
        .flat_map(|v| [Precision::F32, Precision::Bf16, Precision::I8].map(|p| (v, p)))
        .collect();
    let cdf: Vec<f64> = {
        let weights: Vec<f64> = (0..population.len())
            .map(|r| 1.0 / ((r + 1) as f64).powf(cfg.zipf_exponent))
            .collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect()
    };
    let mut zipf = move |rng: &mut Pcg64| -> (usize, Precision) {
        let u = rng.next_f64();
        let idx = cdf.iter().position(|c| u < *c).unwrap_or(cdf.len() - 1);
        population[idx]
    };

    let mut events = Vec::with_capacity(cfg.events);
    let mut clock_ms = 0.0f64;
    let mut submits = 0usize;
    let mut bad_frame = 0usize;
    for _ in 0..cfg.events {
        // Exponential inter-arrival: -mean * ln(1 - u).
        clock_ms += -cfg.mean_interarrival_ms * (1.0 - rng.next_f64()).ln();
        // Op mix: 25% submit, 45% infer, 10% cancel, 10% forget, and
        // (when enabled) 5% evict + 5% malformed frame; disabled fault
        // mass folds into infer.
        let roll = rng.next_f64();
        let op = if roll < 0.25 {
            let (v, p) = zipf(&mut rng);
            submits += 1;
            TraceOp::Submit {
                model: cfg.variants[v].clone(),
                steps: cfg.steps_range.0
                    + rng.below(cfg.steps_range.1 - cfg.steps_range.0 + 1),
                samples: cfg.samples,
                seed: rng.next_u64() % 10_000,
                // int8 is inference-only; training submits coerce to f32.
                precision: if p == Precision::I8 { Precision::F32 } else { p },
            }
        } else if roll < 0.80 && submits > 0 && roll >= 0.70 {
            if roll < 0.75 {
                TraceOp::Cancel { submit: rng.below(submits) }
            } else {
                TraceOp::Forget { submit: rng.below(submits) }
            }
        } else if cfg.evict && (0.80..0.85).contains(&roll) {
            let (v, p) = zipf(&mut rng);
            TraceOp::Evict { model: cfg.variants[v].clone(), precision: p }
        } else if cfg.malformed && (0.85..0.90).contains(&roll) {
            bad_frame += 1;
            TraceOp::Frame { line: BAD_FRAMES[bad_frame % BAD_FRAMES.len()].to_string() }
        } else {
            let (v, p) = zipf(&mut rng);
            TraceOp::Infer {
                model: cfg.variants[v].clone(),
                precision: p,
                seed: rng.next_u64() % 10_000,
            }
        };
        events.push(TraceEvent { at_ms: clock_ms, op });
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_cfg(events: usize, seed: u64) -> GeneratorConfig {
        GeneratorConfig::new(vec!["a".into(), "b".into()], events, seed)
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(&demo_cfg(200, 42));
        let b = generate(&demo_cfg(200, 42));
        assert_eq!(a, b);
        let c = generate(&demo_cfg(200, 43));
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn mix_covers_all_ops_and_targets_are_valid() {
        let mut cfg = demo_cfg(600, 7);
        cfg.evict = true;
        cfg.malformed = true;
        let events = generate(&cfg);
        assert_eq!(events.len(), 600);
        let mut submits = 0usize;
        let mut counts = [0usize; 6];
        let mut last_ms = 0.0;
        for ev in &events {
            assert!(ev.at_ms >= last_ms, "timestamps must be monotone");
            last_ms = ev.at_ms;
            match &ev.op {
                TraceOp::Submit { steps, precision, .. } => {
                    assert!((3..=8).contains(steps));
                    assert!(precision.trainable(), "submits must be trainable precisions");
                    submits += 1;
                    counts[0] += 1;
                }
                TraceOp::Infer { .. } => counts[1] += 1,
                TraceOp::Cancel { submit } => {
                    assert!(*submit < submits, "cancel must target an earlier submit");
                    counts[2] += 1;
                }
                TraceOp::Forget { submit } => {
                    assert!(*submit < submits);
                    counts[3] += 1;
                }
                TraceOp::Evict { .. } => counts[4] += 1,
                TraceOp::Frame { .. } => counts[5] += 1,
            }
        }
        for (i, c) in counts.iter().enumerate() {
            assert!(*c > 0, "op kind {i} never generated: {counts:?}");
        }
    }

    #[test]
    fn zipf_skews_toward_first_variant() {
        let events = generate(&demo_cfg(500, 9));
        let first = events
            .iter()
            .filter(|e| matches!(&e.op, TraceOp::Infer { model, .. } if model == "a"))
            .count();
        let second = events
            .iter()
            .filter(|e| matches!(&e.op, TraceOp::Infer { model, .. } if model == "b"))
            .count();
        assert!(
            first > second,
            "zipf(1.0) must favor the rank-0 variant: {first} vs {second}"
        );
    }

    #[test]
    fn disabled_faults_never_appear() {
        let events = generate(&demo_cfg(400, 11));
        assert!(events
            .iter()
            .all(|e| !matches!(e.op, TraceOp::Evict { .. } | TraceOp::Frame { .. })));
    }
}

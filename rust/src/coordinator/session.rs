//! High-level fine-tuning session: dataset + variant + budget -> report.
//!
//! This is the blocking public API an application embeds (see
//! examples/): pick a dataset preset, a model variant, and an execution
//! engine, fine-tune under the paper's recipe, and get back accuracy,
//! loss curve, wallclock, and the memory breakdown.
//!
//! Since the job-service redesign a `Session` is a thin front over the
//! shared serving core: it holds one [`PoolEntry`] (runtime + manifest,
//! loaded once and shareable with a [`crate::serve::Service`]) and
//! `finetune` runs one job synchronously through the same
//! `serve::runner` code path the multi-session `wasi-train serve`
//! workers execute.  Embedders that want queueing, cancellation, and
//! streamed progress use [`crate::serve::Service`] directly.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::Result;

use crate::engine::EngineKind;
use crate::precision::Precision;
use crate::runtime::{Manifest, Runtime};
use crate::serve::{runner, JobSpec, PoolEntry};
use crate::util::json::{arr, finite_num as fnum, num, obj, str as jstr, Json};

use super::memory::MemoryBreakdown;

/// What to fine-tune and how.
///
/// Construct via [`FinetuneConfig::builder`] (the stable embedding
/// API — new knobs get builder methods without breaking callers) or
/// struct-update syntax over `Default`.
#[derive(Debug, Clone)]
pub struct FinetuneConfig {
    pub model: String,
    pub dataset: String,
    pub samples: usize,
    pub steps: usize,
    pub seed: u64,
    pub verbose: bool,
    /// Initial learning rate of the cosine schedule (paper App. B.1).
    pub lr0: f32,
    /// Steps between verbose log lines; `None` = steps/10.
    pub log_every: Option<usize>,
    /// Execution engine (`auto` prefers HLO when the runtime can run it).
    pub engine: EngineKind,
    /// Weight-storage precision (`--precision f32|bf16`): bf16 rounds
    /// the stored parameter vector after every step (native engine
    /// only); int8 is inference-only and rejected for training.
    pub precision: Precision,
    /// Kernel-layer worker threads for this run (`None` = leave the
    /// process-global setting alone; `Some(0)` = auto-detect).  The
    /// prior setting is restored when the run finishes.  Results are
    /// bit-identical across thread counts — this trades wall-clock only.
    pub threads: Option<usize>,
}

impl Default for FinetuneConfig {
    fn default() -> Self {
        FinetuneConfig {
            model: "vit_wasi_eps80".into(),
            dataset: "cifar10-like".into(),
            samples: 512,
            steps: 200,
            seed: 233, // the paper's fixed seed (App. B.2)
            verbose: false,
            lr0: 0.05, // paper App. B.1
            log_every: None,
            engine: EngineKind::Auto,
            precision: Precision::F32,
            threads: None,
        }
    }
}

impl FinetuneConfig {
    /// Fluent builder starting from the paper defaults:
    /// `FinetuneConfig::builder().model("vit_wasi_eps80").steps(100).build()`.
    pub fn builder() -> FinetuneConfigBuilder {
        FinetuneConfigBuilder { cfg: FinetuneConfig::default() }
    }
}

/// Builder for [`FinetuneConfig`]; every method overrides one default.
#[derive(Debug, Clone)]
pub struct FinetuneConfigBuilder {
    cfg: FinetuneConfig,
}

impl FinetuneConfigBuilder {
    pub fn model(mut self, model: impl Into<String>) -> Self {
        self.cfg.model = model.into();
        self
    }

    pub fn dataset(mut self, dataset: impl Into<String>) -> Self {
        self.cfg.dataset = dataset.into();
        self
    }

    pub fn samples(mut self, samples: usize) -> Self {
        self.cfg.samples = samples;
        self
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.cfg.steps = steps;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn verbose(mut self, verbose: bool) -> Self {
        self.cfg.verbose = verbose;
        self
    }

    pub fn lr0(mut self, lr0: f32) -> Self {
        self.cfg.lr0 = lr0;
        self
    }

    pub fn log_every(mut self, every: usize) -> Self {
        self.cfg.log_every = Some(every);
        self
    }

    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.cfg.engine = engine;
        self
    }

    pub fn precision(mut self, precision: Precision) -> Self {
        self.cfg.precision = precision;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = Some(threads);
        self
    }

    pub fn build(self) -> FinetuneConfig {
        self.cfg
    }
}

/// Results of one session.
#[derive(Debug, Clone)]
pub struct FinetuneReport {
    pub model: String,
    pub dataset: String,
    /// Engine that actually executed (`"hlo"` / `"native"`).
    pub engine: &'static str,
    /// Weight-storage precision the run trained at.
    pub precision: Precision,
    pub final_loss: f64,
    pub val_accuracy: f64,
    pub mean_step_seconds: f64,
    pub total_seconds: f64,
    pub memory: MemoryBreakdown,
    pub loss_curve: Vec<(usize, f32)>,
}

impl FinetuneReport {
    /// JSON shape used by the serve protocol's `done` responses and the
    /// bench record.  Non-finite metrics (NaN accuracy on an empty val
    /// split, a diverged loss) serialize as `null` to stay valid JSON.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", jstr(self.model.clone())),
            ("dataset", jstr(self.dataset.clone())),
            ("engine", jstr(self.engine)),
            ("precision", jstr(self.precision.to_string())),
            ("final_loss", fnum(self.final_loss)),
            ("val_accuracy", fnum(self.val_accuracy)),
            ("mean_step_seconds", num(self.mean_step_seconds)),
            ("total_seconds", num(self.total_seconds)),
            ("memory_mb", num(self.memory.total_mb_at(self.precision))),
            (
                "loss_curve",
                arr(self
                    .loss_curve
                    .iter()
                    .map(|(s, l)| arr([num(*s as f64), fnum(*l as f64)]))),
            ),
        ])
    }
}

/// Owns (a shared handle to) the runtime + manifest and runs sessions.
pub struct Session {
    entry: Arc<PoolEntry>,
}

impl Session {
    pub fn open(artifacts_dir: &str) -> Result<Session> {
        Ok(Session { entry: PoolEntry::open(artifacts_dir)? })
    }

    /// Wrap an already-loaded pool entry (shares the runtime/manifest
    /// with a running service instead of loading the artifacts again).
    pub fn from_pool(entry: Arc<PoolEntry>) -> Session {
        Session { entry }
    }

    /// The artifact runtime backing this session.
    pub fn runtime(&self) -> &Runtime {
        &self.entry.runtime
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.entry.manifest
    }

    /// The shared pool entry (hand this to `serve::Service` /
    /// `Session::from_pool` to reuse the loaded artifacts).
    pub fn pool_entry(&self) -> &Arc<PoolEntry> {
        &self.entry
    }

    /// Fine-tune one variant on one dataset preset; returns the report.
    ///
    /// Blocking single-job front over the same `serve::runner` path the
    /// job service executes — CLI, examples, and `serve` all train
    /// through one code path.
    pub fn finetune(&self, cfg: &FinetuneConfig) -> Result<FinetuneReport> {
        let spec = JobSpec::new(cfg.clone());
        let never = AtomicBool::new(false);
        let outcome = runner::execute_job(&self.entry, &spec, &mut |_| {}, &never)?;
        Ok(outcome.report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::demo::{write_demo_artifacts, DemoConfig};
    use crate::util::threadpool::{set_num_threads, thread_override, TEST_OVERRIDE_LOCK};

    #[test]
    fn builder_overrides_defaults() {
        let cfg = FinetuneConfig::builder()
            .model("m")
            .dataset("d")
            .samples(32)
            .steps(7)
            .seed(9)
            .lr0(0.125)
            .log_every(2)
            .engine(EngineKind::Native)
            .precision(Precision::Bf16)
            .threads(3)
            .build();
        assert_eq!(cfg.model, "m");
        assert_eq!(cfg.dataset, "d");
        assert_eq!(cfg.samples, 32);
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.lr0, 0.125);
        assert_eq!(cfg.log_every, Some(2));
        assert_eq!(cfg.engine, EngineKind::Native);
        assert_eq!(cfg.precision, Precision::Bf16);
        assert_eq!(cfg.threads, Some(3));
        // Untouched knobs keep the paper defaults.
        assert!(!cfg.verbose);
    }

    #[test]
    fn report_json_is_wellformed() {
        let report = FinetuneReport {
            model: "m".into(),
            dataset: "d".into(),
            engine: "native",
            precision: Precision::F32,
            final_loss: 1.5,
            val_accuracy: 0.5,
            mean_step_seconds: 0.01,
            total_seconds: 0.1,
            memory: MemoryBreakdown::default(),
            loss_curve: vec![(0, 2.0), (10, 1.0)],
        };
        let j = Json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(j.get("model").and_then(|v| v.as_str()), Some("m"));
        assert_eq!(j.get("final_loss").and_then(|v| v.as_f64()), Some(1.5));
        assert_eq!(
            j.get("loss_curve").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(2)
        );
    }

    #[test]
    fn finetune_restores_prior_thread_setting() {
        // Satellite contract: `FinetuneConfig::threads` must not leak
        // into subsequent sessions in the same process.
        let _guard = TEST_OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join("wasi_session_threads_restore");
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        let session = Session::open(dir.to_str().unwrap()).unwrap();
        set_num_threads(5);
        let report = session
            .finetune(
                &FinetuneConfig::builder()
                    .model("vit_demo_wasi_eps80")
                    .samples(32)
                    .steps(4)
                    .lr0(0.1)
                    .engine(EngineKind::Native)
                    .threads(2)
                    .build(),
            )
            .unwrap();
        assert_eq!(report.engine, "native");
        assert_eq!(
            thread_override(),
            5,
            "threads=2 leaked past the run instead of being restored"
        );
        set_num_threads(0);
    }
}

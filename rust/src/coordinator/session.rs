//! High-level fine-tuning session: dataset + variant + budget -> report.
//!
//! This is the public API an application embeds (see examples/): pick a
//! dataset preset, a model variant, and an execution engine, fine-tune
//! under the paper's recipe, and get back accuracy, loss curve,
//! wallclock, and the memory breakdown.

use anyhow::Result;

use crate::data::synth::VisionTask;
use crate::data::Loader;
use crate::engine::EngineKind;
use crate::runtime::{Manifest, Runtime};

use super::memory::{account, MemoryBreakdown};
use super::trainer::{TrainConfig, Trainer};

/// What to fine-tune and how.
#[derive(Debug, Clone)]
pub struct FinetuneConfig {
    pub model: String,
    pub dataset: String,
    pub samples: usize,
    pub steps: usize,
    pub seed: u64,
    pub verbose: bool,
    /// Initial learning rate of the cosine schedule (paper App. B.1).
    pub lr0: f32,
    /// Steps between verbose log lines; `None` = steps/10.
    pub log_every: Option<usize>,
    /// Execution engine (`auto` prefers HLO when the runtime can run it).
    pub engine: EngineKind,
    /// Kernel-layer worker threads (`None` = leave the process-global
    /// setting alone; `Some(0)` = auto-detect).  Results are
    /// bit-identical across thread counts — this trades wall-clock only.
    pub threads: Option<usize>,
}

impl Default for FinetuneConfig {
    fn default() -> Self {
        FinetuneConfig {
            model: "vit_wasi_eps80".into(),
            dataset: "cifar10-like".into(),
            samples: 512,
            steps: 200,
            seed: 233, // the paper's fixed seed (App. B.2)
            verbose: false,
            lr0: 0.05, // paper App. B.1
            log_every: None,
            engine: EngineKind::Auto,
            threads: None,
        }
    }
}

/// Results of one session.
#[derive(Debug, Clone)]
pub struct FinetuneReport {
    pub model: String,
    pub dataset: String,
    /// Engine that actually executed (`"hlo"` / `"native"`).
    pub engine: &'static str,
    pub final_loss: f64,
    pub val_accuracy: f64,
    pub mean_step_seconds: f64,
    pub total_seconds: f64,
    pub memory: MemoryBreakdown,
    pub loss_curve: Vec<(usize, f32)>,
}

/// Owns the runtime + manifest and runs sessions.
pub struct Session {
    pub runtime: Runtime,
    pub manifest: Manifest,
}

impl Session {
    pub fn open(artifacts_dir: &str) -> Result<Session> {
        Ok(Session {
            runtime: Runtime::cpu()?,
            manifest: Manifest::load(artifacts_dir)?,
        })
    }

    /// Fine-tune one variant on one dataset preset; returns the report.
    pub fn finetune(&self, cfg: &FinetuneConfig) -> Result<FinetuneReport> {
        if let Some(t) = cfg.threads {
            crate::util::threadpool::set_num_threads(t);
        }
        let entry = self.manifest.model(&cfg.model)?;
        let mut task = VisionTask::preset(&cfg.dataset, cfg.seed)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset preset {:?}", cfg.dataset))?;
        if task.classes != entry.classes || task.dim != entry.input_dim {
            // Artifacts are compiled for a fixed class count and image
            // size; presets are re-instantiated to match (documented
            // substitution: the head's class-count and the input
            // resolution are artifact constants).
            let side = entry.image_side().ok_or_else(|| {
                anyhow::anyhow!(
                    "model {} is not an image model (input_dim {})",
                    entry.name,
                    entry.input_dim
                )
            })?;
            task = VisionTask::new(&cfg.dataset, entry.classes, side, 0.7, 8, cfg.seed);
        }
        let mut loader = Loader::from_task(&mut task, cfg.samples, cfg.seed);
        let tcfg = TrainConfig {
            steps: cfg.steps,
            lr0: cfg.lr0,
            log_every: cfg.log_every.unwrap_or((cfg.steps / 10).max(1)),
            verbose: cfg.verbose,
            engine: cfg.engine,
        };
        let mut trainer = Trainer::new(&self.runtime, entry, tcfg)?;
        trainer.run(&mut loader)?;
        let val = trainer.validate(&self.runtime, &loader)?;
        Ok(FinetuneReport {
            model: cfg.model.clone(),
            dataset: cfg.dataset.clone(),
            engine: trainer.engine.backend(),
            final_loss: trainer.metrics.smoothed_loss(),
            val_accuracy: val,
            mean_step_seconds: trainer.metrics.mean_step_seconds(),
            total_seconds: trainer.metrics.total_seconds(),
            memory: account(entry),
            loss_curve: trainer.metrics.loss_curve(50),
        })
    }
}

//! The training loop: drives a [`TrainEngine`] over a Loader.  The
//! engine may be the AOT/HLO step or the native full-model engine —
//! the loop is identical (that is the point of the trait).

use std::time::Instant;

use anyhow::Result;

use crate::data::loader::Loader;
use crate::engine::{infer_engine, train_engine, EngineKind, TrainEngine};
use crate::runtime::Runtime;

use super::metrics::{Metrics, StepRecord};
use super::schedule::CosineSchedule;

/// Loop configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr0: f32,
    pub log_every: usize,
    pub verbose: bool,
    pub engine: EngineKind,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            lr0: 0.05,
            log_every: 20,
            verbose: false,
            engine: EngineKind::Auto,
        }
    }
}

/// A live trainer for one model variant.
pub struct Trainer<'rt> {
    pub engine: Box<dyn TrainEngine + 'rt>,
    pub metrics: Metrics,
    schedule: CosineSchedule,
    cfg: TrainConfig,
}

impl<'rt> Trainer<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        entry: &crate::runtime::ModelEntry,
        mut cfg: TrainConfig,
    ) -> Result<Self> {
        let engine = train_engine(rt, entry, cfg.engine)?;
        let schedule = CosineSchedule { lr0: cfg.lr0, total: cfg.steps };
        // A zero interval would divide by zero in the logging check.
        cfg.log_every = cfg.log_every.max(1);
        Ok(Trainer { engine, metrics: Metrics::default(), schedule, cfg })
    }

    /// Run the configured number of steps against the loader.
    pub fn run(&mut self, loader: &mut Loader) -> Result<()> {
        let batch = self.engine.entry().batch;
        for s in 0..self.cfg.steps {
            let (x, y) = loader.next_batch(batch);
            let lr = self.schedule.lr(s);
            let t0 = Instant::now();
            let out = self.engine.step(&x, &y, lr)?;
            let dt = t0.elapsed().as_secs_f64();
            self.metrics.push(StepRecord {
                step: s,
                loss: out.loss,
                accuracy: out.accuracy,
                lr,
                seconds: dt,
            });
            if self.cfg.verbose && (s % self.cfg.log_every == 0 || s + 1 == self.cfg.steps) {
                eprintln!(
                    "[train {} ({})] step {s:>4} loss {:.4} acc {:.3} lr {:.4} ({:.0} ms)",
                    self.engine.entry().name,
                    self.engine.backend(),
                    out.loss,
                    out.accuracy,
                    lr,
                    dt * 1000.0
                );
            }
        }
        Ok(())
    }

    /// Validation accuracy via the inference engine matching the
    /// backend that actually trained (under `auto` the two could
    /// otherwise resolve differently, and accuracies are not
    /// comparable across engines — DESIGN.md §4).
    pub fn validate(&self, rt: &'rt Runtime, loader: &Loader) -> Result<f64> {
        let infer = infer_engine(rt, self.engine.entry(), self.engine.kind())?;
        let batch = self.engine.entry().batch;
        let n = loader.val_len();
        if n == 0 {
            return Ok(f64::NAN);
        }
        let mut correct = 0usize;
        let mut seen = 0usize;
        let mut start = 0usize;
        while seen < n {
            let (x, labels) = loader.val_batch(start, batch);
            let preds = infer.predict(self.engine.params(), &x)?;
            let take = batch.min(n - seen);
            for i in 0..take {
                if preds[i] == labels[i] {
                    correct += 1;
                }
            }
            seen += take;
            start += batch;
        }
        Ok(correct as f64 / n as f64)
    }
}

//! The training loop: drives a [`TrainEngine`] over a Loader.  The
//! engine may be the AOT/HLO step or the native full-model engine —
//! the loop is identical (that is the point of the trait).
//!
//! The loop is observable and interruptible: [`Trainer::run_observed`]
//! reports every step to a caller-supplied observer (the job service
//! turns these into streamed [`crate::serve::JobEvent`]s) and polls a
//! cancellation flag between steps, which is what makes jobs
//! cancellable without poisoning the engine.  It can also start at a
//! nonzero step (checkpoint resume): the cosine schedule is indexed by
//! absolute step, so a resumed run replays the exact LR tail.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use anyhow::Result;

use crate::data::loader::Loader;
use crate::engine::{infer_engine, train_engine_with, EngineKind, TrainEngine};
use crate::precision::Precision;
use crate::runtime::Runtime;

use super::metrics::{Metrics, StepRecord};
use super::schedule::CosineSchedule;

/// Loop configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr0: f32,
    pub log_every: usize,
    pub verbose: bool,
    pub engine: EngineKind,
    /// Weight-storage precision (bf16 requires the native engine; int8
    /// is inference-only and rejected at engine construction).
    pub precision: Precision,
    /// Restrict SGD updates to the WASI subspace (`persist:"delta"`
    /// jobs): only factored `.l`/`.r` tensors train, every other tensor
    /// stays bit-identical to the loaded base so the finished job can
    /// be persisted as a variant-store delta record.
    pub subspace_only: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            lr0: 0.05,
            log_every: 20,
            verbose: false,
            engine: EngineKind::Auto,
            precision: Precision::F32,
            subspace_only: false,
        }
    }
}

/// How a training run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunStatus {
    /// All configured steps executed.
    Completed,
    /// The cancellation flag was observed between steps; the engine is
    /// still consistent (a step is never torn).
    Cancelled,
}

/// The one progress-line format, shared by the in-process verbose log
/// and the CLI's event-stream printer so `wasi-train train` output is
/// identical whichever path produced it.
pub fn progress_line(model: &str, backend: &str, r: &StepRecord) -> String {
    format!(
        "[train {model} ({backend})] step {:>4} loss {:.4} acc {:.3} lr {:.4} ({:.0} ms)",
        r.step,
        r.loss,
        r.accuracy,
        r.lr,
        r.seconds * 1000.0
    )
}

/// A live trainer for one model variant.
pub struct Trainer<'rt> {
    pub engine: Box<dyn TrainEngine + 'rt>,
    pub metrics: Metrics,
    schedule: CosineSchedule,
    cfg: TrainConfig,
}

impl<'rt> Trainer<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        entry: &crate::runtime::ModelEntry,
        mut cfg: TrainConfig,
    ) -> Result<Self> {
        let mut engine = train_engine_with(rt, entry, cfg.engine, cfg.precision)?;
        if cfg.subspace_only {
            engine.restrict_to_subspace()?;
        }
        let schedule = CosineSchedule { lr0: cfg.lr0, total: cfg.steps };
        // A zero interval would divide by zero in the logging check.
        cfg.log_every = cfg.log_every.max(1);
        Ok(Trainer { engine, metrics: Metrics::default(), schedule, cfg })
    }

    /// Run the configured number of steps against the loader.
    pub fn run(&mut self, loader: &mut Loader) -> Result<()> {
        let never = AtomicBool::new(false);
        self.run_observed(loader, 0, &mut |_| {}, &never).map(|_| ())
    }

    /// Run steps `start_step..cfg.steps`, reporting each step to
    /// `observe` and polling `cancel` between steps.
    ///
    /// `start_step` is for checkpoint resume: the caller is responsible
    /// for restoring the engine and fast-forwarding the loader to the
    /// same position (see `serve::runner`), after which the trajectory
    /// is bit-identical to an uninterrupted run — the schedule indexes
    /// by absolute step.
    pub fn run_observed(
        &mut self,
        loader: &mut Loader,
        start_step: usize,
        observe: &mut dyn FnMut(&StepRecord),
        cancel: &AtomicBool,
    ) -> Result<RunStatus> {
        let batch = self.engine.entry().batch;
        for s in start_step..self.cfg.steps {
            if cancel.load(Ordering::Relaxed) {
                return Ok(RunStatus::Cancelled);
            }
            let (x, y) = loader.next_batch(batch);
            let lr = self.schedule.lr(s);
            let t0 = Instant::now();
            let out = self.engine.step(&x, &y, lr)?;
            let dt = t0.elapsed().as_secs_f64();
            let record = StepRecord {
                step: s,
                loss: out.loss,
                accuracy: out.accuracy,
                lr,
                seconds: dt,
            };
            self.metrics.push(record);
            if self.cfg.verbose && (s % self.cfg.log_every == 0 || s + 1 == self.cfg.steps) {
                eprintln!(
                    "{}",
                    progress_line(&self.engine.entry().name, self.engine.backend(), &record)
                );
            }
            observe(&record);
        }
        Ok(RunStatus::Completed)
    }

    /// Validation accuracy via the inference engine matching the
    /// backend that actually trained (under `auto` the two could
    /// otherwise resolve differently, and accuracies are not
    /// comparable across engines — DESIGN.md §4).
    pub fn validate(&self, rt: &Runtime, loader: &Loader) -> Result<f64> {
        let infer = infer_engine(rt, self.engine.entry(), self.engine.kind())?;
        let batch = self.engine.entry().batch;
        let n = loader.val_len();
        if n == 0 {
            return Ok(f64::NAN);
        }
        let mut correct = 0usize;
        let mut seen = 0usize;
        let mut start = 0usize;
        while seen < n {
            let (x, labels) = loader.val_batch(start, batch);
            let preds = infer.predict(self.engine.params(), &x)?;
            let take = batch.min(n - seen);
            for i in 0..take {
                if preds[i] == labels[i] {
                    correct += 1;
                }
            }
            seen += take;
            start += batch;
        }
        Ok(correct as f64 / n as f64)
    }
}

//! Training metrics: per-step records + exponential smoothing, JSON dump.

use crate::util::json::{arr, num, obj, Json};

#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub accuracy: f32,
    pub lr: f32,
    pub seconds: f64,
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub records: Vec<StepRecord>,
    ema_loss: Option<f64>,
}

impl Metrics {
    pub fn push(&mut self, r: StepRecord) {
        let alpha = 0.1;
        self.ema_loss = Some(match self.ema_loss {
            None => r.loss as f64,
            Some(e) => e * (1.0 - alpha) + r.loss as f64 * alpha,
        });
        self.records.push(r);
    }

    pub fn smoothed_loss(&self) -> f64 {
        self.ema_loss.unwrap_or(f64::NAN)
    }

    pub fn total_seconds(&self) -> f64 {
        self.records.iter().map(|r| r.seconds).sum()
    }

    pub fn mean_step_seconds(&self) -> f64 {
        if self.records.is_empty() {
            return f64::NAN;
        }
        self.total_seconds() / self.records.len() as f64
    }

    /// Loss curve subsampled to at most `n` points (for logging).
    pub fn loss_curve(&self, n: usize) -> Vec<(usize, f32)> {
        if self.records.is_empty() {
            return Vec::new();
        }
        let stride = (self.records.len() / n.max(1)).max(1);
        self.records
            .iter()
            .step_by(stride)
            .map(|r| (r.step, r.loss))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        arr(self.records.iter().map(|r| {
            obj(vec![
                ("step", num(r.step as f64)),
                ("loss", num(r.loss as f64)),
                ("acc", num(r.accuracy as f64)),
                ("lr", num(r.lr as f64)),
                ("sec", num(r.seconds)),
            ])
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f32) -> StepRecord {
        StepRecord { step, loss, accuracy: 0.5, lr: 0.05, seconds: 0.01 }
    }

    #[test]
    fn ema_tracks_loss() {
        let mut m = Metrics::default();
        for i in 0..100 {
            m.push(rec(i, 1.0));
        }
        assert!((m.smoothed_loss() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn curve_subsamples() {
        let mut m = Metrics::default();
        for i in 0..1000 {
            m.push(rec(i, i as f32));
        }
        let c = m.loss_curve(10);
        assert!(c.len() >= 10 && c.len() <= 11);
        assert_eq!(c[0].0, 0);
    }

    #[test]
    fn json_serializes() {
        let mut m = Metrics::default();
        m.push(rec(0, 2.5));
        let s = m.to_json().to_string();
        assert!(s.contains("\"loss\":2.5"));
    }
}

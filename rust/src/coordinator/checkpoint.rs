//! Checkpointing: save/restore the flat parameter + ASI-state vectors
//! with an integrity header, so an interrupted on-device fine-tune can
//! resume exactly (the paper's target devices lose power routinely).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::engine::TrainEngine;

const MAGIC: u32 = 0x5741_5349; // "WASI"
const VERSION: u32 = 1;

/// Serialized snapshot of a training session.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub step: u64,
    pub params: Vec<f32>,
    pub state: Vec<f32>,
}

impl Checkpoint {
    /// Snapshot a live engine (either backend) at a step.
    pub fn from_engine(engine: &dyn TrainEngine, at_step: u64) -> Checkpoint {
        Checkpoint {
            model: engine.entry().name.clone(),
            step: at_step,
            params: engine.params().to_vec(),
            state: engine.state().to_vec(),
        }
    }

    /// Binary layout: magic, version, step, name_len, name bytes,
    /// params_len, state_len, params f32 LE, state f32 LE, checksum.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        let name = self.model.as_bytes();
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name);
        buf.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        buf.extend_from_slice(&(self.state.len() as u64).to_le_bytes());
        for v in &self.params {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for v in &self.state {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(&checksum(&buf).to_le_bytes());
        std::fs::write(path.as_ref(), buf)
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let buf = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        if buf.len() < 32 {
            return Err(anyhow!("checkpoint truncated"));
        }
        let (body, tail) = buf.split_at(buf.len() - 8);
        let want = u64::from_le_bytes(tail.try_into().unwrap());
        if checksum(body) != want {
            return Err(anyhow!("checkpoint checksum mismatch (corrupt file)"));
        }
        let mut r = Reader { b: body, i: 0 };
        if r.u32()? != MAGIC {
            return Err(anyhow!("not a wasi-train checkpoint"));
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(anyhow!("unsupported checkpoint version {version}"));
        }
        let step = r.u64()?;
        let name_len = r.u32()? as usize;
        let model = String::from_utf8(r.bytes(name_len)?.to_vec())?;
        let p_len = r.u64()? as usize;
        let s_len = r.u64()? as usize;
        let params = r.f32s(p_len)?;
        let state = r.f32s(s_len)?;
        Ok(Checkpoint { model, step, params, state })
    }

    /// Restore into a live engine (must be the same variant).
    pub fn restore_into(&self, engine: &mut dyn TrainEngine) -> Result<()> {
        if engine.entry().name != self.model {
            return Err(anyhow!(
                "checkpoint is for {:?}, engine is {:?}",
                self.model,
                engine.entry().name
            ));
        }
        engine
            .restore(&self.params, &self.state)
            .map_err(|e| anyhow!("checkpoint shape mismatch: {e:#}"))
    }
}

/// FNV-1a 64 over the body.
fn checksum(data: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl Reader<'_> {
    fn bytes(&mut self, n: usize) -> Result<&[u8]> {
        if self.i + n > self.b.len() {
            return Err(anyhow!("checkpoint truncated at byte {}", self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.bytes(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            model: "vit_wasi_eps80".into(),
            step: 1234,
            params: vec![1.0, -2.5, 3.25e-8],
            state: vec![0.5; 7],
        }
    }

    #[test]
    fn roundtrip() {
        let tmp = std::env::temp_dir().join("wasi_ckpt_test.bin");
        let c = sample();
        c.save(&tmp).unwrap();
        let back = Checkpoint::load(&tmp).unwrap();
        assert_eq!(back, c);
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn detects_corruption() {
        let tmp = std::env::temp_dir().join("wasi_ckpt_corrupt.bin");
        sample().save(&tmp).unwrap();
        let mut bytes = std::fs::read(&tmp).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&tmp, bytes).unwrap();
        assert!(Checkpoint::load(&tmp).is_err());
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn rejects_garbage() {
        let tmp = std::env::temp_dir().join("wasi_ckpt_garbage.bin");
        std::fs::write(&tmp, b"definitely not a checkpoint, far too short?x").unwrap();
        assert!(Checkpoint::load(&tmp).is_err());
        let _ = std::fs::remove_file(tmp);
    }
}

//! L3 coordinator: the on-device fine-tuning runtime.
//!
//! For this paper the coordinator's job is the training loop itself —
//! the paper's contribution lives at L2/L1 (the subspace math inside the
//! step), so L3 is the driver the system prompt calls "thin": session
//! lifecycle, cosine LR schedule, batching, validation, checkpointing,
//! live memory accounting, and metrics.  Everything here is pure rust;
//! compute happens inside the AOT-compiled step.

pub mod checkpoint;
pub mod memory;
pub mod metrics;
pub mod schedule;
pub mod session;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use schedule::CosineSchedule;
pub use session::{FinetuneConfig, FinetuneConfigBuilder, FinetuneReport, Session};
pub use trainer::{progress_line, RunStatus, TrainConfig, Trainer};

//! Live memory accounting for a fine-tuning session (the budget the
//! on-device deployment must respect; drives Figs. 5-7 memory axes for
//! the executable models and the `plan-ranks` CLI).

use crate::runtime::ModelEntry;

/// Memory breakdown in ELEMENTS (×4 for bytes).
#[derive(Debug, Clone, Copy, Default)]
pub struct MemoryBreakdown {
    pub weights: usize,
    pub activations: usize,
    pub asi_state: usize,
}

impl MemoryBreakdown {
    pub fn total(&self) -> usize {
        self.weights + self.activations + self.asi_state
    }

    pub fn total_mb(&self) -> f64 {
        self.total() as f64 * 4.0 / (1024.0 * 1024.0)
    }

    /// Total MB with the weight term priced at a storage precision
    /// (`crate::precision`): activations and ASI state stay f32 (the
    /// compute precision), weights shrink to 2 bytes at bf16 / 1 byte
    /// at int8.  `total_mb_at(F32) == total_mb()`.
    pub fn total_mb_at(&self, p: crate::precision::Precision) -> f64 {
        let weight_bytes = self.weights as f64 * p.bytes_per_elem();
        let rest_bytes = (self.activations + self.asi_state) as f64 * 4.0;
        (weight_bytes + rest_bytes) / (1024.0 * 1024.0)
    }
}

/// Account a model variant's training memory from its manifest entry.
///
/// * weights: the flat parameter vector (factored layers are already L/R);
/// * activations: for every factored layer the Eq. 44 compressed form,
///   for a vanilla entry the full B·N·I per layer (Eq. 42);
/// * asi_state: the warm-start bases (counted once; they double as the
///   backward factors).
pub fn account(entry: &ModelEntry) -> MemoryBreakdown {
    let mut b = MemoryBreakdown {
        weights: entry.params_len,
        asi_state: entry.state_len,
        ..Default::default()
    };
    for (name, (_oi, act)) in &entry.layer_dims {
        if let Some(ranks) = entry.asi_ranks.get(name) {
            // Eq. 44: core + factor memory; factors live in asi_state
            // already, so add only the core here to avoid double counting.
            let core: usize = ranks.iter().product();
            b.activations += core;
        } else {
            b.activations += act.iter().product::<usize>();
        }
    }
    b
}

/// Vanilla-model activation memory for the same architecture, for the
/// compression-ratio denominators: full activations at each factored site.
pub fn vanilla_activations(entry: &ModelEntry) -> usize {
    entry
        .layer_dims
        .values()
        .map(|(_oi, act)| act.iter().product::<usize>())
        .sum()
}

/// Elements one variant-store delta record holds for this model: the
/// factored layers' `.l` (O, K) + `.r` (K, I) tensors — all the
/// per-user state a subspace-trained job produces (DESIGN.md §Variant
/// store).  Priced from `param_spec` when present (exact), else from
/// `weight_ranks` × `layer_dims` (the planning path before artifacts
/// exist).
pub fn delta_elems(entry: &ModelEntry) -> usize {
    let from_spec: usize = entry
        .weight_ranks
        .keys()
        .flat_map(|prefix| {
            ["l", "r"].into_iter().filter_map(|suffix| {
                entry.param_tensor(&format!("{prefix}.{suffix}")).map(|t| t.numel())
            })
        })
        .sum();
    if from_spec > 0 {
        return from_spec;
    }
    entry
        .weight_ranks
        .iter()
        .filter_map(|(prefix, &k)| {
            entry.layer_dims.get(prefix).map(|(oi, _act)| {
                let (o, i) = (oi.first().copied().unwrap_or(0), oi.get(1).copied().unwrap_or(0));
                k * (o + i)
            })
        })
        .sum()
}

/// Bytes one resident delta record charges against the store budget
/// (factors are served f32: the overlay path feeds them straight to
/// the f32 kernel walk).
pub fn delta_bytes(entry: &ModelEntry) -> usize {
    delta_elems(entry) * 4
}

/// Personalized users per GB of resident memory when each holds a full
/// parameter copy vs only a delta record — the fleet-scale
/// personalization headline (EXPERIMENTS.md §Perf iteration 11).
/// Returns `(full, delta)` user counts; 0 when the model has no
/// subspace.
pub fn users_per_gb(entry: &ModelEntry) -> (usize, usize) {
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    let full_bytes = entry.params_len as f64 * 4.0;
    let d_bytes = delta_bytes(entry) as f64;
    let full = if full_bytes > 0.0 { (GB / full_bytes) as usize } else { 0 };
    let delta = if d_bytes > 0.0 { (GB / d_bytes) as usize } else { 0 };
    (full, delta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn entry() -> ModelEntry {
        let mut layer_dims = BTreeMap::new();
        layer_dims.insert(
            "l1".to_string(),
            (vec![256usize, 128], vec![16usize, 65, 128]),
        );
        let mut asi_ranks = BTreeMap::new();
        asi_ranks.insert("l1".to_string(), vec![4usize, 12, 20]);
        ModelEntry {
            name: "t".into(),
            train_hlo: None,
            infer_hlo: PathBuf::new(),
            params_file: PathBuf::new(),
            state_file: None,
            params_len: 1000,
            state_len: 16 * 4 + 65 * 12 + 128 * 20,
            batch: 16,
            input_dim: 3072,
            classes: 10,
            eps: Some(0.8),
            weight_ranks: BTreeMap::new(),
            asi_ranks,
            layer_dims,
            param_spec: Vec::new(),
            state_spec: Vec::new(),
        }
    }

    #[test]
    fn wasi_total_below_vanilla() {
        let e = entry();
        let b = account(&e);
        // compressed total (core + factors-in-state) < full activation
        assert!(b.activations + b.asi_state < vanilla_activations(&e));
        assert_eq!(b.weights, 1000);
        assert_eq!(b.activations, 4 * 12 * 20);
    }

    #[test]
    fn vanilla_entry_counts_full_activations() {
        let mut e = entry();
        e.asi_ranks.clear();
        e.state_len = 0;
        let b = account(&e);
        assert_eq!(b.activations, 16 * 65 * 128);
    }

    #[test]
    fn delta_pricing_prefers_spec_and_falls_back_to_ranks() {
        use crate::runtime::TensorSpec;
        let mut e = entry();
        // Planning path: no param_spec — price k·(o+i) from the ranks.
        e.weight_ranks.insert("l1".to_string(), 6);
        assert_eq!(delta_elems(&e), 6 * (256 + 128));
        assert_eq!(delta_bytes(&e), 6 * (256 + 128) * 4);
        // Artifact path: the spec's exact factor shapes win.
        e.param_spec = vec![
            TensorSpec { name: "l1.l".into(), shape: vec![256, 5], offset: 0 },
            TensorSpec { name: "l1.r".into(), shape: vec![5, 128], offset: 256 * 5 },
        ];
        assert_eq!(delta_elems(&e), 5 * (256 + 128));
        let (full, delta) = users_per_gb(&e);
        assert!(delta > full, "delta records must fit more users per GB");
        // No subspace — no delta users.
        e.weight_ranks.clear();
        e.param_spec.clear();
        assert_eq!(delta_elems(&e), 0);
        assert_eq!(users_per_gb(&e).1, 0);
    }

    #[test]
    fn precision_prices_only_the_weight_term() {
        use crate::precision::Precision;
        let b = account(&entry());
        assert!((b.total_mb_at(Precision::F32) - b.total_mb()).abs() < 1e-12);
        let f32_mb = b.total_mb_at(Precision::F32);
        let bf16_mb = b.total_mb_at(Precision::Bf16);
        let i8_mb = b.total_mb_at(Precision::I8);
        assert!(bf16_mb < f32_mb && i8_mb < bf16_mb);
        let rest = (b.activations + b.asi_state) as f64 * 4.0 / (1024.0 * 1024.0);
        let want_i8 = rest + b.weights as f64 / (1024.0 * 1024.0);
        assert!((i8_mb - want_i8).abs() < 1e-12);
    }
}

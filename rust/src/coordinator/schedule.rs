//! Learning-rate schedules.  The paper trains with SGD, initial LR 0.05,
//! cosine annealing over the full run (App. B.1).

/// Cosine annealing from `lr0` to ~0 over `total` steps.
#[derive(Debug, Clone, Copy)]
pub struct CosineSchedule {
    pub lr0: f32,
    pub total: usize,
}

impl CosineSchedule {
    pub fn paper_default(total: usize) -> Self {
        CosineSchedule { lr0: 0.05, total: total.max(1) }
    }

    pub fn lr(&self, step: usize) -> f32 {
        let t = (step.min(self.total)) as f32 / self.total as f32;
        self.lr0 * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        let s = CosineSchedule::paper_default(100);
        assert!((s.lr(0) - 0.05).abs() < 1e-7);
        assert!(s.lr(100) < 1e-6);
        assert!((s.lr(50) - 0.025).abs() < 1e-6);
    }

    #[test]
    fn monotone_decreasing() {
        let s = CosineSchedule::paper_default(37);
        let mut prev = f32::INFINITY;
        for step in 0..=37 {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }

    #[test]
    fn clamps_past_total() {
        let s = CosineSchedule::paper_default(10);
        assert_eq!(s.lr(10), s.lr(999));
    }
}

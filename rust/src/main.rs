//! wasi-train CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   train       fine-tune a model variant on a synthetic dataset preset
//!   infer       run inference with a variant's initial params
//!   plan-ranks  run the Eq. 30/32 rank-selection DP over the manifest's
//!               perplexity table
//!   eval        regenerate a paper exhibit (fig2..fig12, tab1..tab4, all)
//!   cost-model  print the Fig. 2 analytic sweep
//!   calibrate   measure this host's GFLOP/s + bandwidth
//!   list        list artifact model variants
//!   demo        generate a tiny pure-rust artifact set (no Python/PJRT)

use anyhow::{anyhow, Result};

use wasi_train::coordinator::{FinetuneConfig, Session};
use wasi_train::engine::{self, EngineKind};
use wasi_train::eval::{self, EvalCtx};
use wasi_train::util::cli::Args;
use wasi_train::util::table::Table;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    [
        "usage: wasi-train <train|infer|plan-ranks|eval|bench|cost-model|calibrate|list|demo> [options]",
        "common options:",
        "  --artifacts DIR   artifact directory (default: artifacts)",
        "  --engine KIND     execution engine: auto|hlo|native (default: auto;",
        "                    auto prefers HLO when the runtime can execute model",
        "                    HLO and falls back to the native engine otherwise)",
        "  --threads N       kernel-layer worker threads (default: auto = all",
        "                    cores; results are bit-identical across counts)",
        "train:      --model NAME --dataset PRESET --steps N --samples N --seed S",
        "            --lr LR0 (cosine schedule start, default 0.05)",
        "            --save-curve FILE (write the loss curve as JSON)",
        "            --silent (suppress per-step progress lines)",
        "infer:      --model NAME --seed S (batch accuracy with initial params;",
        "            works on infer-only variants, no train artifact needed)",
        "plan-ranks: --budget-kb N | --eps E",
        "eval:       <exhibit|all> --steps N --out DIR [--quick]",
        "bench:      [--quick] [--steps N] [--out FILE (default BENCH_native.json)]",
        "            times demo->train->infer on both engines, sweeps 1 vs N",
        "            threads, and writes the perf record JSON",
        "demo:       --out DIR (default: demo_artifacts) -- tiny ViT manifest +",
        "            params generated in pure rust, so train/infer run offline:",
        "            wasi-train demo --out D && wasi-train train --artifacts D \
--engine native --model vit_demo_wasi_eps80",
        "",
    ]
    .join("\n")
}

fn engine_kind(args: &Args) -> Result<EngineKind> {
    args.get_or("engine", "auto").parse()
}

fn run() -> Result<()> {
    let args = Args::parse();
    // `--threads N|auto` applies process-wide before any kernel runs.
    if let Some(v) = args.get("threads") {
        let n = if v == "auto" {
            0
        } else {
            v.parse::<usize>()
                .map_err(|e| anyhow!("--threads expects an integer or 'auto', got {v:?}: {e}"))?
        };
        wasi_train::util::threadpool::set_num_threads(n);
    }
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args, &artifacts),
        Some("infer") => cmd_infer(&args, &artifacts),
        Some("bench") => cmd_bench(&args),
        Some("demo") => cmd_demo(&args),
        Some("plan-ranks") => cmd_plan_ranks(&args, &artifacts),
        Some("eval") => cmd_eval(&args, &artifacts),
        Some("cost-model") => {
            let pts = wasi_train::costmodel::curves::fig2_sweep(
                128, 197, &[256, 512, 1024, 2048], &[16, 64, 256]);
            let mut t = Table::new(["dim", "rank", "C_tr", "S_tr", "C_inf", "S_inf"]);
            for p in pts {
                t.row([
                    p.dim.to_string(), p.rank.to_string(),
                    format!("{:.2}", p.c_training), format!("{:.2}", p.s_training),
                    format!("{:.2}", p.c_inference), format!("{:.2}", p.s_inference),
                ]);
            }
            t.print();
            Ok(())
        }
        Some("calibrate") => {
            let prof = wasi_train::device::calibrate::host_profile();
            println!(
                "host: {:.1} GFLOP/s sustained matmul, {:.1} GB/s stream bandwidth",
                prof.gflops, prof.mem_gbps
            );
            Ok(())
        }
        Some("list") => {
            let session = Session::open(&artifacts)?;
            let mut t = Table::new(["model", "eps", "params", "state", "batch", "trainable"]);
            for m in session.manifest.models.values() {
                t.row([
                    m.name.clone(),
                    m.eps.map(|e| e.to_string()).unwrap_or_else(|| "-".into()),
                    m.params_len.to_string(),
                    m.state_len.to_string(),
                    m.batch.to_string(),
                    if m.train_hlo.is_some() { "yes" } else { "infer-only" }.into(),
                ]);
            }
            t.print();
            Ok(())
        }
        _ => {
            print!("{}", usage());
            Ok(())
        }
    }
}

fn cmd_train(args: &Args, artifacts: &str) -> Result<()> {
    // Validate flag values before touching the manifest so a typo'd
    // --engine fails with its own message.
    let engine = engine_kind(args)?;
    let session = Session::open(artifacts)?;
    let cfg = FinetuneConfig {
        model: args.get_or("model", "vit_wasi_eps80").to_string(),
        dataset: args.get_or("dataset", "cifar10-like").to_string(),
        samples: args.usize_or("samples", 512)?,
        steps: args.usize_or("steps", 200)?,
        seed: args.usize_or("seed", 233)? as u64,
        verbose: !args.flag("silent"),
        lr0: args.f64_or("lr", 0.05)? as f32,
        log_every: None,
        engine,
        // `--threads` is already applied process-wide in `run`.
        threads: None,
    };
    let report = session.finetune(&cfg)?;
    println!(
        "\nmodel {}  dataset {}  engine {}",
        report.model, report.dataset, report.engine
    );
    println!("val accuracy     {:.3}", report.val_accuracy);
    println!("final loss (ema) {:.4}", report.final_loss);
    println!("mean step        {:.1} ms", report.mean_step_seconds * 1e3);
    println!("train memory     {:.2} MB", report.memory.total_mb());
    if let Some(out) = args.get("save-curve") {
        let json = wasi_train::util::json::arr(report.loss_curve.iter().map(|(s, l)| {
            wasi_train::util::json::obj(vec![
                ("step", wasi_train::util::json::num(*s as f64)),
                ("loss", wasi_train::util::json::num(*l as f64)),
            ])
        }));
        std::fs::write(out, json.to_string())?;
        println!("loss curve -> {out}");
    }
    Ok(())
}

fn cmd_infer(args: &Args, artifacts: &str) -> Result<()> {
    let session = Session::open(artifacts)?;
    let name = args.get_or("model", "vit_wasi_eps80");
    let entry = session.manifest.model(name)?;
    // Initial params come straight off the manifest entry — inference
    // must never require a train artifact (infer-only variants).
    let params = entry.load_params()?;
    let infer = engine::infer_engine(&session.runtime, entry, engine_kind(args)?)?;
    let side = entry.image_side().ok_or_else(|| {
        anyhow!("model {name} is not an image model (input_dim {})", entry.input_dim)
    })?;
    let mut task = wasi_train::data::synth::VisionTask::new(
        "infer", entry.classes, side, 0.7, 8, args.usize_or("seed", 233)? as u64);
    let (x, _, labels) = task.batch_onehot(entry.batch);
    let preds = infer.predict(&params, &x)?;
    let correct = preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
    println!(
        "batch accuracy (pre-finetune, {} engine): {}/{}",
        infer.backend(),
        correct,
        entry.batch
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let cfg = wasi_train::eval::perf::BenchConfig {
        quick,
        steps: args.usize_or("steps", if quick { 10 } else { 50 })?,
        out: std::path::PathBuf::from(args.get_or("out", "BENCH_native.json")),
    };
    let summary = wasi_train::eval::perf::run_bench(&cfg)?;
    println!("{summary}");
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    let out = args.get_or("out", "demo_artifacts");
    let cfg = wasi_train::engine::demo::DemoConfig::default();
    let names = wasi_train::engine::demo::write_demo_artifacts(out, &cfg)?;
    println!("demo artifacts -> {out}/manifest.json");
    for n in &names {
        println!("  model {n}");
    }
    println!(
        "try: wasi-train train --artifacts {out} --engine native --model {} --steps 50",
        names.last().unwrap()
    );
    Ok(())
}

fn cmd_plan_ranks(args: &Args, artifacts: &str) -> Result<()> {
    let session = Session::open(artifacts)?;
    let table = session
        .manifest
        .perplexity
        .as_ref()
        .ok_or_else(|| anyhow!("manifest has no perplexity table"))?;
    if let Some(eps) = args.get("eps") {
        let eps: f64 = eps.parse()?;
        let plan = wasi_train::wasi::rank_select::plan_ranks_wasi(table, eps)?;
        print_plan(table, &plan);
    } else {
        let kb = args.usize_or("budget-kb", 64)?;
        let budget = kb * 1024 / 4;
        let plan = wasi_train::wasi::rank_select::plan_ranks(table, budget, 4096)?;
        println!("budget: {kb} KB ({budget} f32 elems)");
        print_plan(table, &plan);
    }
    Ok(())
}

fn print_plan(table: &wasi_train::wasi::rank_select::PerplexityTable,
              plan: &wasi_train::wasi::rank_select::RankPlan) {
    let mut t = Table::new(["layer", "eps", "ranks", "mem elems", "perplexity"]);
    for (l, &j) in plan.choice.iter().enumerate() {
        t.row([
            table.layers[l].clone(),
            format!("{}", table.eps_grid[j]),
            format!("{:?}", table.ranks[l][j]),
            table.memory[l][j].to_string(),
            format!("{:.4}", table.perplexity[l][j]),
        ]);
    }
    t.print();
    println!(
        "total: {} elems ({:.1} KB), perplexity {:.4}",
        plan.total_memory,
        plan.total_memory as f64 * 4.0 / 1024.0,
        plan.total_perplexity
    );
}

fn cmd_eval(args: &Args, artifacts: &str) -> Result<()> {
    let exhibit = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let quick = args.flag("quick");
    let steps = args.usize_or("steps", if quick { 60 } else { 150 })?;
    let out_dir = args.get_or("out", "eval_out");
    let ctx = EvalCtx::open(artifacts, out_dir, steps, quick)?.with_engine(engine_kind(args)?);
    let body = if exhibit == "all" {
        eval::run_all(&ctx)?
    } else {
        eval::run(&ctx, exhibit)?
    };
    println!("{body}");
    Ok(())
}

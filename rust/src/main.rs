//! wasi-train CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   train       fine-tune a model variant (one job through the serve core)
//!   serve       multi-session job service speaking JSON-lines on stdin/stdout
//!   soak        bounded adversarial workload soak over the serve core
//!   store       inspect a variant-store directory (ls | gc | show KEY)
//!   infer       run inference with a variant's initial params
//!   plan-ranks  run the Eq. 30/32 rank-selection DP over the manifest's
//!               perplexity table
//!   eval        regenerate a paper exhibit (fig2..fig12, tab1..tab4, all)
//!   cost-model  print the Fig. 2 analytic sweep
//!   calibrate   measure this host's GFLOP/s + bandwidth
//!   list        list artifact model variants
//!   demo        generate a tiny pure-rust artifact set (no Python/PJRT)
//!
//! Every subcommand rejects options outside its accepted set (a typo'd
//! `--step 50` errors instead of silently training the default steps).

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use wasi_train::coordinator::{progress_line, FinetuneConfig, Session};
use wasi_train::engine::EngineKind;
use wasi_train::eval::{self, EvalCtx};
use wasi_train::precision::Precision;
use wasi_train::serve::{
    serve_lines, InferRequest, JobEvent, JobSpec, JobState, Service, ServiceConfig,
};
use wasi_train::util::cli::Args;
use wasi_train::util::table::Table;

/// Count heap allocations process-wide so `wasi-train bench` can pin
/// the arena pass's allocations-per-step number (`util::alloc`).  The
/// counter is one relaxed atomic add per alloc — noise-level cost.
#[global_allocator]
static ALLOC: wasi_train::util::alloc::CountingAllocator =
    wasi_train::util::alloc::CountingAllocator;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    [
        "usage: wasi-train <train|serve|soak|store|infer|plan|plan-ranks|eval|bench|cost-model|calibrate|list|demo> [options]",
        "common options:",
        "  --artifacts DIR   artifact directory (default: artifacts)",
        "  --engine KIND     execution engine: auto|hlo|native (default: auto;",
        "                    auto prefers HLO when the runtime can execute model",
        "                    HLO and falls back to the native engine otherwise)",
        "  --threads N       kernel-layer worker threads (default: auto = all",
        "                    cores; results are bit-identical across counts)",
        "  --precision P     weight storage: f32|bf16|i8 (default f32; bf16",
        "                    trains + serves at 2 bytes/weight, i8 is",
        "                    inference-only per-tensor symmetric quantization)",
        "  --passes LIST     optimization passes: all|none|comma-list of",
        "                    fold,fuse,arena,prepack (default all; every pass is",
        "                    bit-identical to the unoptimized walk, so this is a",
        "                    perf/debug knob, never a results knob; env",
        "                    WASI_PASSES is the fallback when the flag is absent)",
        "unknown --options are rejected per subcommand; the accepted sets are:",
        "train:      --model NAME --dataset PRESET --steps N --samples N --seed S",
        "            --lr LR0 (cosine schedule start, default 0.05)",
        "            --save-curve FILE (write the loss curve as JSON)",
        "            --save-checkpoint FILE (save final params+state)",
        "            --resume FILE (continue from a checkpoint, bit-identical)",
        "            --silent (suppress per-step progress lines)",
        "            runs as one job through the same service core as `serve`",
        "serve:      --workers N (default 2) -- long-lived JSON-lines service:",
        "            {\"cmd\":\"submit\"|\"status\"|\"events\"|\"infer\"|\"cancel\"|\"forget\"",
        "             |\"store\"|\"store-stats\"|\"stats\"|\"shutdown\"} per line on stdin;",
        "            training jobs queue onto worker threads, infer requests answer",
        "            inline (DESIGN.md \u{a7}serve)",
        "            --store DIR attaches a variant store: submit accepts",
        "            \"persist\":\"delta\" and finished jobs keep only their subspace",
        "            factors (DESIGN.md \u{a7}Variant store)",
        "            --memory-budget-mb N caps the resident delta set (0 = unbounded)",
        "            --listen ADDR serves the same protocol over TCP instead of",
        "            stdio (length-prefix framed, many concurrent connections;",
        "            DESIGN.md \u{a7}Network front-end), with admission control",
        "            (--max-inflight N, default 64; --queue-cap N, default 256 --",
        "            overload answers {\"ok\":false,\"code\":\"overloaded\"} in-band) and",
        "            cross-request infer micro-batching (--batch-window-us U,",
        "            default 200; --max-batch N, default 8; bit-identical to solo",
        "            serving); --stdio forces the stdio session explicitly",
        "soak:       [--quick] --events N --seconds S --seed S --workers N",
        "            --faults LIST (cancel-storm,worker-death,evict,malformed,evict-budget|all|none)",
        "            --trace FILE (replay a recorded trace) --record FILE (save it)",
        "            --variants A,B --out FILE (default SOAK_report.json) [--pace]",
        "            --store DIR --memory-budget-mb N (variant store for delta jobs;",
        "            auto-provisioned under a tight budget when --faults includes",
        "            evict-budget)",
        "            --listen routes infer traffic over a real loopback socket",
        "            front-end (implied by --faults conn-churn/all, which add",
        "            abrupt-disconnect, half-close, and slow-reader connection",
        "            faults)",
        "            drives the serve core with a seeded adversarial workload,",
        "            checks the serving invariants, exits non-zero on violations",
        "store:      <ls|gc|show KEY> --store DIR (default: store) -- offline",
        "            variant-store inspection: ls lists delta records, gc drops",
        "            undecodable ones, show prints a record's factor metadata",
        "infer:      --model NAME --seed S (batch accuracy with initial params;",
        "            works on infer-only variants, no train artifact needed)",
        "plan:       [--model NAME] -- dump the pass pipeline's optimized node",
        "            program per variant: liveness intervals, arena offsets,",
        "            arena size vs sum-of-buffers, prepacked panel footprint",
        "plan-ranks: --budget-kb N | --eps E",
        "eval:       <exhibit|all> --steps N --out DIR [--quick]",
        "bench:      [--quick] [--steps N] [--out FILE (default BENCH_native.json)]",
        "            times demo->train->infer on both engines, sweeps 1 vs N",
        "            threads, benches the serve scheduler (jobs/sec, p50/p95",
        "            submit->done at 1 vs N workers), and writes the perf JSON",
        "demo:       --out DIR (default: demo_artifacts) -- tiny ViT manifest +",
        "            params generated in pure rust, so train/infer run offline:",
        "            wasi-train demo --out D && wasi-train train --artifacts D \
--engine native --model vit_demo_wasi_eps80",
        "",
    ]
    .join("\n")
}

fn engine_kind(args: &Args) -> Result<EngineKind> {
    args.get_or("engine", "auto").parse()
}

fn precision_of(args: &Args) -> Result<Precision> {
    args.get_or("precision", "f32").parse()
}

/// Per-subcommand accepted option/flag sets (satellite: unknown
/// `--options` are rejected instead of silently ignored).  The usage
/// screen's "common options" (`--artifacts`, `--engine`, `--threads`,
/// `--precision`) are accepted by every subcommand — `--threads`
/// applies process-wide before dispatch, the others simply don't bind
/// where a subcommand has no use for them — so help text and rejection
/// never contradict.
fn check_known_options(sub: &str, args: &Args) -> Result<()> {
    let (specific, flags): (&[&str], &[&str]) = match sub {
        "train" => (
            &[
                "model", "dataset", "steps", "samples", "seed", "lr", "save-curve",
                "save-checkpoint", "resume",
            ],
            &["silent"],
        ),
        "serve" => (
            &[
                "workers", "store", "memory-budget-mb", "listen", "max-inflight", "queue-cap",
                "batch-window-us", "max-batch",
            ],
            &["stdio"],
        ),
        "soak" => (
            &[
                "workers", "events", "seconds", "seed", "trace", "record", "out", "faults",
                "variants", "store", "memory-budget-mb",
            ],
            &["quick", "pace", "listen"],
        ),
        "store" => (&["store"], &[]),
        "infer" => (&["model", "seed"], &[]),
        "plan" => (&["model"], &[]),
        "bench" => (&["steps", "out"], &["quick"]),
        "demo" => (&["out"], &[]),
        "plan-ranks" => (&["budget-kb", "eps"], &[]),
        "eval" => (&["steps", "out"], &["quick"]),
        "cost-model" | "calibrate" | "list" => (&[], &[]),
        // Unknown subcommands fall through to the usage screen.
        _ => return Ok(()),
    };
    let mut options: Vec<&str> = vec!["artifacts", "engine", "threads", "precision", "passes"];
    options.extend_from_slice(specific);
    args.reject_unknown(sub, &options, flags)
}

fn run() -> Result<()> {
    let args = Args::parse();
    if let Some(sub) = args.subcommand.as_deref() {
        check_known_options(sub, &args)?;
    }
    // `--threads N|auto` applies process-wide before any kernel runs.
    if let Some(v) = args.get("threads") {
        let n = if v == "auto" {
            0
        } else {
            v.parse::<usize>()
                .map_err(|e| anyhow!("--threads expects an integer or 'auto', got {v:?}: {e}"))?
        };
        wasi_train::util::threadpool::set_num_threads(n);
    }
    // `--passes LIST` applies process-wide before any executor is
    // planned (falls back to env WASI_PASSES, then all-on).
    if let Some(v) = args.get("passes") {
        wasi_train::engine::passes::set_passes(wasi_train::engine::passes::PassSet::parse(v)?);
    }
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args, &artifacts),
        Some("serve") => cmd_serve(&args, &artifacts),
        Some("soak") => cmd_soak(&args, &artifacts),
        Some("store") => cmd_store(&args),
        Some("infer") => cmd_infer(&args, &artifacts),
        Some("plan") => cmd_plan(&args, &artifacts),
        Some("bench") => cmd_bench(&args),
        Some("demo") => cmd_demo(&args),
        Some("plan-ranks") => cmd_plan_ranks(&args, &artifacts),
        Some("eval") => cmd_eval(&args, &artifacts),
        Some("cost-model") => {
            let pts = wasi_train::costmodel::curves::fig2_sweep(
                128,
                197,
                &[256, 512, 1024, 2048],
                &[16, 64, 256],
            );
            let mut t = Table::new(["dim", "rank", "C_tr", "S_tr", "C_inf", "S_inf"]);
            for p in pts {
                t.row([
                    p.dim.to_string(),
                    p.rank.to_string(),
                    format!("{:.2}", p.c_training),
                    format!("{:.2}", p.s_training),
                    format!("{:.2}", p.c_inference),
                    format!("{:.2}", p.s_inference),
                ]);
            }
            t.print();
            Ok(())
        }
        Some("calibrate") => {
            let prof = wasi_train::device::calibrate::host_profile();
            println!(
                "host: {:.1} GFLOP/s sustained matmul, {:.1} GB/s stream bandwidth",
                prof.gflops,
                prof.mem_gbps
            );
            Ok(())
        }
        Some("list") => {
            let session = Session::open(&artifacts)?;
            let mut t = Table::new(["model", "eps", "params", "state", "batch", "trainable"]);
            for m in session.manifest().models.values() {
                t.row([
                    m.name.clone(),
                    m.eps.map(|e| e.to_string()).unwrap_or_else(|| "-".into()),
                    m.params_len.to_string(),
                    m.state_len.to_string(),
                    m.batch.to_string(),
                    if m.train_hlo.is_some() { "yes" } else { "infer-only" }.into(),
                ]);
            }
            t.print();
            Ok(())
        }
        _ => {
            print!("{}", usage());
            Ok(())
        }
    }
}

/// `train`: submit one job to an in-process service and stream its
/// events — the exact code path `wasi-train serve` workers execute.
fn cmd_train(args: &Args, artifacts: &str) -> Result<()> {
    // Validate flag values before touching the manifest so a typo'd
    // --engine fails with its own message.
    let engine = engine_kind(args)?;
    let precision = precision_of(args)?;
    let cfg = FinetuneConfig::builder()
        .model(args.get_or("model", "vit_wasi_eps80"))
        .dataset(args.get_or("dataset", "cifar10-like"))
        .samples(args.usize_or("samples", 512)?)
        .steps(args.usize_or("steps", 200)?)
        .seed(args.usize_or("seed", 233)? as u64)
        .lr0(args.f64_or("lr", 0.05)? as f32)
        .engine(engine)
        .precision(precision)
        // Progress is printed from the event stream below; --threads is
        // already applied process-wide in `run`.
        .build();
    let verbose = !args.flag("silent");

    let service = Service::start(ServiceConfig::new(PathBuf::from(artifacts)).with_workers(1))?;
    let mut spec = JobSpec::new(cfg.clone());
    spec.resume_from = args.get("resume").map(PathBuf::from);
    spec.checkpoint_to = args.get("save-checkpoint").map(PathBuf::from);
    let job = service.submit(spec)?;
    let events = service
        .take_events(job)
        .expect("a freshly submitted job exposes its event stream");
    let log_every = (cfg.steps / 10).max(1);
    let mut backend = "?";
    for ev in events {
        match ev {
            JobEvent::Started { backend: b, .. } => backend = b,
            JobEvent::Step { record, .. }
                if verbose
                    && (record.step % log_every == 0 || record.step + 1 == cfg.steps) =>
            {
                eprintln!("{}", progress_line(&cfg.model, backend, &record));
            }
            _ => {}
        }
    }
    let report = match service.status(job) {
        Some(JobState::Done(report)) => report,
        Some(JobState::Failed(e)) => return Err(anyhow!(e)),
        other => return Err(anyhow!("job ended without a terminal state: {other:?}")),
    };
    service.shutdown();

    println!(
        "\nmodel {}  dataset {}  engine {}  precision {}",
        report.model,
        report.dataset,
        report.engine,
        report.precision
    );
    println!("val accuracy     {:.3}", report.val_accuracy);
    println!("final loss (ema) {:.4}", report.final_loss);
    println!("mean step        {:.1} ms", report.mean_step_seconds * 1e3);
    println!("train memory     {:.2} MB", report.memory.total_mb_at(report.precision));
    if let Some(out) = args.get("save-checkpoint") {
        println!("checkpoint -> {out}");
    }
    if let Some(out) = args.get("save-curve") {
        let json = wasi_train::util::json::arr(report.loss_curve.iter().map(|(s, l)| {
            wasi_train::util::json::obj(vec![
                ("step", wasi_train::util::json::num(*s as f64)),
                ("loss", wasi_train::util::json::num(*l as f64)),
            ])
        }));
        std::fs::write(out, json.to_string())?;
        println!("loss curve -> {out}");
    }
    Ok(())
}

/// `serve`: the long-lived multi-session front-end — JSON-lines
/// requests on stdin, responses on stdout, log chatter on stderr; or,
/// with `--listen ADDR`, the same protocol length-prefix framed over
/// TCP with admission control and infer micro-batching
/// (DESIGN.md §Network front-end).
fn cmd_serve(args: &Args, artifacts: &str) -> Result<()> {
    let listen = args.get("listen").map(str::to_string);
    if args.flag("stdio") && listen.is_some() {
        return Err(anyhow!("--stdio and --listen ADDR are mutually exclusive"));
    }
    if listen.is_none() {
        for opt in ["max-inflight", "queue-cap", "batch-window-us", "max-batch"] {
            if args.get(opt).is_some() {
                return Err(anyhow!("--{opt} requires --listen ADDR"));
            }
        }
    }
    let workers = args.usize_or("workers", 2)?;
    let mut cfg = ServiceConfig::new(PathBuf::from(artifacts)).with_workers(workers);
    if let Some(dir) = args.get("store") {
        let mb = args.usize_or("memory-budget-mb", 0)?;
        cfg = cfg.with_store(PathBuf::from(dir), mb << 20);
    } else if args.get("memory-budget-mb").is_some() {
        return Err(anyhow!("--memory-budget-mb requires --store DIR"));
    }
    let store_note = cfg
        .store
        .as_ref()
        .map(|d| format!(", variant store {}", d.display()))
        .unwrap_or_default();
    if let Some(addr) = listen {
        let net_cfg = wasi_train::net::NetConfig {
            listen: addr,
            max_inflight: args.usize_or("max-inflight", 64)?,
            queue_cap: args.usize_or("queue-cap", 256)?,
            batch_window_us: args.usize_or("batch-window-us", 200)? as u64,
            max_batch: args.usize_or("max-batch", 8)?,
            dispatchers: 0,
        };
        let service = std::sync::Arc::new(Service::start(cfg)?);
        let mut handle = wasi_train::net::serve_listener(service.clone(), net_cfg)?;
        // The "listening on ADDR" phrase is parsed by socket clients
        // (scripts/socket_smoke.py) to discover a `:0` ephemeral port.
        eprintln!(
            "wasi-train serve: {} worker(s) over {artifacts}/{store_note} — listening on {} \
             (length-prefix framed JSON; send {{\"cmd\":\"shutdown\"}} to stop)",
            workers.max(1),
            handle.addr()
        );
        handle.wait_stop();
        // Stop the service first so any still-streaming `events` jobs
        // terminate, then drain and join the front-end.
        service.shutdown();
        handle.shutdown();
        return Ok(());
    }
    let service = Service::start(cfg)?;
    eprintln!(
        "wasi-train serve: {} worker(s) over {artifacts}/{store_note} — JSON-lines on stdin \
         (submit|status|events|infer|cancel|forget|store|store-stats|stats|shutdown)",
        workers.max(1)
    );
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    serve_lines(&service, stdin.lock(), stdout.lock())?;
    service.shutdown();
    Ok(())
}

/// `soak`: drive the serve core with a seeded adversarial workload and
/// hold it to the serving invariants (DESIGN.md §Scenario harness).
/// Exits non-zero when any invariant is violated so CI can gate on it.
fn cmd_soak(args: &Args, artifacts: &str) -> Result<()> {
    use wasi_train::scenario::{run_soak_to, FaultPlan, SoakConfig};
    let quick = args.flag("quick");
    let mut cfg = SoakConfig::quick(artifacts);
    cfg.workers = args.usize_or("workers", 2)?;
    cfg.events = args.usize_or("events", if quick { 120 } else { 600 })?;
    cfg.max_seconds = args.f64_or("seconds", if quick { 60.0 } else { 300.0 })?;
    cfg.seed = args.usize_or("seed", 233)? as u64;
    cfg.faults = FaultPlan::parse(args.get_or("faults", "none"))?;
    cfg.trace_in = args.get("trace").map(PathBuf::from);
    cfg.trace_out = args.get("record").map(PathBuf::from);
    cfg.pace = args.flag("pace");
    cfg.store = args.get("store").map(PathBuf::from);
    cfg.memory_budget_mb = args.usize_or("memory-budget-mb", 0)?;
    cfg.listen = args.flag("listen");
    if let Some(v) = args.get("variants") {
        cfg.variants = v.split(',').map(|s| s.trim().to_string()).collect();
    }
    let out = PathBuf::from(args.get_or("out", "SOAK_report.json"));

    let report = run_soak_to(&cfg, Some(&out))?;

    println!(
        "soak: {} of {} events in {:.1}s  (seed {}, faults {}, {} workers{})",
        report.events_replayed,
        report.events_total,
        report.soak_seconds,
        report.seed,
        report.faults,
        report.workers,
        if report.truncated { ", TRUNCATED by wallclock cap" } else { "" },
    );
    println!(
        "ops : {} submit  {} infer  {} cancel  {} forget  {} evict  {} frame",
        report.ops.submits,
        report.ops.infers,
        report.ops.cancels,
        report.ops.forgets,
        report.ops.evicts,
        report.ops.frames
    );
    println!(
        "jobs: {} done  {} cancelled  {} panicked  {} shutdown  {} unexpected",
        report.jobs.done,
        report.jobs.cancelled,
        report.jobs.panicked,
        report.jobs.shutdown,
        report.jobs.unexpected
    );
    println!(
        "pool: {} loads  {} evictions  {} resident  |  queue depth max {}",
        report.pool_loads,
        report.pool_evictions,
        report.pool_occupancy.len(),
        report.queue_depth_max()
    );
    if let Some(s) = &report.store {
        println!(
            "store: {} puts  {} hits  {} misses  {} reloads  {} evictions  \
             {} bit-identity verified",
            s.puts,
            s.hits,
            s.misses,
            s.reloads,
            s.evictions,
            report.store_verified
        );
    }
    if report.submit_to_done.count() > 0 {
        println!(
            "submit→done  p50 {:.0} ms  p95 {:.0} ms  p99 {:.0} ms  ({} jobs)",
            report.submit_to_done.p(50.0),
            report.submit_to_done.p(95.0),
            report.submit_to_done.p(99.0),
            report.submit_to_done.count()
        );
    }
    if report.infer_roundtrip.count() > 0 {
        println!(
            "infer trip   p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms  ({} calls)",
            report.infer_roundtrip.p(50.0),
            report.infer_roundtrip.p(95.0),
            report.infer_roundtrip.p(99.0),
            report.infer_roundtrip.count()
        );
    }
    println!("report -> {}", out.display());

    if report.violations.is_empty() {
        println!("invariants: OK (0 violations)");
        Ok(())
    } else {
        for v in &report.violations {
            eprintln!("VIOLATION: {v}");
        }
        Err(anyhow!(
            "soak finished with {} invariant violation(s)",
            report.violations.len()
        ))
    }
}

/// `store`: offline inspection of a variant-store directory — the same
/// records `serve --store DIR` pages, without starting a service.
fn cmd_store(args: &Args) -> Result<()> {
    use wasi_train::store::VariantStore;
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("ls");
    let dir = PathBuf::from(args.get_or("store", "store"));
    // Budget 0 = unbounded: inspection never needs to page anything out.
    let store = VariantStore::open(&dir, 0)?;
    match action {
        "ls" => {
            let records = store.list()?;
            let mut t = Table::new(["key", "bytes"]);
            let mut total = 0u64;
            for (key, bytes) in &records {
                total += bytes;
                t.row([key.clone(), bytes.to_string()]);
            }
            t.print();
            println!("{} record(s), {} bytes in {}", records.len(), total, dir.display());
        }
        "gc" => {
            let dropped = store.gc()?;
            for key in &dropped {
                println!("dropped {key}");
            }
            println!("gc: {} undecodable record(s) dropped", dropped.len());
        }
        "show" => {
            let key = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("store show needs a KEY (see `wasi-train store ls`)"))?;
            let rec = store.get(key)?;
            println!("key             {key}");
            println!("model           {}", rec.model);
            println!("train precision {}", rec.train_precision);
            println!("base hash       {:016x}", rec.base_hash);
            println!("delta payload   {} elems ({} bytes)", rec.elems(), rec.bytes());
            let mut t = Table::new(["tensor", "shape", "offset"]);
            for ten in &rec.tensors {
                t.row([ten.name.clone(), format!("{:?}", ten.shape), ten.offset.to_string()]);
            }
            t.print();
        }
        other => {
            return Err(anyhow!("unknown store action {other:?}; expected ls | gc | show KEY"))
        }
    }
    Ok(())
}

fn cmd_infer(args: &Args, artifacts: &str) -> Result<()> {
    let engine = engine_kind(args)?;
    let session = Session::open(artifacts)?;
    let req = InferRequest {
        model: args.get_or("model", "vit_wasi_eps80").to_string(),
        engine,
        precision: precision_of(args)?,
        seed: args.usize_or("seed", 233)? as u64,
        x: None,
    };
    // Initial params come straight off the pool cache — inference must
    // never require a train artifact (infer-only variants).  Same
    // `run_infer` path the serve protocol's `infer` command uses.
    let out = wasi_train::serve::runner::run_infer(session.pool_entry(), &req, None)?;
    println!(
        "batch accuracy (pre-finetune, {} engine, {} weights): {}/{}",
        out.backend,
        out.precision,
        out.correct.unwrap_or(0),
        out.batch
    );
    Ok(())
}

/// `plan`: make the pass pipeline inspectable without a debugger —
/// dump the optimized node program, the liveness intervals with their
/// arena offsets, the arena size vs the no-reuse footprint, and the
/// prepacked panel summary, per variant.
fn cmd_plan(args: &Args, artifacts: &str) -> Result<()> {
    use wasi_train::costmodel::memory::{arena_reuse_ratio, elems_to_mb};
    use wasi_train::engine::{GraphExecutor, LayerGraph, PackedParams, ProgramReport};

    let session = Session::open(artifacts)?;
    let filter = args.get("model");
    let mut shown = 0usize;
    for entry in session.manifest().models.values() {
        if let Some(name) = filter {
            if entry.name != *name {
                continue;
            }
        }
        shown += 1;
        let graph = match LayerGraph::from_entry(entry) {
            Ok(g) => g,
            Err(e) => {
                println!("model {}: not plannable by the native IR ({e:#})\n", entry.name);
                continue;
            }
        };
        // Train executor when the variant supports it, else infer-only
        // (the plan differs: training pins saved activations across the
        // loss boundary, inference re-plans per batch element).
        let exec = match GraphExecutor::new(graph, entry) {
            Ok(x) => x,
            Err(_) => GraphExecutor::new_infer(LayerGraph::from_entry(entry)?, entry)?,
        };
        let rep = exec.plan_report();
        println!("model {}  (passes: {})", entry.name, rep.passes);
        let mut nodes = Table::new(["#", "node", "out features"]);
        for (i, nt) in exec.node_timings().iter().enumerate() {
            nodes.row([i.to_string(), nt.label.clone(), nt.out_features.to_string()]);
        }
        nodes.print();
        let sections: [(&str, Option<&ProgramReport>); 2] = [
            ("train (fwd+bwd round trip)", rep.train.as_ref()),
            ("infer (per batch element)", rep.infer.as_ref()),
        ];
        for (tag, pr) in sections {
            match pr {
                Some(p) => {
                    println!(
                        "{tag}: arena {} elems ({:.2} MB) for {} buffers; \
                         sum-of-buffers {} elems ({:.2} MB); reuse {:.2}x",
                        p.arena_elems,
                        elems_to_mb(p.arena_elems as f64),
                        p.buffers,
                        p.sum_elems,
                        elems_to_mb(p.sum_elems as f64),
                        arena_reuse_ratio(p.sum_elems, p.arena_elems),
                    );
                    let mut t = Table::new(["buf", "def", "last use", "elems", "offset"]);
                    for (i, (def, last, elems, off)) in p.intervals.iter().enumerate() {
                        t.row([
                            i.to_string(),
                            def.to_string(),
                            last.to_string(),
                            elems.to_string(),
                            off.to_string(),
                        ]);
                    }
                    t.print();
                }
                None => println!("{tag}: arena pass disabled — unplanned per-Vec walk"),
            }
        }
        let params = entry.load_params()?;
        for prec in [Precision::Bf16, Precision::I8] {
            match PackedParams::pack(entry, &params, prec) {
                Ok(p) => println!(
                    "prepack @ {prec}: {} panels, {} panel bytes{}",
                    p.panel_count(),
                    p.panel_bytes(),
                    if p.has_folded_assemble() { ", assemble folded" } else { "" },
                ),
                Err(e) => println!("prepack @ {prec}: unavailable ({e:#})"),
            }
        }
        println!();
    }
    if shown == 0 {
        return Err(anyhow!(
            "no variant matched {:?}; see `wasi-train list`",
            filter.unwrap_or("<all>")
        ));
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let cfg = wasi_train::eval::perf::BenchConfig {
        quick,
        steps: args.usize_or("steps", if quick { 10 } else { 50 })?,
        out: std::path::PathBuf::from(args.get_or("out", "BENCH_native.json")),
    };
    let summary = wasi_train::eval::perf::run_bench(&cfg)?;
    println!("{summary}");
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    let out = args.get_or("out", "demo_artifacts");
    let cfg = wasi_train::engine::demo::DemoConfig::default();
    let names = wasi_train::engine::demo::write_demo_artifacts(out, &cfg)?;
    println!("demo artifacts -> {out}/manifest.json");
    for n in &names {
        println!("  model {n}");
    }
    println!(
        "try: wasi-train train --artifacts {out} --engine native --model {} --steps 50",
        names.last().unwrap()
    );
    Ok(())
}

fn cmd_plan_ranks(args: &Args, artifacts: &str) -> Result<()> {
    let session = Session::open(artifacts)?;
    let table = session
        .manifest()
        .perplexity
        .as_ref()
        .ok_or_else(|| anyhow!("manifest has no perplexity table"))?;
    if let Some(eps) = args.get("eps") {
        let eps: f64 = eps.parse()?;
        let plan = wasi_train::wasi::rank_select::plan_ranks_wasi(table, eps)?;
        print_plan(table, &plan);
    } else {
        let kb = args.usize_or("budget-kb", 64)?;
        let budget = kb * 1024 / 4;
        let plan = wasi_train::wasi::rank_select::plan_ranks(table, budget, 4096)?;
        println!("budget: {kb} KB ({budget} f32 elems)");
        print_plan(table, &plan);
    }
    Ok(())
}

fn print_plan(
    table: &wasi_train::wasi::rank_select::PerplexityTable,
    plan: &wasi_train::wasi::rank_select::RankPlan,
) {
    let mut t = Table::new(["layer", "eps", "ranks", "mem elems", "perplexity"]);
    for (l, &j) in plan.choice.iter().enumerate() {
        t.row([
            table.layers[l].clone(),
            format!("{}", table.eps_grid[j]),
            format!("{:?}", table.ranks[l][j]),
            table.memory[l][j].to_string(),
            format!("{:.4}", table.perplexity[l][j]),
        ]);
    }
    t.print();
    println!(
        "total: {} elems ({:.1} KB), perplexity {:.4}",
        plan.total_memory,
        plan.total_memory as f64 * 4.0 / 1024.0,
        plan.total_perplexity
    );
}

fn cmd_eval(args: &Args, artifacts: &str) -> Result<()> {
    let exhibit = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let quick = args.flag("quick");
    let steps = args.usize_or("steps", if quick { 60 } else { 150 })?;
    let out_dir = args.get_or("out", "eval_out");
    let ctx = EvalCtx::open(artifacts, out_dir, steps, quick)?.with_engine(engine_kind(args)?);
    let body = if exhibit == "all" {
        eval::run_all(&ctx)?
    } else {
        eval::run(&ctx, exhibit)?
    };
    println!("{body}");
    Ok(())
}

//! One-sided Jacobi SVD (no LAPACK).
//!
//! Rotates column pairs of A until all pairs are orthogonal; the column
//! norms are then the singular values, the normalized columns are U, and
//! V accumulates the rotations.  Plenty fast at the sizes this project
//! decomposes (weight matrices up to ~512x512, unfoldings up to ~1k) and
//! accurate to f32 roundoff.  Tall matrices are pre-reduced by QR.

use super::matrix::Mat;
use super::qr::householder_qr;

/// Thin SVD result: a = u * diag(s) * vt, singular values descending.
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Mat,  // (m, k)
    pub s: Vec<f32>,
    pub vt: Mat, // (k, n)
}

/// Compute the thin SVD of `a` (m x n), k = min(m, n).
pub fn svd(a: &Mat) -> Svd {
    if a.rows >= 2 * a.cols {
        // Tall: QR first, SVD of small R, then U = Q U_r.
        let (q, r) = householder_qr(a);
        let inner = jacobi_svd(&r);
        return Svd {
            u: q.matmul(&inner.u),
            s: inner.s,
            vt: inner.vt,
        };
    }
    if a.cols > 2 * a.rows {
        // Wide: SVD of the transpose, swap factors.
        let t = svd(&a.transpose());
        return Svd {
            u: t.vt.transpose(),
            s: t.s,
            vt: t.u.transpose(),
        };
    }
    jacobi_svd(a)
}

fn jacobi_svd(a: &Mat) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let k = m.min(n);
    // Work on columns of a copy; accumulate V.
    let mut w = a.clone();
    let mut v = Mat::eye(n);
    let eps = 1e-10f64;

    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Stride-aware column views: the O(n² · sweeps) pair loop
                // used to allocate two fresh Vecs per pair (`Mat::col`).
                let apq = w.col_view(p).dot(w.col_view(q)) as f64;
                let app = w.col_view(p).sq_norm() as f64;
                let aqq = w.col_view(q).sq_norm() as f64;
                if apq.abs() <= eps * (app * aqq).sqrt() || app + aqq < 1e-30 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) inner product.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (c, s) = (c as f32, s as f32);
                for i in 0..m {
                    let wp = w.at(i, p);
                    let wq = w.at(i, q);
                    *w.at_mut(i, p) = c * wp - s * wq;
                    *w.at_mut(i, q) = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    *v.at_mut(i, p) = c * vp - s * vq;
                    *v.at_mut(i, q) = s * vp + c * vq;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }

    // Singular values = column norms; sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f32> = (0..n).map(|j| w.col_view(j).sq_norm().sqrt()).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = Mat::zeros(m, k);
    let mut s = vec![0.0f32; k];
    let mut vt = Mat::zeros(k, n);
    for (out_j, &j) in order.iter().take(k).enumerate() {
        s[out_j] = norms[j];
        let cj = w.col_view(j);
        if norms[j] > 1e-12 {
            for i in 0..m {
                u.data[i * k + out_j] = cj.get(i) / norms[j];
            }
        } else {
            u.data[(out_j % m) * k + out_j] = 1.0;
        }
        for i in 0..n {
            vt.data[out_j * n + i] = v.at(i, j);
        }
    }
    Svd { u, s, vt }
}

impl Svd {
    /// Reconstruct the (possibly truncated) matrix u[:, :k] s[:k] vt[:k, :].
    pub fn reconstruct(&self, k: usize) -> Mat {
        let k = k.min(self.s.len());
        let m = self.u.rows;
        let n = self.vt.cols;
        let mut out = Mat::zeros(m, n);
        for j in 0..k {
            let sj = self.s[j];
            for i in 0..m {
                let uij = self.u.at(i, j) * sj;
                if uij == 0.0 {
                    continue;
                }
                let vrow = &self.vt.data[j * n..(j + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (o, &vv) in orow.iter_mut().zip(vrow) {
                    *o += uij * vv;
                }
            }
        }
        out
    }

    /// Smallest K with cumulative explained variance >= eps (paper §3.3).
    pub fn rank_for_energy(&self, eps: f64) -> usize {
        let total: f64 = self.s.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if total <= 0.0 {
            return 1;
        }
        let mut cum = 0.0;
        for (j, &sj) in self.s.iter().enumerate() {
            cum += (sj as f64) * (sj as f64);
            if cum / total >= eps {
                return j + 1;
            }
        }
        self.s.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    fn reconstruct_err(a: &Mat) -> f32 {
        let d = svd(a);
        let r = d.reconstruct(d.s.len());
        r.sub(a).frob_norm() / a.frob_norm().max(1e-9)
    }

    #[test]
    fn reconstructs_square() {
        let mut rng = Pcg64::new(1);
        let a = Mat::random(16, 16, &mut rng);
        assert!(reconstruct_err(&a) < 1e-4);
    }

    #[test]
    fn reconstructs_tall_and_wide() {
        let mut rng = Pcg64::new(2);
        assert!(reconstruct_err(&Mat::random(64, 12, &mut rng)) < 1e-4);
        assert!(reconstruct_err(&Mat::random(9, 40, &mut rng)) < 1e-4);
    }

    #[test]
    fn singular_values_descending_and_orthonormal() {
        let mut rng = Pcg64::new(3);
        let a = Mat::random(20, 14, &mut rng);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
        let g = d.u.matmul_tn(&d.u);
        for i in 0..g.rows {
            for j in 0..g.cols {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g.at(i, j) - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn exact_on_known_matrix() {
        // diag(3, 2) embedded in 2x2.
        let a = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, 2.0]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-5);
        assert!((d.s[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn low_rank_matrix_detected() {
        // rank-2 matrix: only 2 nonzero singular values.
        let mut rng = Pcg64::new(4);
        let u = Mat::random(20, 2, &mut rng);
        let v = Mat::random(2, 15, &mut rng);
        let a = u.matmul(&v);
        let d = svd(&a);
        assert!(d.s[2] < 1e-3 * d.s[0]);
        assert_eq!(d.rank_for_energy(0.999), 2);
    }

    #[test]
    fn rank_for_energy_monotone() {
        let mut rng = Pcg64::new(5);
        let a = Mat::random(30, 30, &mut rng);
        let d = svd(&a);
        let mut prev = 0;
        for eps in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let k = d.rank_for_energy(eps);
            assert!(k >= prev);
            prev = k;
        }
    }
}

//! Dense linear algebra substrate (pure rust, f32).
//!
//! Implements everything the paper's method and baselines need — the
//! shared multithreaded GEMM kernel layer (`kernels`, the one
//! optimization site every matmul routes through), Gram-Schmidt /
//! Householder QR, one-sided Jacobi SVD, Cholesky (for SVD-LLM's
//! whitening), warm-started subspace iteration, and Tucker/HOSVD tensor
//! ops — with no external BLAS/LAPACK.

pub mod cholesky;
pub mod kernels;
pub mod matrix;
pub mod qr;
pub mod simd;
pub mod subspace;
pub mod svd;
pub mod tucker;

pub use cholesky::cholesky;
pub use matrix::Mat;
pub use qr::{gram_schmidt, householder_qr};
pub use subspace::{subspace_iterate, SubspaceState};
pub use svd::{svd, Svd};
pub use tucker::{hosvd, mode_product, unfold, Tensor};

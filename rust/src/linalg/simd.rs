//! Portable 8-lane f32 SIMD primitives for the kernel layer
//! (DESIGN.md §Kernels).
//!
//! Three ISA backends sit behind one generic microkernel body:
//!
//! * **avx** (`x86_64`, runtime-detected via `is_x86_feature_detected!`),
//! * **neon** (`aarch64`, baseline feature — always available),
//! * **scalar** (`[f32; 8]` lanes, any target; also what
//!   [`set_force_scalar`] pins for the scalar-vs-SIMD parity tests and
//!   the `wasi-train bench` scalar arm).
//!
//! **Determinism contract:** every backend performs the *same* sequence
//! of IEEE-754 single operations per output element — multiply then add
//! (never FMA), lanes mapped to ascending element indices, horizontal
//! sums reduced lane 0 → 7 — so scalar and SIMD results are
//! **bit-identical**, and the kernel layer's bit-identical-across-
//! thread-counts pin extends unchanged to the vectorized path.  SIMD
//! here buys load/store and issue width, not reassociation.
//!
//! The primitives operate on the kernel layer's packed panels
//! (`linalg::kernels`): `update4_panel` is the 4-row register-blocked
//! microkernel over an interleaved packed A tile, `update1_panel` the
//! single-row remainder form, `dot` the 8-accumulator dot product.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Force the scalar backend regardless of what the host supports
/// (parity tests, the bench's scalar arm).  Process-global, like the
/// kernel layer's thread override.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Serializes tests that toggle the process-global [`FORCE_SCALAR`]
/// flag (results are backend-independent by construction, but a parity
/// test must control which backend it is timing/comparing).
#[cfg(test)]
pub(crate) static SIMD_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Pin the scalar backend on (`true`) or restore runtime dispatch
/// (`false`).  Results are bit-identical either way; this knob exists
/// so parity tests and `wasi-train bench` can measure the difference.
pub fn set_force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// Whether the scalar backend is currently forced.
pub fn force_scalar() -> bool {
    FORCE_SCALAR.load(Ordering::Relaxed)
}

/// The instruction set the dispatcher currently selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

#[cfg(target_arch = "x86_64")]
fn detect() -> Isa {
    if is_x86_feature_detected!("avx") {
        Isa::Avx
    } else {
        Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> Isa {
    // NEON is part of the aarch64 baseline.
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> Isa {
    Isa::Scalar
}

fn detected_isa() -> Isa {
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(detect)
}

/// The backend the next kernel call will use (detection result unless
/// the scalar backend is forced).
pub fn active_isa() -> Isa {
    if force_scalar() {
        Isa::Scalar
    } else {
        detected_isa()
    }
}

/// Short name of [`active_isa`] for logs and the bench record.
pub fn isa_name() -> &'static str {
    match active_isa() {
        Isa::Scalar => "scalar",
        #[cfg(target_arch = "x86_64")]
        Isa::Avx => "avx",
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => "neon",
    }
}

// ---------------------------------------------------------------------------
// The generic 8-lane vocabulary
// ---------------------------------------------------------------------------

/// Eight f32 lanes.  Implementations must keep lane `l` bound to
/// element index `base + l` through load/op/store so every backend
/// computes the identical IEEE operation sequence (see module docs).
trait F32x8: Copy {
    type V: Copy;
    /// # Safety
    /// `p..p+8` must be readable.
    unsafe fn load(p: *const f32) -> Self::V;
    /// # Safety
    /// `p..p+8` must be writable.
    unsafe fn store(p: *mut f32, v: Self::V);
    unsafe fn splat(v: f32) -> Self::V;
    /// Lane-wise `a * b` (a plain multiply — never fused with the
    /// following add, to preserve scalar bit-identity).
    unsafe fn mul(a: Self::V, b: Self::V) -> Self::V;
    unsafe fn add(a: Self::V, b: Self::V) -> Self::V;
}

#[derive(Clone, Copy)]
struct ScalarIsa;

impl F32x8 for ScalarIsa {
    type V = [f32; 8];

    #[inline(always)]
    unsafe fn load(p: *const f32) -> [f32; 8] {
        let mut v = [0.0f32; 8];
        for (l, slot) in v.iter_mut().enumerate() {
            *slot = *p.add(l);
        }
        v
    }

    #[inline(always)]
    unsafe fn store(p: *mut f32, v: [f32; 8]) {
        for (l, x) in v.iter().enumerate() {
            *p.add(l) = *x;
        }
    }

    #[inline(always)]
    unsafe fn splat(v: f32) -> [f32; 8] {
        [v; 8]
    }

    #[inline(always)]
    unsafe fn mul(a: [f32; 8], b: [f32; 8]) -> [f32; 8] {
        let mut o = [0.0f32; 8];
        for l in 0..8 {
            o[l] = a[l] * b[l];
        }
        o
    }

    #[inline(always)]
    unsafe fn add(a: [f32; 8], b: [f32; 8]) -> [f32; 8] {
        let mut o = [0.0f32; 8];
        for l in 0..8 {
            o[l] = a[l] + b[l];
        }
        o
    }
}

#[cfg(target_arch = "x86_64")]
mod avx {
    use std::arch::x86_64::{
        __m256, _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };

    use super::F32x8;

    #[derive(Clone, Copy)]
    pub(super) struct AvxIsa;

    impl F32x8 for AvxIsa {
        type V = __m256;

        #[inline(always)]
        unsafe fn load(p: *const f32) -> __m256 {
            _mm256_loadu_ps(p)
        }

        #[inline(always)]
        unsafe fn store(p: *mut f32, v: __m256) {
            _mm256_storeu_ps(p, v)
        }

        #[inline(always)]
        unsafe fn splat(v: f32) -> __m256 {
            _mm256_set1_ps(v)
        }

        #[inline(always)]
        unsafe fn mul(a: __m256, b: __m256) -> __m256 {
            _mm256_mul_ps(a, b)
        }

        #[inline(always)]
        unsafe fn add(a: __m256, b: __m256) -> __m256 {
            _mm256_add_ps(a, b)
        }
    }

    #[target_feature(enable = "avx")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        super::dot_impl::<AvxIsa>(a, b)
    }

    #[target_feature(enable = "avx")]
    pub(super) unsafe fn update1_panel(apanel: &[f32], bpanel: &[f32], n: usize, out: &mut [f32]) {
        super::update1_panel_impl::<AvxIsa>(apanel, bpanel, n, out)
    }

    #[target_feature(enable = "avx")]
    pub(super) unsafe fn update4_panel(
        apack: &[f32],
        bpanel: &[f32],
        n: usize,
        outs: [&mut [f32]; 4],
    ) {
        super::update4_panel_impl::<AvxIsa>(apack, bpanel, n, outs)
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::{
        float32x4_t, vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32,
    };

    use super::F32x8;

    #[derive(Clone, Copy)]
    pub(super) struct NeonIsa;

    /// Two q-registers = 8 lanes; `.0` holds elements `base..base+4`,
    /// `.1` holds `base+4..base+8`, matching the scalar lane order.
    #[derive(Clone, Copy)]
    pub(super) struct V8(float32x4_t, float32x4_t);

    impl F32x8 for NeonIsa {
        type V = V8;

        #[inline(always)]
        unsafe fn load(p: *const f32) -> V8 {
            V8(vld1q_f32(p), vld1q_f32(p.add(4)))
        }

        #[inline(always)]
        unsafe fn store(p: *mut f32, v: V8) {
            vst1q_f32(p, v.0);
            vst1q_f32(p.add(4), v.1);
        }

        #[inline(always)]
        unsafe fn splat(v: f32) -> V8 {
            V8(vdupq_n_f32(v), vdupq_n_f32(v))
        }

        #[inline(always)]
        unsafe fn mul(a: V8, b: V8) -> V8 {
            V8(vmulq_f32(a.0, b.0), vmulq_f32(a.1, b.1))
        }

        #[inline(always)]
        unsafe fn add(a: V8, b: V8) -> V8 {
            V8(vaddq_f32(a.0, b.0), vaddq_f32(a.1, b.1))
        }
    }

    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        super::dot_impl::<NeonIsa>(a, b)
    }

    pub(super) unsafe fn update1_panel(apanel: &[f32], bpanel: &[f32], n: usize, out: &mut [f32]) {
        super::update1_panel_impl::<NeonIsa>(apanel, bpanel, n, out)
    }

    pub(super) unsafe fn update4_panel(
        apack: &[f32],
        bpanel: &[f32],
        n: usize,
        outs: [&mut [f32]; 4],
    ) {
        super::update4_panel_impl::<NeonIsa>(apack, bpanel, n, outs)
    }
}

// ---------------------------------------------------------------------------
// Generic microkernel bodies (monomorphized per backend)
// ---------------------------------------------------------------------------

/// 8-accumulator dot product: lane `l` accumulates elements `8c + l`,
/// lanes reduce in ascending order, the tail is scalar — the exact
/// operation sequence of the historical scalar `dot`, so every backend
/// is bit-identical.
#[inline(always)]
unsafe fn dot_impl<S: F32x8>(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut lanes = [0.0f32; 8];
    if chunks > 0 {
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = S::splat(0.0);
        for c in 0..chunks {
            let va = S::load(pa.add(c * 8));
            let vb = S::load(pb.add(c * 8));
            acc = S::add(acc, S::mul(va, vb));
        }
        S::store(lanes.as_mut_ptr(), acc);
    }
    let mut s = 0.0f32;
    for lane in lanes {
        s += lane;
    }
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// One packed-panel row update: `out[j] += apanel[kk] * bpanel[kk*n+j]`
/// for every `kk`, ascending, with the kernel layer's exact-zero skip.
/// `apanel` is the row's contiguous A panel (length = panel depth),
/// `bpanel` the matching contiguous B panel rows.
#[inline(always)]
unsafe fn update1_panel_impl<S: F32x8>(apanel: &[f32], bpanel: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(bpanel.len(), apanel.len() * n);
    debug_assert_eq!(out.len(), n);
    let chunks = n / 8;
    let po = out.as_mut_ptr();
    for (kk, &a) in apanel.iter().enumerate() {
        if a == 0.0 {
            continue;
        }
        let b = &bpanel[kk * n..(kk + 1) * n];
        let pb = b.as_ptr();
        let va = S::splat(a);
        for c in 0..chunks {
            let off = c * 8;
            let vb = S::load(pb.add(off));
            let vo = S::add(S::load(po.add(off)), S::mul(va, vb));
            S::store(po.add(off), vo);
        }
        for j in chunks * 8..n {
            *po.add(j) += a * b[j];
        }
    }
}

/// The 4-row register-blocked microkernel: `apack` is the interleaved
/// packed A tile (`apack[kk*4 + r]` = row `r`'s coefficient at panel
/// depth `kk`), `bpanel` the contiguous B panel, `outs` the four output
/// rows.  Four independent accumulator chains per B load.
#[inline(always)]
unsafe fn update4_panel_impl<S: F32x8>(
    apack: &[f32],
    bpanel: &[f32],
    n: usize,
    mut outs: [&mut [f32]; 4],
) {
    let kc = apack.len() / 4;
    debug_assert_eq!(bpanel.len(), kc * n);
    let chunks = n / 8;
    let p0 = outs[0].as_mut_ptr();
    let p1 = outs[1].as_mut_ptr();
    let p2 = outs[2].as_mut_ptr();
    let p3 = outs[3].as_mut_ptr();
    for kk in 0..kc {
        let a0 = apack[kk * 4];
        let a1 = apack[kk * 4 + 1];
        let a2 = apack[kk * 4 + 2];
        let a3 = apack[kk * 4 + 3];
        if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
            continue;
        }
        let b = &bpanel[kk * n..(kk + 1) * n];
        let pb = b.as_ptr();
        let (va0, va1, va2, va3) = (S::splat(a0), S::splat(a1), S::splat(a2), S::splat(a3));
        for c in 0..chunks {
            let off = c * 8;
            let vb = S::load(pb.add(off));
            S::store(p0.add(off), S::add(S::load(p0.add(off)), S::mul(va0, vb)));
            S::store(p1.add(off), S::add(S::load(p1.add(off)), S::mul(va1, vb)));
            S::store(p2.add(off), S::add(S::load(p2.add(off)), S::mul(va2, vb)));
            S::store(p3.add(off), S::add(S::load(p3.add(off)), S::mul(va3, vb)));
        }
        for j in chunks * 8..n {
            let bv = b[j];
            *p0.add(j) += a0 * bv;
            *p1.add(j) += a1 * bv;
            *p2.add(j) += a2 * bv;
            *p3.add(j) += a3 * bv;
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatched entry points
// ---------------------------------------------------------------------------

/// Unrolled 8-lane dot product, dispatched to the active backend.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    match active_isa() {
        Isa::Scalar => unsafe { dot_impl::<ScalarIsa>(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx => unsafe { avx::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot(a, b) },
    }
}

/// Single-row packed-panel update, dispatched to the active backend:
/// `out[j] += apanel[kk] * bpanel[kk*n + j]` for every `kk` ascending,
/// with the kernel layer's exact-zero skip.
#[inline]
pub fn update1_panel(apanel: &[f32], bpanel: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(bpanel.len(), apanel.len() * n);
    assert_eq!(out.len(), n);
    match active_isa() {
        Isa::Scalar => unsafe { update1_panel_impl::<ScalarIsa>(apanel, bpanel, n, out) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx => unsafe { avx::update1_panel(apanel, bpanel, n, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::update1_panel(apanel, bpanel, n, out) },
    }
}

/// 4-row register-blocked microkernel over an interleaved packed A
/// tile (`apack[kk*4 + r]`), dispatched to the active backend.
#[inline]
pub fn update4_panel(apack: &[f32], bpanel: &[f32], n: usize, outs: [&mut [f32]; 4]) {
    assert_eq!(apack.len() % 4, 0);
    assert_eq!(bpanel.len(), (apack.len() / 4) * n);
    match active_isa() {
        Isa::Scalar => unsafe { update4_panel_impl::<ScalarIsa>(apack, bpanel, n, outs) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx => unsafe { avx::update4_panel(apack, bpanel, n, outs) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::update4_panel(apack, bpanel, n, outs) },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    fn scalar_dot(a: &[f32], b: &[f32]) -> f32 {
        unsafe { dot_impl::<ScalarIsa>(a, b) }
    }

    #[test]
    fn dispatched_dot_is_bitwise_scalar() {
        let _guard = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(11);
        for len in [0usize, 1, 7, 8, 9, 16, 63, 64, 65, 1000] {
            let a: Vec<f32> = rng.normal_vec(len);
            let b: Vec<f32> = rng.normal_vec(len);
            let want = scalar_dot(&a, &b);
            set_force_scalar(false);
            let got = dot(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn panel_updates_match_scalar_bitwise() {
        let _guard = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(12);
        for (kc, n) in [(1usize, 1usize), (3, 7), (5, 8), (4, 33), (16, 70)] {
            let mut apanel: Vec<f32> = rng.normal_vec(kc);
            apanel[kc / 2] = 0.0; // exercise the exact-zero skip
            let bpanel: Vec<f32> = rng.normal_vec(kc * n);
            let mut want: Vec<f32> = rng.normal_vec(n);
            let mut got = want.clone();
            unsafe { update1_panel_impl::<ScalarIsa>(&apanel, &bpanel, n, &mut want) };
            set_force_scalar(false);
            update1_panel(&apanel, &bpanel, n, &mut got);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "update1 kc={kc} n={n}"
            );

            let mut apack: Vec<f32> = rng.normal_vec(kc * 4);
            apack[0] = 0.0;
            let mut want4: Vec<f32> = rng.normal_vec(4 * n);
            let mut got4 = want4.clone();
            {
                let (w0, rest) = want4.split_at_mut(n);
                let (w1, rest) = rest.split_at_mut(n);
                let (w2, w3) = rest.split_at_mut(n);
                unsafe { update4_panel_impl::<ScalarIsa>(&apack, &bpanel, n, [w0, w1, w2, w3]) };
            }
            {
                let (g0, rest) = got4.split_at_mut(n);
                let (g1, rest) = rest.split_at_mut(n);
                let (g2, g3) = rest.split_at_mut(n);
                update4_panel(&apack, &bpanel, n, [g0, g1, g2, g3]);
            }
            assert_eq!(
                got4.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want4.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "update4 kc={kc} n={n}"
            );
        }
    }

    #[test]
    fn force_scalar_pins_the_scalar_backend() {
        let _guard = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_force_scalar(true);
        assert_eq!(active_isa(), Isa::Scalar);
        assert_eq!(isa_name(), "scalar");
        set_force_scalar(false);
        // Detection is cached; whatever it picked, the name matches.
        let name = isa_name();
        assert!(["scalar", "avx", "neon"].contains(&name), "{name}");
    }
}

//! Portable 8-lane f32 SIMD primitives for the kernel layer
//! (DESIGN.md §Kernels).
//!
//! Three ISA backends sit behind one generic microkernel body:
//!
//! * **avx** (`x86_64`, runtime-detected via `is_x86_feature_detected!`),
//! * **neon** (`aarch64`, baseline feature — always available),
//! * **scalar** (`[f32; 8]` lanes, any target; also what
//!   [`set_force_scalar`] pins for the scalar-vs-SIMD parity tests and
//!   the `wasi-train bench` scalar arm).
//!
//! **Determinism contract:** every backend performs the *same* sequence
//! of IEEE-754 single operations per output element — multiply then add
//! (never FMA), lanes mapped to ascending element indices, horizontal
//! sums reduced lane 0 → 7 — so scalar and SIMD results are
//! **bit-identical**, and the kernel layer's bit-identical-across-
//! thread-counts pin extends unchanged to the vectorized path.  SIMD
//! here buys load/store and issue width, not reassociation.
//!
//! The primitives operate on the kernel layer's packed panels
//! (`linalg::kernels`): `update4_panel` is the 4-row register-blocked
//! microkernel over an interleaved packed A tile, `update1_panel` the
//! single-row remainder form, `dot` the 8-accumulator dot product and
//! `dot4` its 4-row batched form (each row bit-identical to `dot`).
//!
//! **Integer (int8) primitives** live alongside the f32 vocabulary:
//! [`dot_i8`] / [`dot4_i8`] are i8×i8→i32 dot products with exact i32
//! accumulation — the true-integer inference path (`gemm_nt_i8`).
//! Integer addition is associative, so these are bit-identical across
//! scalar/AVX2/NEON *by construction*, whatever the lane order; the
//! determinism contract needs no op-sequence discipline here, only the
//! caller's `k` bound that rules out i32 overflow
//! (`kernels::I8_DOT_MAX_K`).  Backends:
//!
//! * **avx2** (`x86_64`, runtime-detected): sign-extend i8→i16
//!   (`cvtepi8_epi16`) then `madd_epi16` pairwise into i32 — the
//!   `maddubs`-family integer path *without* its i16 saturation hazard
//!   (pair sums of ±127 products exceed i16 when one operand is u8).
//! * **neon** (`aarch64` baseline): `sdot`-style widening
//!   multiply-accumulate — `vmull_s8` to i16×8, `vpadalq_s16` pairwise
//!   into i32×4.  The literal `vdotq_s32` intrinsic needs the optional
//!   `dotprod` target feature and is not stable on the crate's MSRV
//!   (1.74); the widening-MAC form is baseline NEON and produces the
//!   same exact integers.
//! * **scalar** — the plain i32 loop, also what [`set_force_scalar`]
//!   pins (shared flag with the f32 backends).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Force the scalar backend regardless of what the host supports
/// (parity tests, the bench's scalar arm).  Process-global, like the
/// kernel layer's thread override.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Serializes tests that toggle the process-global [`FORCE_SCALAR`]
/// flag (results are backend-independent by construction, but a parity
/// test must control which backend it is timing/comparing).
#[cfg(test)]
pub(crate) static SIMD_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Pin the scalar backend on (`true`) or restore runtime dispatch
/// (`false`).  Results are bit-identical either way; this knob exists
/// so parity tests and `wasi-train bench` can measure the difference.
pub fn set_force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// Whether the scalar backend is currently forced.
pub fn force_scalar() -> bool {
    FORCE_SCALAR.load(Ordering::Relaxed)
}

/// The instruction set the dispatcher currently selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

#[cfg(target_arch = "x86_64")]
fn detect() -> Isa {
    if is_x86_feature_detected!("avx") {
        Isa::Avx
    } else {
        Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> Isa {
    // NEON is part of the aarch64 baseline.
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> Isa {
    Isa::Scalar
}

fn detected_isa() -> Isa {
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(detect)
}

/// The backend the next kernel call will use (detection result unless
/// the scalar backend is forced).
pub fn active_isa() -> Isa {
    if force_scalar() {
        Isa::Scalar
    } else {
        detected_isa()
    }
}

/// Short name of [`active_isa`] for logs and the bench record.
pub fn isa_name() -> &'static str {
    match active_isa() {
        Isa::Scalar => "scalar",
        #[cfg(target_arch = "x86_64")]
        Isa::Avx => "avx",
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => "neon",
    }
}

/// The instruction set the *integer* dispatcher currently selects.
///
/// Separate from [`Isa`] because the integer path needs AVX2
/// (256-bit integer ops), a strictly stronger feature than the AVX
/// the f32 path detects; NEON integer MAC is aarch64 baseline like
/// the f32 lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Int8Isa {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

#[cfg(target_arch = "x86_64")]
fn detect_int8() -> Int8Isa {
    if is_x86_feature_detected!("avx2") {
        Int8Isa::Avx2
    } else {
        Int8Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_int8() -> Int8Isa {
    // Widening i8 multiply-accumulate is part of the aarch64 baseline.
    Int8Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_int8() -> Int8Isa {
    Int8Isa::Scalar
}

fn detected_int8_isa() -> Int8Isa {
    static DETECTED: OnceLock<Int8Isa> = OnceLock::new();
    *DETECTED.get_or_init(detect_int8)
}

/// The backend the next *integer* kernel call will use.  Honors the
/// same [`set_force_scalar`] pin as the f32 dispatcher.
pub fn active_int8_isa() -> Int8Isa {
    if force_scalar() {
        Int8Isa::Scalar
    } else {
        detected_int8_isa()
    }
}

/// Short name of [`active_int8_isa`] for logs and the bench record.
pub fn int8_isa_name() -> &'static str {
    match active_int8_isa() {
        Int8Isa::Scalar => "scalar",
        #[cfg(target_arch = "x86_64")]
        Int8Isa::Avx2 => "avx2",
        #[cfg(target_arch = "aarch64")]
        Int8Isa::Neon => "neon",
    }
}

// ---------------------------------------------------------------------------
// The generic 8-lane vocabulary
// ---------------------------------------------------------------------------

/// Eight f32 lanes.  Implementations must keep lane `l` bound to
/// element index `base + l` through load/op/store so every backend
/// computes the identical IEEE operation sequence (see module docs).
trait F32x8: Copy {
    type V: Copy;
    /// # Safety
    /// `p..p+8` must be readable.
    unsafe fn load(p: *const f32) -> Self::V;
    /// # Safety
    /// `p..p+8` must be writable.
    unsafe fn store(p: *mut f32, v: Self::V);
    unsafe fn splat(v: f32) -> Self::V;
    /// Lane-wise `a * b` (a plain multiply — never fused with the
    /// following add, to preserve scalar bit-identity).
    unsafe fn mul(a: Self::V, b: Self::V) -> Self::V;
    unsafe fn add(a: Self::V, b: Self::V) -> Self::V;
}

#[derive(Clone, Copy)]
struct ScalarIsa;

impl F32x8 for ScalarIsa {
    type V = [f32; 8];

    #[inline(always)]
    unsafe fn load(p: *const f32) -> [f32; 8] {
        let mut v = [0.0f32; 8];
        for (l, slot) in v.iter_mut().enumerate() {
            *slot = *p.add(l);
        }
        v
    }

    #[inline(always)]
    unsafe fn store(p: *mut f32, v: [f32; 8]) {
        for (l, x) in v.iter().enumerate() {
            *p.add(l) = *x;
        }
    }

    #[inline(always)]
    unsafe fn splat(v: f32) -> [f32; 8] {
        [v; 8]
    }

    #[inline(always)]
    unsafe fn mul(a: [f32; 8], b: [f32; 8]) -> [f32; 8] {
        let mut o = [0.0f32; 8];
        for l in 0..8 {
            o[l] = a[l] * b[l];
        }
        o
    }

    #[inline(always)]
    unsafe fn add(a: [f32; 8], b: [f32; 8]) -> [f32; 8] {
        let mut o = [0.0f32; 8];
        for l in 0..8 {
            o[l] = a[l] + b[l];
        }
        o
    }
}

#[cfg(target_arch = "x86_64")]
mod avx {
    use std::arch::x86_64::{
        __m256, _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };

    use super::F32x8;

    #[derive(Clone, Copy)]
    pub(super) struct AvxIsa;

    impl F32x8 for AvxIsa {
        type V = __m256;

        #[inline(always)]
        unsafe fn load(p: *const f32) -> __m256 {
            _mm256_loadu_ps(p)
        }

        #[inline(always)]
        unsafe fn store(p: *mut f32, v: __m256) {
            _mm256_storeu_ps(p, v)
        }

        #[inline(always)]
        unsafe fn splat(v: f32) -> __m256 {
            _mm256_set1_ps(v)
        }

        #[inline(always)]
        unsafe fn mul(a: __m256, b: __m256) -> __m256 {
            _mm256_mul_ps(a, b)
        }

        #[inline(always)]
        unsafe fn add(a: __m256, b: __m256) -> __m256 {
            _mm256_add_ps(a, b)
        }
    }

    #[target_feature(enable = "avx")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        super::dot_impl::<AvxIsa>(a, b)
    }

    #[target_feature(enable = "avx")]
    pub(super) unsafe fn dot4(
        a0: &[f32],
        a1: &[f32],
        a2: &[f32],
        a3: &[f32],
        b: &[f32],
    ) -> [f32; 4] {
        super::dot4_impl::<AvxIsa>(a0, a1, a2, a3, b)
    }

    #[target_feature(enable = "avx")]
    pub(super) unsafe fn update1_panel(apanel: &[f32], bpanel: &[f32], n: usize, out: &mut [f32]) {
        super::update1_panel_impl::<AvxIsa>(apanel, bpanel, n, out)
    }

    #[target_feature(enable = "avx")]
    pub(super) unsafe fn update4_panel(
        apack: &[f32],
        bpanel: &[f32],
        n: usize,
        outs: [&mut [f32]; 4],
    ) {
        super::update4_panel_impl::<AvxIsa>(apack, bpanel, n, outs)
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::{
        float32x4_t, vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32,
    };

    use super::F32x8;

    #[derive(Clone, Copy)]
    pub(super) struct NeonIsa;

    /// Two q-registers = 8 lanes; `.0` holds elements `base..base+4`,
    /// `.1` holds `base+4..base+8`, matching the scalar lane order.
    #[derive(Clone, Copy)]
    pub(super) struct V8(float32x4_t, float32x4_t);

    impl F32x8 for NeonIsa {
        type V = V8;

        #[inline(always)]
        unsafe fn load(p: *const f32) -> V8 {
            V8(vld1q_f32(p), vld1q_f32(p.add(4)))
        }

        #[inline(always)]
        unsafe fn store(p: *mut f32, v: V8) {
            vst1q_f32(p, v.0);
            vst1q_f32(p.add(4), v.1);
        }

        #[inline(always)]
        unsafe fn splat(v: f32) -> V8 {
            V8(vdupq_n_f32(v), vdupq_n_f32(v))
        }

        #[inline(always)]
        unsafe fn mul(a: V8, b: V8) -> V8 {
            V8(vmulq_f32(a.0, b.0), vmulq_f32(a.1, b.1))
        }

        #[inline(always)]
        unsafe fn add(a: V8, b: V8) -> V8 {
            V8(vaddq_f32(a.0, b.0), vaddq_f32(a.1, b.1))
        }
    }

    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        super::dot_impl::<NeonIsa>(a, b)
    }

    pub(super) unsafe fn dot4(
        a0: &[f32],
        a1: &[f32],
        a2: &[f32],
        a3: &[f32],
        b: &[f32],
    ) -> [f32; 4] {
        super::dot4_impl::<NeonIsa>(a0, a1, a2, a3, b)
    }

    pub(super) unsafe fn update1_panel(apanel: &[f32], bpanel: &[f32], n: usize, out: &mut [f32]) {
        super::update1_panel_impl::<NeonIsa>(apanel, bpanel, n, out)
    }

    pub(super) unsafe fn update4_panel(
        apack: &[f32],
        bpanel: &[f32],
        n: usize,
        outs: [&mut [f32]; 4],
    ) {
        super::update4_panel_impl::<NeonIsa>(apack, bpanel, n, outs)
    }
}

// ---------------------------------------------------------------------------
// Generic microkernel bodies (monomorphized per backend)
// ---------------------------------------------------------------------------

/// 8-accumulator dot product: lane `l` accumulates elements `8c + l`,
/// lanes reduce in ascending order, the tail is scalar — the exact
/// operation sequence of the historical scalar `dot`, so every backend
/// is bit-identical.
#[inline(always)]
unsafe fn dot_impl<S: F32x8>(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut lanes = [0.0f32; 8];
    if chunks > 0 {
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = S::splat(0.0);
        for c in 0..chunks {
            let va = S::load(pa.add(c * 8));
            let vb = S::load(pb.add(c * 8));
            acc = S::add(acc, S::mul(va, vb));
        }
        S::store(lanes.as_mut_ptr(), acc);
    }
    let mut s = 0.0f32;
    for lane in lanes {
        s += lane;
    }
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// 4-row batched dot product: four independent accumulator chains
/// share each B load, and every row runs the *exact* operation
/// sequence of [`dot_impl`] (8 lanes bound to ascending indices,
/// multiply then add, lanes reduced 0 → 7, scalar tail) — so each of
/// the four results is **bit-identical** to a solo `dot` call on that
/// row.  That invariance is what keeps batched inference bitwise equal
/// to solo inference (pinned in `engine::net`).
#[inline(always)]
unsafe fn dot4_impl<S: F32x8>(
    a0: &[f32],
    a1: &[f32],
    a2: &[f32],
    a3: &[f32],
    b: &[f32],
) -> [f32; 4] {
    let k = b.len();
    debug_assert_eq!(a0.len(), k);
    debug_assert_eq!(a1.len(), k);
    debug_assert_eq!(a2.len(), k);
    debug_assert_eq!(a3.len(), k);
    let chunks = k / 8;
    let mut lanes = [[0.0f32; 8]; 4];
    if chunks > 0 {
        let pb = b.as_ptr();
        let (p0, p1, p2, p3) = (a0.as_ptr(), a1.as_ptr(), a2.as_ptr(), a3.as_ptr());
        let mut acc0 = S::splat(0.0);
        let mut acc1 = S::splat(0.0);
        let mut acc2 = S::splat(0.0);
        let mut acc3 = S::splat(0.0);
        for c in 0..chunks {
            let off = c * 8;
            let vb = S::load(pb.add(off));
            acc0 = S::add(acc0, S::mul(S::load(p0.add(off)), vb));
            acc1 = S::add(acc1, S::mul(S::load(p1.add(off)), vb));
            acc2 = S::add(acc2, S::mul(S::load(p2.add(off)), vb));
            acc3 = S::add(acc3, S::mul(S::load(p3.add(off)), vb));
        }
        S::store(lanes[0].as_mut_ptr(), acc0);
        S::store(lanes[1].as_mut_ptr(), acc1);
        S::store(lanes[2].as_mut_ptr(), acc2);
        S::store(lanes[3].as_mut_ptr(), acc3);
    }
    let rows = [a0, a1, a2, a3];
    let mut out = [0.0f32; 4];
    for ((o, row), row_lanes) in out.iter_mut().zip(rows).zip(lanes) {
        let mut s = 0.0f32;
        for lane in row_lanes {
            s += lane;
        }
        for i in chunks * 8..k {
            s += row[i] * b[i];
        }
        *o = s;
    }
    out
}

/// One packed-panel row update: `out[j] += apanel[kk] * bpanel[kk*n+j]`
/// for every `kk`, ascending, with the kernel layer's exact-zero skip.
/// `apanel` is the row's contiguous A panel (length = panel depth),
/// `bpanel` the matching contiguous B panel rows.
#[inline(always)]
unsafe fn update1_panel_impl<S: F32x8>(apanel: &[f32], bpanel: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(bpanel.len(), apanel.len() * n);
    debug_assert_eq!(out.len(), n);
    let chunks = n / 8;
    let po = out.as_mut_ptr();
    for (kk, &a) in apanel.iter().enumerate() {
        if a == 0.0 {
            continue;
        }
        let b = &bpanel[kk * n..(kk + 1) * n];
        let pb = b.as_ptr();
        let va = S::splat(a);
        for c in 0..chunks {
            let off = c * 8;
            let vb = S::load(pb.add(off));
            let vo = S::add(S::load(po.add(off)), S::mul(va, vb));
            S::store(po.add(off), vo);
        }
        for j in chunks * 8..n {
            *po.add(j) += a * b[j];
        }
    }
}

/// The 4-row register-blocked microkernel: `apack` is the interleaved
/// packed A tile (`apack[kk*4 + r]` = row `r`'s coefficient at panel
/// depth `kk`), `bpanel` the contiguous B panel, `outs` the four output
/// rows.  Four independent accumulator chains per B load.
#[inline(always)]
unsafe fn update4_panel_impl<S: F32x8>(
    apack: &[f32],
    bpanel: &[f32],
    n: usize,
    mut outs: [&mut [f32]; 4],
) {
    let kc = apack.len() / 4;
    debug_assert_eq!(bpanel.len(), kc * n);
    let chunks = n / 8;
    let p0 = outs[0].as_mut_ptr();
    let p1 = outs[1].as_mut_ptr();
    let p2 = outs[2].as_mut_ptr();
    let p3 = outs[3].as_mut_ptr();
    for kk in 0..kc {
        let a0 = apack[kk * 4];
        let a1 = apack[kk * 4 + 1];
        let a2 = apack[kk * 4 + 2];
        let a3 = apack[kk * 4 + 3];
        if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
            continue;
        }
        let b = &bpanel[kk * n..(kk + 1) * n];
        let pb = b.as_ptr();
        let (va0, va1, va2, va3) = (S::splat(a0), S::splat(a1), S::splat(a2), S::splat(a3));
        for c in 0..chunks {
            let off = c * 8;
            let vb = S::load(pb.add(off));
            S::store(p0.add(off), S::add(S::load(p0.add(off)), S::mul(va0, vb)));
            S::store(p1.add(off), S::add(S::load(p1.add(off)), S::mul(va1, vb)));
            S::store(p2.add(off), S::add(S::load(p2.add(off)), S::mul(va2, vb)));
            S::store(p3.add(off), S::add(S::load(p3.add(off)), S::mul(va3, vb)));
        }
        for j in chunks * 8..n {
            let bv = b[j];
            *p0.add(j) += a0 * bv;
            *p1.add(j) += a1 * bv;
            *p2.add(j) += a2 * bv;
            *p3.add(j) += a3 * bv;
        }
    }
}

// ---------------------------------------------------------------------------
// Integer (int8) backends
// ---------------------------------------------------------------------------
//
// i8×i8→i32 with exact i32 accumulation.  No op-sequence discipline is
// needed for bit-identity (integer addition is associative); the only
// correctness obligation is the caller's bound on `k`
// (`kernels::I8_DOT_MAX_K`) that rules out i32 overflow.

/// Plain scalar i8 dot product — the reference all SIMD integer paths
/// must match exactly (and do, by associativity of exact i32 adds).
fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0i32;
    for (&x, &y) in a.iter().zip(b.iter()) {
        s += i32::from(x) * i32::from(y);
    }
    s
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::{
        __m256i, _mm256_add_epi32, _mm256_castsi256_si128, _mm256_cvtepi8_epi16,
        _mm256_extracti128_si256, _mm256_loadu_si256, _mm256_madd_epi16, _mm256_setzero_si256,
        _mm256_storeu_si256,
    };

    /// Widen one 32-byte i8 vector into two i16×16 halves (low 16
    /// bytes, high 16 bytes) via sign extension.  Widening first keeps
    /// every `madd_epi16` pair sum ≤ 2·127² — far inside i16×i16→i32
    /// exactness — unlike `maddubs`, whose u8×i8 pair sums can
    /// saturate i16.
    #[inline(always)]
    unsafe fn widen(v: __m256i) -> (__m256i, __m256i) {
        (
            _mm256_cvtepi8_epi16(_mm256_castsi256_si128(v)),
            _mm256_cvtepi8_epi16(_mm256_extracti128_si256(v, 1)),
        )
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 32;
        let mut acc = _mm256_setzero_si256();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        for c in 0..chunks {
            let va = _mm256_loadu_si256(pa.add(c * 32) as *const __m256i);
            let vb = _mm256_loadu_si256(pb.add(c * 32) as *const __m256i);
            let (a_lo, a_hi) = widen(va);
            let (b_lo, b_hi) = widen(vb);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_lo, b_lo));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_hi, b_hi));
        }
        let mut lanes = [0i32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut s: i32 = lanes.iter().sum();
        for i in chunks * 32..a.len() {
            s += i32::from(a[i]) * i32::from(b[i]);
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4_i8(
        a0: &[i8],
        a1: &[i8],
        a2: &[i8],
        a3: &[i8],
        b: &[i8],
    ) -> [i32; 4] {
        let k = b.len();
        let chunks = k / 32;
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut acc2 = _mm256_setzero_si256();
        let mut acc3 = _mm256_setzero_si256();
        let pb = b.as_ptr();
        let (p0, p1, p2, p3) = (a0.as_ptr(), a1.as_ptr(), a2.as_ptr(), a3.as_ptr());
        for c in 0..chunks {
            let off = c * 32;
            let (b_lo, b_hi) = widen(_mm256_loadu_si256(pb.add(off) as *const __m256i));
            let (v_lo, v_hi) = widen(_mm256_loadu_si256(p0.add(off) as *const __m256i));
            acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(v_lo, b_lo));
            acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(v_hi, b_hi));
            let (v_lo, v_hi) = widen(_mm256_loadu_si256(p1.add(off) as *const __m256i));
            acc1 = _mm256_add_epi32(acc1, _mm256_madd_epi16(v_lo, b_lo));
            acc1 = _mm256_add_epi32(acc1, _mm256_madd_epi16(v_hi, b_hi));
            let (v_lo, v_hi) = widen(_mm256_loadu_si256(p2.add(off) as *const __m256i));
            acc2 = _mm256_add_epi32(acc2, _mm256_madd_epi16(v_lo, b_lo));
            acc2 = _mm256_add_epi32(acc2, _mm256_madd_epi16(v_hi, b_hi));
            let (v_lo, v_hi) = widen(_mm256_loadu_si256(p3.add(off) as *const __m256i));
            acc3 = _mm256_add_epi32(acc3, _mm256_madd_epi16(v_lo, b_lo));
            acc3 = _mm256_add_epi32(acc3, _mm256_madd_epi16(v_hi, b_hi));
        }
        let rows = [a0, a1, a2, a3];
        let accs = [acc0, acc1, acc2, acc3];
        let mut out = [0i32; 4];
        for ((o, row), acc) in out.iter_mut().zip(rows).zip(accs) {
            let mut lanes = [0i32; 8];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            let mut s: i32 = lanes.iter().sum();
            for i in chunks * 32..k {
                s += i32::from(row[i]) * i32::from(b[i]);
            }
            *o = s;
        }
        out
    }
}

#[cfg(target_arch = "aarch64")]
mod neon_i8 {
    use std::arch::aarch64::{
        int32x4_t, int8x16_t, vaddvq_s32, vdupq_n_s32, vget_high_s8, vget_low_s8, vld1q_s8,
        vmull_s8, vpadalq_s16,
    };

    /// `sdot`-style widening MAC over one 16-byte chunk of each
    /// operand: `vmull_s8` (i8×8 → i16×8 products, exact) then
    /// `vpadalq_s16` (pairwise add-accumulate into i32×4, exact).
    /// `vdotq_s32` itself needs the optional `dotprod` feature and is
    /// unstable on the crate's MSRV; this baseline form computes the
    /// same exact integers.
    #[inline(always)]
    unsafe fn mac16(acc: int32x4_t, va: int8x16_t, vb: int8x16_t) -> int32x4_t {
        let acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(va), vget_low_s8(vb)));
        vpadalq_s16(acc, vmull_s8(vget_high_s8(va), vget_high_s8(vb)))
    }

    pub(super) unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 16;
        let mut acc = vdupq_n_s32(0);
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        for c in 0..chunks {
            acc = mac16(acc, vld1q_s8(pa.add(c * 16)), vld1q_s8(pb.add(c * 16)));
        }
        let mut s = vaddvq_s32(acc);
        for i in chunks * 16..a.len() {
            s += i32::from(a[i]) * i32::from(b[i]);
        }
        s
    }

    pub(super) unsafe fn dot4_i8(
        a0: &[i8],
        a1: &[i8],
        a2: &[i8],
        a3: &[i8],
        b: &[i8],
    ) -> [i32; 4] {
        let k = b.len();
        let chunks = k / 16;
        let mut acc0 = vdupq_n_s32(0);
        let mut acc1 = vdupq_n_s32(0);
        let mut acc2 = vdupq_n_s32(0);
        let mut acc3 = vdupq_n_s32(0);
        let pb = b.as_ptr();
        let (p0, p1, p2, p3) = (a0.as_ptr(), a1.as_ptr(), a2.as_ptr(), a3.as_ptr());
        for c in 0..chunks {
            let off = c * 16;
            let vb = vld1q_s8(pb.add(off));
            acc0 = mac16(acc0, vld1q_s8(p0.add(off)), vb);
            acc1 = mac16(acc1, vld1q_s8(p1.add(off)), vb);
            acc2 = mac16(acc2, vld1q_s8(p2.add(off)), vb);
            acc3 = mac16(acc3, vld1q_s8(p3.add(off)), vb);
        }
        let rows = [a0, a1, a2, a3];
        let accs = [acc0, acc1, acc2, acc3];
        let mut out = [0i32; 4];
        for ((o, row), acc) in out.iter_mut().zip(rows).zip(accs) {
            let mut s = vaddvq_s32(acc);
            for i in chunks * 16..k {
                s += i32::from(row[i]) * i32::from(b[i]);
            }
            *o = s;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Dispatched entry points
// ---------------------------------------------------------------------------

/// Unrolled 8-lane dot product, dispatched to the active backend.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    match active_isa() {
        Isa::Scalar => unsafe { dot_impl::<ScalarIsa>(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx => unsafe { avx::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot(a, b) },
    }
}

/// 4-row batched dot product, dispatched to the active backend.  Each
/// returned element is bit-identical to `dot(a_r, b)` — four
/// accumulator chains run the same per-row operation sequence while
/// sharing each B load, which is what lets an M>1 GEMM microtile
/// amortize the B walk without perturbing solo-vs-batched bitwise
/// equality.
#[inline]
pub fn dot4(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], b: &[f32]) -> [f32; 4] {
    assert_eq!(a0.len(), b.len());
    assert_eq!(a1.len(), b.len());
    assert_eq!(a2.len(), b.len());
    assert_eq!(a3.len(), b.len());
    match active_isa() {
        Isa::Scalar => unsafe { dot4_impl::<ScalarIsa>(a0, a1, a2, a3, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx => unsafe { avx::dot4(a0, a1, a2, a3, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot4(a0, a1, a2, a3, b) },
    }
}

/// Integer i8×i8→i32 dot product with exact i32 accumulation,
/// dispatched to the active integer backend.  Exact (hence bit-
/// identical across backends) as long as `a.len() <=
/// kernels::I8_DOT_MAX_K`, which callers must guarantee.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    assert_eq!(a.len(), b.len());
    match active_int8_isa() {
        Int8Isa::Scalar => dot_i8_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        Int8Isa::Avx2 => unsafe { avx2::dot_i8(a, b) },
        #[cfg(target_arch = "aarch64")]
        Int8Isa::Neon => unsafe { neon_i8::dot_i8(a, b) },
    }
}

/// 4-row batched integer dot product (the int8 GEMM microtile),
/// dispatched to the active integer backend.  Each element equals
/// `dot_i8(a_r, b)` exactly.
#[inline]
pub fn dot4_i8(a0: &[i8], a1: &[i8], a2: &[i8], a3: &[i8], b: &[i8]) -> [i32; 4] {
    assert_eq!(a0.len(), b.len());
    assert_eq!(a1.len(), b.len());
    assert_eq!(a2.len(), b.len());
    assert_eq!(a3.len(), b.len());
    match active_int8_isa() {
        Int8Isa::Scalar => [
            dot_i8_scalar(a0, b),
            dot_i8_scalar(a1, b),
            dot_i8_scalar(a2, b),
            dot_i8_scalar(a3, b),
        ],
        #[cfg(target_arch = "x86_64")]
        Int8Isa::Avx2 => unsafe { avx2::dot4_i8(a0, a1, a2, a3, b) },
        #[cfg(target_arch = "aarch64")]
        Int8Isa::Neon => unsafe { neon_i8::dot4_i8(a0, a1, a2, a3, b) },
    }
}

/// Single-row packed-panel update, dispatched to the active backend:
/// `out[j] += apanel[kk] * bpanel[kk*n + j]` for every `kk` ascending,
/// with the kernel layer's exact-zero skip.
#[inline]
pub fn update1_panel(apanel: &[f32], bpanel: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(bpanel.len(), apanel.len() * n);
    assert_eq!(out.len(), n);
    match active_isa() {
        Isa::Scalar => unsafe { update1_panel_impl::<ScalarIsa>(apanel, bpanel, n, out) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx => unsafe { avx::update1_panel(apanel, bpanel, n, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::update1_panel(apanel, bpanel, n, out) },
    }
}

/// 4-row register-blocked microkernel over an interleaved packed A
/// tile (`apack[kk*4 + r]`), dispatched to the active backend.
#[inline]
pub fn update4_panel(apack: &[f32], bpanel: &[f32], n: usize, outs: [&mut [f32]; 4]) {
    assert_eq!(apack.len() % 4, 0);
    assert_eq!(bpanel.len(), (apack.len() / 4) * n);
    match active_isa() {
        Isa::Scalar => unsafe { update4_panel_impl::<ScalarIsa>(apack, bpanel, n, outs) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx => unsafe { avx::update4_panel(apack, bpanel, n, outs) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::update4_panel(apack, bpanel, n, outs) },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    fn scalar_dot(a: &[f32], b: &[f32]) -> f32 {
        unsafe { dot_impl::<ScalarIsa>(a, b) }
    }

    #[test]
    fn dispatched_dot_is_bitwise_scalar() {
        let _guard = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(11);
        for len in [0usize, 1, 7, 8, 9, 16, 63, 64, 65, 1000] {
            let a: Vec<f32> = rng.normal_vec(len);
            let b: Vec<f32> = rng.normal_vec(len);
            let want = scalar_dot(&a, &b);
            set_force_scalar(false);
            let got = dot(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn panel_updates_match_scalar_bitwise() {
        let _guard = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(12);
        for (kc, n) in [(1usize, 1usize), (3, 7), (5, 8), (4, 33), (16, 70)] {
            let mut apanel: Vec<f32> = rng.normal_vec(kc);
            apanel[kc / 2] = 0.0; // exercise the exact-zero skip
            let bpanel: Vec<f32> = rng.normal_vec(kc * n);
            let mut want: Vec<f32> = rng.normal_vec(n);
            let mut got = want.clone();
            unsafe { update1_panel_impl::<ScalarIsa>(&apanel, &bpanel, n, &mut want) };
            set_force_scalar(false);
            update1_panel(&apanel, &bpanel, n, &mut got);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "update1 kc={kc} n={n}"
            );

            let mut apack: Vec<f32> = rng.normal_vec(kc * 4);
            apack[0] = 0.0;
            let mut want4: Vec<f32> = rng.normal_vec(4 * n);
            let mut got4 = want4.clone();
            {
                let (w0, rest) = want4.split_at_mut(n);
                let (w1, rest) = rest.split_at_mut(n);
                let (w2, w3) = rest.split_at_mut(n);
                unsafe { update4_panel_impl::<ScalarIsa>(&apack, &bpanel, n, [w0, w1, w2, w3]) };
            }
            {
                let (g0, rest) = got4.split_at_mut(n);
                let (g1, rest) = rest.split_at_mut(n);
                let (g2, g3) = rest.split_at_mut(n);
                update4_panel(&apack, &bpanel, n, [g0, g1, g2, g3]);
            }
            assert_eq!(
                got4.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want4.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "update4 kc={kc} n={n}"
            );
        }
    }

    #[test]
    fn force_scalar_pins_the_scalar_backend() {
        let _guard = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_force_scalar(true);
        assert_eq!(active_isa(), Isa::Scalar);
        assert_eq!(isa_name(), "scalar");
        assert_eq!(active_int8_isa(), Int8Isa::Scalar);
        assert_eq!(int8_isa_name(), "scalar");
        set_force_scalar(false);
        // Detection is cached; whatever it picked, the name matches.
        let name = isa_name();
        assert!(["scalar", "avx", "neon"].contains(&name), "{name}");
        let iname = int8_isa_name();
        assert!(["scalar", "avx2", "neon"].contains(&iname), "{iname}");
    }

    fn random_i8(rng: &mut Pcg64, len: usize) -> Vec<i8> {
        rng.normal_vec(len)
            .into_iter()
            .map(|x| (x * 50.0).clamp(-127.0, 127.0) as i8)
            .collect()
    }

    #[test]
    fn dispatched_dot4_rows_are_bitwise_solo_dots() {
        let _guard = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(21);
        for len in [0usize, 1, 7, 8, 9, 16, 33, 64, 100, 1000] {
            let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(len)).collect();
            let b: Vec<f32> = rng.normal_vec(len);
            set_force_scalar(false);
            let got = dot4(&rows[0], &rows[1], &rows[2], &rows[3], &b);
            for (r, g) in got.iter().enumerate() {
                let solo = dot(&rows[r], &b);
                assert_eq!(g.to_bits(), solo.to_bits(), "len {len} row {r}");
                let scalar = scalar_dot(&rows[r], &b);
                assert_eq!(g.to_bits(), scalar.to_bits(), "len {len} row {r} vs scalar");
            }
        }
    }

    #[test]
    fn integer_dot_matches_scalar_reference_exactly() {
        let _guard = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(22);
        for len in [0usize, 1, 15, 16, 17, 31, 32, 33, 64, 100, 1000] {
            let a = random_i8(&mut rng, len);
            let b = random_i8(&mut rng, len);
            let want = dot_i8_scalar(&a, &b);
            set_force_scalar(false);
            assert_eq!(dot_i8(&a, &b), want, "len {len} dispatched vs scalar");
            set_force_scalar(true);
            assert_eq!(dot_i8(&a, &b), want, "len {len} forced-scalar");
            set_force_scalar(false);
        }
    }

    #[test]
    fn integer_dot4_rows_equal_solo_integer_dots() {
        let _guard = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(23);
        for len in [0usize, 1, 15, 16, 17, 33, 100, 1000] {
            let rows: Vec<Vec<i8>> = (0..4).map(|_| random_i8(&mut rng, len)).collect();
            let b = random_i8(&mut rng, len);
            set_force_scalar(false);
            let got = dot4_i8(&rows[0], &rows[1], &rows[2], &rows[3], &b);
            for (r, g) in got.iter().enumerate() {
                assert_eq!(*g, dot_i8(&rows[r], &b), "len {len} row {r}");
                assert_eq!(*g, dot_i8_scalar(&rows[r], &b), "len {len} row {r} vs scalar");
            }
        }
    }

    #[test]
    fn integer_dot_is_exact_at_saturated_inputs() {
        let _guard = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // Worst-case magnitudes: every product is ±127·127 (or 128·128
        // when fed raw i8::MIN, which the quantizer never emits but the
        // kernel must still handle).  k = 1000 keeps the exact sum well
        // inside i32; the i64 recomputation pins exactness end-to-end.
        for (x, y, k) in [
            (127i8, 127i8, 1000usize),
            (-127, 127, 1000),
            (i8::MIN, i8::MIN, 1000),
            (i8::MIN, 127, 999),
        ] {
            let a = vec![x; k];
            let b = vec![y; k];
            let want_i64 = i64::from(x) * i64::from(y) * k as i64;
            let want = i32::try_from(want_i64).expect("test sum fits i32");
            set_force_scalar(false);
            assert_eq!(dot_i8(&a, &b), want, "{x}*{y} k={k} dispatched");
            set_force_scalar(true);
            assert_eq!(dot_i8(&a, &b), want, "{x}*{y} k={k} scalar");
            set_force_scalar(false);
        }
    }
}

//! Cholesky decomposition + triangular solves.
//!
//! Needed by the SVD-LLM baseline's "truncation-aware data whitening"
//! (Appendix A.4): S is the Cholesky factor of X Xᵀ and the whitened
//! weight is W S with S⁻¹ applied on the way back.

use anyhow::{bail, Result};

use super::matrix::Mat;

/// Lower-triangular L with L Lᵀ = A for symmetric positive-definite A.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if a.rows != a.cols {
        bail!("cholesky expects square, got {}x{}", a.rows, a.cols);
    }
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= (l.at(i, k) as f64) * (l.at(j, k) as f64);
            }
            if i == j {
                if s <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (s={s})");
                }
                *l.at_mut(i, j) = s.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (s / l.at(j, j) as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Solve L x = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= (l.at(i, k) as f64) * (x[k] as f64);
        }
        x[i] = (s / l.at(i, i) as f64) as f32;
    }
    x
}

/// Solve Lᵀ x = b for lower-triangular L (back substitution).
pub fn solve_lower_t(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = b[i] as f64;
        for k in (i + 1)..n {
            s -= (l.at(k, i) as f64) * (x[k] as f64);
        }
        x[i] = (s / l.at(i, i) as f64) as f32;
    }
    x
}

/// Inverse of a lower-triangular matrix (column-by-column solves).
pub fn invert_lower(l: &Mat) -> Mat {
    let n = l.rows;
    let mut inv = Mat::zeros(n, n);
    for j in 0..n {
        let mut e = vec![0.0f32; n];
        e[j] = 1.0;
        let col = solve_lower(l, &e);
        inv.set_col(j, &col);
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let a = Mat::random(n, n + 4, &mut rng);
        let mut g = a.matmul_nt(&a);
        for i in 0..n {
            *g.at_mut(i, i) += 0.1; // boost conditioning
        }
        g
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(10, 1);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul_nt(&l);
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-2 * a.frob_norm());
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigvals 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solves_are_inverses() {
        let a = random_spd(8, 2);
        let l = cholesky(&a).unwrap();
        let mut rng = Pcg64::new(3);
        let b: Vec<f32> = rng.normal_vec(8);
        let y = solve_lower(&l, &b);
        // L y = b
        let ly = l.matvec(&y);
        for (p, q) in ly.iter().zip(&b) {
            assert!((p - q).abs() < 1e-4);
        }
        let z = solve_lower_t(&l, &b);
        let ltz = l.transpose().matvec(&z);
        for (p, q) in ltz.iter().zip(&b) {
            assert!((p - q).abs() < 1e-4);
        }
    }

    #[test]
    fn invert_lower_matches_identity() {
        let a = random_spd(6, 4);
        let l = cholesky(&a).unwrap();
        let li = invert_lower(&l);
        let prod = l.matmul(&li);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-4);
            }
        }
    }
}

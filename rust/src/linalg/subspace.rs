//! Warm-started subspace iteration (Stewart & Miller 1975; PowerSGD
//! reuse, Vogels et al. 2019) — the compute core of both WSI and ASI.

use crate::data::rng::Pcg64;

use super::matrix::Mat;
use super::qr::gram_schmidt;

/// Persistent basis for one matrix stream (one layer-mode pair).
#[derive(Debug, Clone)]
pub struct SubspaceState {
    pub u: Mat, // (a, r) orthonormal basis
}

impl SubspaceState {
    /// Random-normal initialization, orthogonalized (Algorithm 2, t = 0).
    pub fn random(a: usize, r: usize, rng: &mut Pcg64) -> Self {
        let init = Mat::random(a, r, rng);
        SubspaceState { u: gram_schmidt(&init) }
    }

    /// Initialization from a known basis (e.g. build-time HOSVD factors).
    pub fn from_basis(u: Mat) -> Self {
        SubspaceState { u }
    }

    /// One warm-started iteration on unfolding `a_m` (a, b):
    /// V = A_mᵀ U;  U' = orth(A_m V).  Returns the projection A ≈ U U' ᵀ ...
    pub fn step(&mut self, a_m: &Mat) {
        let v = a_m.matmul_tn(&self.u); // (b, r)
        let p = a_m.matmul(&v);         // (a, r)
        self.u = gram_schmidt(&p);
    }

    pub fn rank(&self) -> usize {
        self.u.cols
    }
}

/// Run `iters` subspace iterations from a random start; returns the basis.
/// With enough iterations this converges to the top-r left singular
/// vectors of `a_m` — the property the unit tests pin down.
pub fn subspace_iterate(a_m: &Mat, r: usize, iters: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut st = SubspaceState::random(a_m.rows, r, &mut rng);
    for _ in 0..iters {
        st.step(a_m);
    }
    st.u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd;

    #[test]
    fn converges_to_dominant_subspace() {
        // Construct a matrix with a strong rank-3 dominant subspace.
        let mut rng = Pcg64::new(7);
        let u = gram_schmidt(&Mat::random(30, 3, &mut rng));
        let v = gram_schmidt(&Mat::random(40, 3, &mut rng));
        let mut a = Mat::zeros(30, 40);
        for (j, s) in [10.0f32, 8.0, 6.0].iter().enumerate() {
            for i in 0..30 {
                for k in 0..40 {
                    *a.at_mut(i, k) += s * u.at(i, j) * v.at(k, j);
                }
            }
        }
        // small noise
        let noise = Mat::random(30, 40, &mut rng);
        let mut an = a.clone();
        for (x, n) in an.data.iter_mut().zip(&noise.data) {
            *x += 0.01 * n;
        }
        let basis = subspace_iterate(&an, 3, 10, 1);
        // Projection of the true dominant space onto span(basis) ≈ identity.
        let proj = basis.matmul_tn(&u); // (3, 3)
        let d = svd(&proj);
        for &s in &d.s {
            assert!(s > 0.99, "principal angle cos {s}");
        }
    }

    #[test]
    fn warm_start_tracks_slow_changes() {
        // A slowly-rotating low-rank matrix: a warm-started single step per
        // "iteration" keeps up (the stability argument of §3.3/App. A.2).
        let mut rng = Pcg64::new(9);
        let u0 = gram_schmidt(&Mat::random(20, 2, &mut rng));
        let v0 = gram_schmidt(&Mat::random(25, 2, &mut rng));
        let build = |t: f32, u0: &Mat, v0: &Mat| -> Mat {
            let mut a = Mat::zeros(20, 25);
            let (c, s) = ((0.02 * t).cos(), (0.02 * t).sin());
            for i in 0..20 {
                for k in 0..25 {
                    // rotate the two principal directions slightly over time
                    let u1 = c * u0.at(i, 0) + s * u0.at(i, 1);
                    let u2 = -s * u0.at(i, 0) + c * u0.at(i, 1);
                    *a.at_mut(i, k) += 5.0 * u1 * v0.at(k, 0) + 3.0 * u2 * v0.at(k, 1);
                }
            }
            a
        };
        let mut st = SubspaceState::random(20, 2, &mut rng);
        // burn-in on the t=0 matrix
        let a0 = build(0.0, &u0, &v0);
        for _ in 0..8 {
            st.step(&a0);
        }
        let mut worst = 1.0f32;
        for t in 1..20 {
            let a = build(t as f32, &u0, &v0);
            st.step(&a); // ONE step per change
            // residual of projecting a onto span(u)
            let proj = st.u.matmul(&st.u.matmul_tn(&a));
            let rel = proj.sub(&a).frob_norm() / a.frob_norm();
            worst = worst.min(1.0 - rel);
            assert!(rel < 0.05, "tracking residual {rel} at t={t}");
        }
    }

    #[test]
    fn basis_stays_orthonormal() {
        let mut rng = Pcg64::new(11);
        let a = Mat::random(15, 50, &mut rng);
        let mut st = SubspaceState::random(15, 4, &mut rng);
        for _ in 0..5 {
            st.step(&a);
            let g = st.u.matmul_tn(&st.u);
            for i in 0..4 {
                for j in 0..4 {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((g.at(i, j) - want).abs() < 1e-3);
                }
            }
        }
    }
}

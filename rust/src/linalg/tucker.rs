//! Dense N-d tensor + Tucker/HOSVD operations (App. A.2).
//!
//! `unfold` / `mode_product` implement the i-mode algebra of Eq. 27;
//! `hosvd` is the truncated HOSVD the AMC baseline runs every iteration
//! (and that WASI's build-time calibration uses once).

use super::matrix::Mat;
use super::svd::svd;

/// Dense row-major (C-order) tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32
    }

    /// Row-major strides.
    fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }
}

/// Mode-m unfolding: (`shape[m]`, prod(other dims)) with the other dims in
/// their original relative order (matches `jnp.moveaxis(t, m, 0).reshape`).
pub fn unfold(t: &Tensor, mode: usize) -> Mat {
    let dm = t.shape[mode];
    let rest: usize = t.numel() / dm;
    let strides = t.strides();
    let mut out = Mat::zeros(dm, rest);

    // Iterate all elements once, computing target positions.
    let ndim = t.shape.len();
    let mut idx = vec![0usize; ndim];
    for (lin, &v) in t.data.iter().enumerate() {
        // decode row-major index (cheap incremental counter)
        let _ = lin;
        let i_m = idx[mode];
        // column index = row-major index over dims != mode, preserving order
        let mut col = 0usize;
        for d in 0..ndim {
            if d == mode {
                continue;
            }
            col = col * t.shape[d] + idx[d];
        }
        out.data[i_m * rest + col] = v;
        // increment counter
        for d in (0..ndim).rev() {
            idx[d] += 1;
            if idx[d] < t.shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    let _ = strides;
    out
}

/// Inverse of `unfold` for a given mode and full shape.
pub fn fold(m: &Mat, mode: usize, shape: &[usize]) -> Tensor {
    let mut t = Tensor::zeros(shape);
    let ndim = shape.len();
    let rest: usize = shape.iter().product::<usize>() / shape[mode];
    let mut idx = vec![0usize; ndim];
    for v in t.data.iter_mut() {
        let i_m = idx[mode];
        let mut col = 0usize;
        for d in 0..ndim {
            if d == mode {
                continue;
            }
            col = col * shape[d] + idx[d];
        }
        *v = m.data[i_m * rest + col];
        for d in (0..ndim).rev() {
            idx[d] += 1;
            if idx[d] < shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    t
}

/// i-mode product  T ×_mode M  with M (q, `shape[mode]`)  (Eq. 27).
pub fn mode_product(t: &Tensor, m: &Mat, mode: usize) -> Tensor {
    assert_eq!(m.cols, t.shape[mode], "mode_product dims");
    let unfolded = unfold(t, mode);           // (d_m, rest)
    let prod = m.matmul(&unfolded);           // (q, rest)
    let mut new_shape = t.shape.clone();
    new_shape[mode] = m.rows;
    fold(&prod, mode, &new_shape)
}

/// Truncated HOSVD: returns (core, factors) with `factors[m]` (d_m, r_m).
pub fn hosvd(t: &Tensor, ranks: &[usize]) -> (Tensor, Vec<Mat>) {
    assert_eq!(ranks.len(), t.shape.len());
    let mut factors = Vec::with_capacity(ranks.len());
    for (m, &r) in ranks.iter().enumerate() {
        let a = unfold(t, m);
        let d = svd(&a);
        let r = r.min(d.u.cols);
        let mut u = Mat::zeros(a.rows, r);
        for i in 0..a.rows {
            for j in 0..r {
                u.data[i * r + j] = d.u.at(i, j);
            }
        }
        factors.push(u);
    }
    let mut core = t.clone();
    for (m, u) in factors.iter().enumerate() {
        core = mode_product(&core, &u.transpose(), m);
    }
    (core, factors)
}

/// Reconstruct from Tucker form: core ×_0 U0 ×_1 U1 ...
pub fn tucker_reconstruct(core: &Tensor, factors: &[Mat]) -> Tensor {
    let mut out = core.clone();
    for (m, u) in factors.iter().enumerate() {
        out = mode_product(&out, u, m);
    }
    out
}

/// Per-mode explained-variance rank selection on a tensor (Fig. 4 study).
pub fn energy_ranks(t: &Tensor, eps: f64) -> Vec<usize> {
    (0..t.shape.len())
        .map(|m| {
            let a = unfold(t, m);
            svd(&a).rank_for_energy(eps).min(a.rows)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    fn random_tensor(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg64::new(seed);
        Tensor::from_vec(shape, rng.normal_vec(shape.iter().product()))
    }

    #[test]
    fn unfold_fold_roundtrip() {
        let t = random_tensor(&[3, 4, 5], 1);
        for mode in 0..3 {
            let m = unfold(&t, mode);
            assert_eq!(m.rows, t.shape[mode]);
            let back = fold(&m, mode, &t.shape);
            assert_eq!(back.data, t.data);
        }
    }

    #[test]
    fn unfold_matches_manual_3d() {
        // t[i,j,k] with shape (2,2,2), data 0..8 row-major.
        let t = Tensor::from_vec(&[2, 2, 2], (0..8).map(|x| x as f32).collect());
        let m1 = unfold(&t, 1); // rows indexed by j, cols by (i,k)
        // element (j=1, i=0, k=1) = t[0,1,1] = 3; col = i*2+k = 1
        assert_eq!(m1.at(1, 1), 3.0);
        let m0 = unfold(&t, 0);
        assert_eq!(m0.row(0), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn mode_product_identity() {
        let t = random_tensor(&[4, 3, 6], 2);
        for mode in 0..3 {
            let p = mode_product(&t, &Mat::eye(t.shape[mode]), mode);
            assert_eq!(p.data, t.data);
        }
    }

    #[test]
    fn hosvd_exact_at_full_rank() {
        let t = random_tensor(&[4, 5, 3], 3);
        let (core, factors) = hosvd(&t, &[4, 5, 3]);
        let rec = tucker_reconstruct(&core, &factors);
        let err: f32 = rec
            .data
            .iter()
            .zip(&t.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-3, "max err {err}");
    }

    #[test]
    fn hosvd_compresses_lowrank_tensor() {
        // Build a rank-(2,2,2) tensor exactly.
        let mut rng = Pcg64::new(4);
        let core = random_tensor(&[2, 2, 2], 5);
        let u0 = Mat::random(6, 2, &mut rng);
        let u1 = Mat::random(7, 2, &mut rng);
        let u2 = Mat::random(8, 2, &mut rng);
        let t = tucker_reconstruct(&core, &[u0, u1, u2]);
        let (c2, f2) = hosvd(&t, &[2, 2, 2]);
        let rec = tucker_reconstruct(&c2, &f2);
        let rel = {
            let mut d = 0.0f64;
            for (a, b) in rec.data.iter().zip(&t.data) {
                d += ((a - b) * (a - b)) as f64;
            }
            (d.sqrt() as f32) / t.frob_norm()
        };
        assert!(rel < 1e-3, "relative error {rel}");
        assert_eq!(energy_ranks(&t, 0.999), vec![2, 2, 2]);
    }
}

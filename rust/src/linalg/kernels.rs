//! The shared GEMM kernel layer — the ONE optimization site every
//! matmul in the crate routes through (DESIGN.md §Kernels): `Mat`'s
//! operator methods, the `wasi::{layer, wsi, lowrank_grad}` math, the
//! baselines, and the engine graph executor all end up in `gemm_nn` /
//! `gemm_nt` / `gemm_tn` below.
//!
//! Design (DESIGN.md §Kernels, EXPERIMENTS.md §Perf):
//!
//! * **Row-sliced threading** — output rows are split into disjoint
//!   contiguous ranges across `util::threadpool::parallel_ranges`
//!   workers.  Each output element is accumulated by exactly one thread
//!   in ascending-k order, so results are **bit-identical for every
//!   thread count** (pinned by `tests` below and the engine-parity
//!   suite) — `--threads` trades wall-clock only.
//! * **SIMD microkernels** — the inner loops run on the runtime-
//!   dispatched 8-lane primitives in [`super::simd`] (AVX on x86_64,
//!   NEON on aarch64, a scalar 8-lane fallback everywhere else).  The
//!   primitives use multiply-then-add (never FMA) with lanes bound to
//!   ascending element indices, so **scalar and SIMD results are
//!   bit-identical** too (pinned by the parity tests below at shapes
//!   with remainder lanes).
//! * **Packed panels** — `gemm_nn`/`gemm_tn` walk k in `KC`-wide panels
//!   and pack the active A tile into a contiguous register-blocked
//!   layout (`apack[kk*4 + r]`), so the microkernel streams one
//!   contiguous A stream and one contiguous B panel (`b[k0*n..k1*n]`
//!   is already contiguous row-major — B needs no copy) instead of
//!   striding across the source matrix per coefficient.
//! * **Register blocking** — the packed microkernel feeds each
//!   streamed B row into FOUR output rows (4x fewer B loads, four
//!   independent accumulator chains); `gemm_nt` uses the 8-lane
//!   [`dot`].
//! * **Fused epilogues** — bias add, GELU, and the reduced-precision
//!   dequantization run inside the parallel region while the output
//!   panel is still hot ([`Epilogue`]), instead of a second full sweep
//!   from memory after the join.
//! * **Dequantizing GEMM** — [`gemm_nt_deq`] is `gemm_nt` over int8 or
//!   bf16 weight payloads (`crate::precision`): weight rows dequantize
//!   block-wise into a per-thread f32 panel (each element converts once
//!   per thread, not once per output row), the dots run on the same
//!   SIMD [`dot`] as the f32 path, and the int8 per-tensor scale folds
//!   into the epilogue ([`Epilogue::ScaleBias`]).  Still the reference
//!   int8 semantics the true-integer path is measured against, and the
//!   production bf16 path.
//! * **True-integer GEMM** — [`gemm_nt_i8`] never dequantizes:
//!   activations quantize per-row once (`precision::quantize_i8_rows`),
//!   the dots run as exact i8×i8→i32 integer arithmetic on
//!   [`simd::dot_i8`] / [`simd::dot4_i8`] (AVX2 / NEON / scalar,
//!   bit-identical by construction), and the combined scale applies
//!   once per output in the epilogue.  This is what makes int8 faster
//!   — not just smaller — than f32 (ROADMAP item 3).
//! * **M>1 microtiles** — `gemm_nt` and `gemm_nt_i8` walk output rows
//!   four at a time ([`simd::dot4`] / [`simd::dot4_i8`]), so a
//!   coalesced batch from the serving front-end amortizes each B-row
//!   load across four requests without perturbing solo-vs-batched
//!   bitwise equality.

use crate::util::threadpool::parallel_ranges;

use super::simd;

/// k-panel width for cache blocking (a KC x n B-panel of f32 at the
/// model dims this crate runs stays within L2 alongside the output
/// rows).
const KC: usize = 128;

pub const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
pub const GELU_A: f32 = 0.044_715;

/// tanh-approximation GELU (matches `python/compile/model.py`).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_A * x * x * x)).tanh())
}

/// d/dx of [`gelu`].
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    let inner = GELU_C * (x + GELU_A * x * x * x);
    let t = inner.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * x * x)
}

/// 8-lane dot product on the runtime-dispatched SIMD backend
/// (bit-identical across backends; see `linalg::simd`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot(a, b)
}

/// A weight element the dequantizing GEMM can convert to f32 in its
/// inner loop: int8 payloads (per-tensor scale applied by the
/// epilogue) and raw bf16 bits (exact conversion).
pub trait DequantElem: Copy + Send + Sync {
    fn to_f32(self) -> f32;
}

impl DequantElem for i8 {
    #[inline(always)]
    fn to_f32(self) -> f32 {
        self as f32
    }
}

/// bf16 bits (see `crate::precision::bf16_to_f32`).
impl DequantElem for u16 {
    #[inline(always)]
    fn to_f32(self) -> f32 {
        crate::precision::bf16_to_f32(self)
    }
}

/// Epilogue fused into the GEMM's parallel region, applied per output
/// row while the row is cache-hot.
#[derive(Clone, Copy)]
pub enum Epilogue<'a> {
    /// Plain C = A·B.
    None,
    /// C = A·B + bias (bias broadcast over rows; `bias.len() == n`).
    Bias(&'a [f32]),
    /// C = gelu(A·B + bias) — the inference fc1 fusion.
    BiasGelu(&'a [f32]),
    /// C = gelu(A·B).
    Gelu,
    /// C = s·(A·B) — int8 dequantization without a bias (the factored
    /// rank-space product).
    Scale(f32),
    /// C = s·(A·B) + bias — the int8 dequantizing epilogue.
    ScaleBias(f32, &'a [f32]),
    /// C = gelu(s·(A·B) + bias) — dequantize + fc1 fusion in one pass.
    ScaleBiasGelu(f32, &'a [f32]),
}

impl Epilogue<'_> {
    #[inline]
    fn apply(&self, row: &mut [f32]) {
        match self {
            Epilogue::None => {}
            Epilogue::Bias(bias) => {
                for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                    *o += bv;
                }
            }
            Epilogue::BiasGelu(bias) => {
                for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                    *o = gelu(*o + bv);
                }
            }
            Epilogue::Gelu => {
                for o in row.iter_mut() {
                    *o = gelu(*o);
                }
            }
            Epilogue::Scale(s) => {
                for o in row.iter_mut() {
                    *o *= s;
                }
            }
            Epilogue::ScaleBias(s, bias) => {
                for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                    *o = *o * s + bv;
                }
            }
            Epilogue::ScaleBiasGelu(s, bias) => {
                for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                    *o = gelu(*o * s + bv);
                }
            }
        }
    }
}

/// Shareable raw pointer for scoped-thread row writes (each thread owns
/// a disjoint row range, so no aliasing).
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// C (m x n) = A (m x k) · B (k x n), then `epi`.  Overwrites `out`.
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], epi: Epilogue) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        let panel = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo * n), (hi - lo) * n) };
        panel.fill(0.0);
        // Packed A tile, reused across k-panels (4 rows x KC depths,
        // interleaved so the microkernel reads one contiguous stream).
        let mut apack = vec![0.0f32; 4 * KC];
        // k-panel loop OUTSIDE the row loop: the KC x n slab of B stays
        // cache-resident across this thread's whole row range.  Each
        // output element still accumulates in ascending-k order, so the
        // result is independent of KC, the thread partition, and the
        // SIMD backend.
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            let kc = k1 - k0;
            let bpanel = &b[k0 * n..k1 * n];
            let mut i = lo;
            while i + 4 <= hi {
                // Pack row-by-row: each source row is read contiguously,
                // the tile interleaves as apack[kk*4 + r].
                for r in 0..4 {
                    let a_row = &a[(i + r) * k + k0..(i + r) * k + k1];
                    for (kk, &v) in a_row.iter().enumerate() {
                        apack[kk * 4 + r] = v;
                    }
                }
                let out4 = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), 4 * n) };
                let (o0, rest) = out4.split_at_mut(n);
                let (o1, rest) = rest.split_at_mut(n);
                let (o2, o3) = rest.split_at_mut(n);
                simd::update4_panel(&apack[..kc * 4], bpanel, n, [o0, o1, o2, o3]);
                i += 4;
            }
            // remainder rows: the A panel is already contiguous per row.
            for ii in i..hi {
                let out_row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(ii * n), n) };
                simd::update1_panel(&a[ii * k + k0..ii * k + k1], bpanel, n, out_row);
            }
            k0 = k1;
        }
        for i in lo..hi {
            let row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            epi.apply(row);
        }
    });
}

/// C (m x n) = A (m x k) · Bᵀ with B stored (n x k) — dot-product form,
/// no transpose materialized.  Then `epi`.  Overwrites `out`.
///
/// Rows run through the 4-row [`simd::dot4`] microtile (each B row
/// loads once per four output rows — the M>1 form the micro-batching
/// front-end coalesces into), with single-row [`simd::dot`] remainders.
/// `dot4` rows are bit-identical to solo `dot` calls, so the result is
/// independent of m and of where the 4-row blocking lands — batched
/// inference stays bitwise equal to solo inference (pinned below and
/// in `engine::net`).
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], epi: Epilogue) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        let mut i = lo;
        while i + 4 <= hi {
            let out4 = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), 4 * n) };
            let (o0, rest) = out4.split_at_mut(n);
            let (o1, rest) = rest.split_at_mut(n);
            let (o2, o3) = rest.split_at_mut(n);
            let a0 = &a[i * k..(i + 1) * k];
            let a1 = &a[(i + 1) * k..(i + 2) * k];
            let a2 = &a[(i + 2) * k..(i + 3) * k];
            let a3 = &a[(i + 3) * k..(i + 4) * k];
            for j in 0..n {
                let b_row = &b[j * k..(j + 1) * k];
                let s4 = simd::dot4(a0, a1, a2, a3, b_row);
                o0[j] = s4[0];
                o1[j] = s4[1];
                o2[j] = s4[2];
                o3[j] = s4[3];
            }
            for row in [o0, o1, o2, o3] {
                epi.apply(row);
            }
            i += 4;
        }
        for ii in i..hi {
            let out_row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(ii * n), n) };
            let a_row = &a[ii * k..(ii + 1) * k];
            for (j, o) in out_row.iter_mut().enumerate() {
                let b_row = &b[j * k..(j + 1) * k];
                *o = dot(a_row, b_row);
            }
            epi.apply(out_row);
        }
    });
}

/// Column-block width for the dequantizing GEMM: JB weight rows are
/// converted to f32 once per thread and reused across the thread's
/// whole row range, so each weight element converts `threads` times
/// per call instead of `m` times, and the inner dot runs on the SIMD
/// backend.
const JB: usize = 8;

/// [`gemm_nt`] against a reduced-precision B (int8 payloads or bf16
/// bits): C (m x n) = A (m x k) · Bᵀ with B stored (n x k).  Weight
/// rows dequantize block-wise into a per-thread f32 panel and the dot
/// products run on the same SIMD [`dot`] as the f32 path, so results
/// are bit-identical to `gemm_nt` over the dequantized tensor; the
/// int8 per-tensor scale belongs in `epi` ([`Epilogue::Scale`] forms).
/// The row partition matches `gemm_nt` exactly.
pub fn gemm_nt_deq<E: DequantElem>(
    a: &[f32],
    b: &[E],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    epi: Epilogue,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        let mut bconv = vec![0.0f32; JB * k];
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + JB).min(n);
            for (jj, j) in (j0..j1).enumerate() {
                let b_row = &b[j * k..(j + 1) * k];
                for (dst, &e) in bconv[jj * k..(jj + 1) * k].iter_mut().zip(b_row) {
                    *dst = e.to_f32();
                }
            }
            for i in lo..hi {
                let out_row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
                let a_row = &a[i * k..(i + 1) * k];
                for (jj, j) in (j0..j1).enumerate() {
                    out_row[j] = dot(a_row, &bconv[jj * k..(jj + 1) * k]);
                }
            }
            j0 = j1;
        }
        for i in lo..hi {
            let row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            epi.apply(row);
        }
    });
}

/// Largest reduction depth the integer GEMM accepts: with every
/// product bounded by `127·127`, `k` of them summed exactly in i32
/// needs `k ≤ i32::MAX / 127²`.  Model dims sit orders of magnitude
/// below this; the assert in [`gemm_nt_i8`] turns a silent-wraparound
/// hazard into a loud error.
pub const I8_DOT_MAX_K: usize = i32::MAX as usize / (127 * 127);

/// TRUE-integer [`gemm_nt`] against int8 weights: C (m x n) =
/// A (m x k) · Bᵀ with B stored (n x k) as raw quantized bytes and
/// per-tensor scale `wscale`.  Unlike [`gemm_nt_deq`] — which
/// dequantizes every weight to f32 lanes before the dot — this path
/// quantizes each *activation row* once (per-row symmetric scale,
/// `precision::quantize_i8_rows`), runs i8×i8→i32 integer dots on the
/// runtime-dispatched [`simd::dot_i8`] / [`simd::dot4_i8`] microtile,
/// and applies the combined scale `s_row · wscale` once per output in
/// the epilogue.  That is O(m·k) conversion work amortized over n
/// outputs, vs the deq path's O(n·k) per thread.
///
/// **Determinism:** the integer accumulation is *exact* (the assert on
/// [`I8_DOT_MAX_K`] rules out i32 overflow), so results are
/// bit-identical across scalar/AVX2/NEON backends, thread counts, and
/// batch blockings by construction; the f32 epilogue applies one fixed
/// operation sequence per element (`acc as f32 * (s_row * wscale)`,
/// then `epi`).  Row scales are computed before the parallel region so
/// every thread partition sees identical quantized activations.
///
/// **Epilogue contract:** pass the PLAIN forms (`None` / `Bias` /
/// `BiasGelu` / `Gelu`).  The quantization scales are applied
/// intrinsically — a `Scale*` epilogue would double-scale.
///
/// **Accuracy:** vs the dequantizing path the only new error is the
/// activation round-trip: per output element the difference is at most
/// `(s_row/2) · 127 · k · wscale` (|x − q·s| ≤ s/2 against weight
/// magnitudes ≤ 127·wscale, summed over k), pinned in tests.
#[allow(clippy::too_many_arguments)] // the GEMM signature family + the weight scale
pub fn gemm_nt_i8(
    a: &[f32],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    wscale: f32,
    out: &mut [f32],
    epi: Epilogue,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    assert!(
        k <= I8_DOT_MAX_K,
        "gemm_nt_i8: k = {k} exceeds the exact-i32 accumulation bound {I8_DOT_MAX_K}"
    );
    debug_assert!(
        !matches!(
            epi,
            Epilogue::Scale(_) | Epilogue::ScaleBias(..) | Epilogue::ScaleBiasGelu(..)
        ),
        "gemm_nt_i8 applies quantization scales intrinsically; pass a plain epilogue"
    );
    let (qa, ascales) = crate::precision::quantize_i8_rows(a, m, k);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        let mut i = lo;
        while i + 4 <= hi {
            let out4 = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), 4 * n) };
            let (o0, rest) = out4.split_at_mut(n);
            let (o1, rest) = rest.split_at_mut(n);
            let (o2, o3) = rest.split_at_mut(n);
            let a0 = &qa[i * k..(i + 1) * k];
            let a1 = &qa[(i + 1) * k..(i + 2) * k];
            let a2 = &qa[(i + 2) * k..(i + 3) * k];
            let a3 = &qa[(i + 3) * k..(i + 4) * k];
            let s0 = ascales[i] * wscale;
            let s1 = ascales[i + 1] * wscale;
            let s2 = ascales[i + 2] * wscale;
            let s3 = ascales[i + 3] * wscale;
            for j in 0..n {
                let b_row = &b[j * k..(j + 1) * k];
                let acc = simd::dot4_i8(a0, a1, a2, a3, b_row);
                o0[j] = acc[0] as f32 * s0;
                o1[j] = acc[1] as f32 * s1;
                o2[j] = acc[2] as f32 * s2;
                o3[j] = acc[3] as f32 * s3;
            }
            for row in [o0, o1, o2, o3] {
                epi.apply(row);
            }
            i += 4;
        }
        for ii in i..hi {
            let out_row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(ii * n), n) };
            let a_row = &qa[ii * k..(ii + 1) * k];
            let srow = ascales[ii] * wscale;
            for (j, o) in out_row.iter_mut().enumerate() {
                let b_row = &b[j * k..(j + 1) * k];
                *o = simd::dot_i8(a_row, b_row) as f32 * srow;
            }
            epi.apply(out_row);
        }
    });
}

/// Payload of a [`PackedPanel`]: either a pre-dequantized f32 image
/// (bf16 weights) or raw int8 bytes with their per-tensor scale (the
/// true-integer path — a quarter of the f32 image's footprint).
pub enum PanelPayload {
    /// Dequantized `(n x k)` row-major f32 image.
    F32(Vec<f32>),
    /// Raw `(n x k)` row-major quantized bytes + per-tensor scale —
    /// consumed directly by [`gemm_nt_i8`], never dequantized.
    I8 {
        /// Quantized weight bytes.
        q: Vec<i8>,
        /// Per-tensor dequantization scale (applied in the integer
        /// GEMM's epilogue).
        scale: f32,
    },
}

/// A pre-packed B-side panel for [`gemm_nt_prepacked`], built ONCE at
/// plan time instead of per GEMM call (DESIGN.md §Pass pipeline,
/// prepack pass).
///
/// Two payload forms (see [`PanelPayload`]):
///
/// * **bf16 → f32 image** — the same row-major `(n x k)` layout the
///   f32 `gemm_nt` consumes, NOT an interleaved tile layout, so the
///   prepacked product runs the identical [`dot`] calls in the
///   identical order as [`gemm_nt_deq`] over the same payload and the
///   bitwise-identity contract survives the pass.
/// * **i8 → raw quantized bytes** — stored 1 byte/element (~¼ the f32
///   image) and fed straight to the integer GEMM [`gemm_nt_i8`], which
///   is bit-identical to the unpacked int8 route because both run the
///   same exact integer dots over the same bytes.  The per-tensor
///   scale travels inside the payload and is applied intrinsically —
///   callers pass plain epilogues for BOTH payload forms.
pub struct PackedPanel {
    payload: PanelPayload,
    /// Output features (B rows).
    n: usize,
    /// Reduction depth (B cols).
    k: usize,
}

impl PackedPanel {
    /// Pack an `(n x k)` reduced-precision tensor into its f32 image
    /// (the bf16 panel form; values are final after conversion).
    pub fn pack<E: DequantElem>(b: &[E], n: usize, k: usize) -> PackedPanel {
        debug_assert_eq!(b.len(), n * k);
        PackedPanel { payload: PanelPayload::F32(b.iter().map(|e| e.to_f32()).collect()), n, k }
    }

    /// Pack an `(n x k)` int8 tensor as raw quantized bytes + scale
    /// (the true-integer panel form).
    pub fn pack_i8(q: &[i8], n: usize, k: usize, scale: f32) -> PackedPanel {
        debug_assert_eq!(q.len(), n * k);
        PackedPanel { payload: PanelPayload::I8 { q: q.to_vec(), scale }, n, k }
    }

    /// Output features (B rows).
    pub fn rows(&self) -> usize {
        self.n
    }

    /// Reduction depth (B cols).
    pub fn cols(&self) -> usize {
        self.k
    }

    /// The int8 per-tensor scale carried by an i8 payload (`None` for
    /// f32-image panels, whose values are final).  Informational —
    /// [`gemm_nt_prepacked`] applies it intrinsically either way.
    pub fn scale(&self) -> Option<f32> {
        match &self.payload {
            PanelPayload::F32(_) => None,
            PanelPayload::I8 { scale, .. } => Some(*scale),
        }
    }

    /// The stored payload (bench/report introspection).
    pub fn payload(&self) -> &PanelPayload {
        &self.payload
    }

    /// Resident bytes of the packed payload (the prepack pass trades
    /// this memory for zero per-call conversion work; i8 panels keep
    /// 1 byte/element instead of a 4-byte f32 image).
    pub fn bytes(&self) -> usize {
        match &self.payload {
            PanelPayload::F32(data) => data.len() * std::mem::size_of::<f32>(),
            PanelPayload::I8 { q, .. } => q.len() + std::mem::size_of::<f32>(),
        }
    }
}

/// [`gemm_nt`] against a [`PackedPanel`]: C (m x n) = A (m x k) · Bᵀ
/// with B packed at plan time.  f32-image panels delegate to the f32
/// [`gemm_nt`] — same row partition, same [`dot`] order, so the result
/// is bit-identical to [`gemm_nt_deq`] over the original payload
/// (pinned below).  i8 panels delegate to the true-integer
/// [`gemm_nt_i8`] — bit-identical to the unpacked int8 route over the
/// same bytes.  Scales are applied intrinsically for both forms: pass
/// plain epilogues only.
pub fn gemm_nt_prepacked(a: &[f32], b: &PackedPanel, m: usize, out: &mut [f32], epi: Epilogue) {
    match &b.payload {
        PanelPayload::F32(data) => gemm_nt(a, data, m, b.k, b.n, out, epi),
        PanelPayload::I8 { q, scale } => gemm_nt_i8(a, q, m, b.k, b.n, *scale, out, epi),
    }
}

/// C (m x n) = Aᵀ · B with A stored (k x m) — no transpose materialized.
/// Then `epi`.  Overwrites `out`.
pub fn gemm_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], epi: Epilogue) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        let panel = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo * n), (hi - lo) * n) };
        panel.fill(0.0);
        // A is stored (k x m): the per-row coefficient stream strides
        // by m, so pack it — 4-row tiles interleaved for the
        // register-blocked microkernel (the pack itself reads the
        // contiguous 4-wide runs a[kk*m + i..i+4]), single rows
        // contiguous per depth.
        let mut apack = vec![0.0f32; 4 * KC];
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            let kc = k1 - k0;
            let bpanel = &b[k0 * n..k1 * n];
            let mut i = lo;
            while i + 4 <= hi {
                for kk in 0..kc {
                    for r in 0..4 {
                        apack[kk * 4 + r] = a[(k0 + kk) * m + i + r];
                    }
                }
                let out4 = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), 4 * n) };
                let (o0, rest) = out4.split_at_mut(n);
                let (o1, rest) = rest.split_at_mut(n);
                let (o2, o3) = rest.split_at_mut(n);
                simd::update4_panel(&apack[..kc * 4], bpanel, n, [o0, o1, o2, o3]);
                i += 4;
            }
            for ii in i..hi {
                for kk in 0..kc {
                    apack[kk] = a[(k0 + kk) * m + ii];
                }
                let out_row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(ii * n), n) };
                simd::update1_panel(&apack[..kc], bpanel, n, out_row);
            }
            k0 = k1;
        }
        for i in lo..hi {
            let row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            epi.apply(row);
        }
    });
}

/// out += A · B over raw slices (A: m x k, B: k x n, out: m x n) —
/// the allocation-free accumulating form the f_LR Eq. 18 contraction
/// loop needs.  Serial on purpose: its callers already sit inside a
/// row-blocked outer loop (see `wasi::lowrank_grad`); the row update
/// still runs on the 8-lane SIMD primitive.
pub fn gemm_nn_acc(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let out_row = &mut out[i * n..(i + 1) * n];
        simd::update1_panel(a_row, b, n, out_row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;
    use crate::linalg::simd::{set_force_scalar, SIMD_TEST_LOCK};
    use crate::precision::{f32_to_bf16, quantize_i8};
    use crate::util::threadpool::set_num_threads;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn transpose(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut t = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = a[r * cols + c];
            }
        }
        t
    }

    #[test]
    fn gemm_forms_match_naive() {
        let mut rng = Pcg64::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 9, 13), (70, 150, 33), (1, 7, 1)] {
            let a: Vec<f32> = rng.normal_vec(m * k);
            let b: Vec<f32> = rng.normal_vec(k * n);
            let want = naive(&a, &b, m, k, n);

            let mut c = vec![0.0f32; m * n];
            gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::None);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "nn {m}x{k}x{n}: {x} vs {y}");
            }

            let bt = transpose(&b, k, n); // (n, k)
            gemm_nt(&a, &bt, m, k, n, &mut c, Epilogue::None);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "nt {m}x{k}x{n}: {x} vs {y}");
            }

            let at = transpose(&a, m, k); // (k, m)
            gemm_tn(&at, &b, m, k, n, &mut c, Epilogue::None);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "tn {m}x{k}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn threaded_matches_single_thread_bitwise() {
        // The deterministic row partition: every output element is
        // accumulated by exactly one thread in ascending-k order, so
        // thread count must not change a single bit.
        let _guard = crate::util::threadpool::TEST_OVERRIDE_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(2);
        // Sizes straddle the 4-row blocking and the KC panel boundary,
        // and exceed the n >= 64 threading threshold.
        for (m, k, n) in [(97, 200, 65), (130, 129, 70), (68, 33, 90)] {
            let a: Vec<f32> = rng.normal_vec(m * k);
            let b: Vec<f32> = rng.normal_vec(k * n);
            let bt = transpose(&b, k, n);
            let at = transpose(&a, m, k);
            let mut single = vec![0.0f32; m * n];
            let mut multi = vec![0.0f32; m * n];
            for (form, name) in [(0usize, "nn"), (1, "nt"), (2, "tn")] {
                set_num_threads(1);
                match form {
                    0 => gemm_nn(&a, &b, m, k, n, &mut single, Epilogue::None),
                    1 => gemm_nt(&a, &bt, m, k, n, &mut single, Epilogue::None),
                    _ => gemm_tn(&at, &b, m, k, n, &mut single, Epilogue::None),
                }
                set_num_threads(7);
                match form {
                    0 => gemm_nn(&a, &b, m, k, n, &mut multi, Epilogue::None),
                    1 => gemm_nt(&a, &bt, m, k, n, &mut multi, Epilogue::None),
                    _ => gemm_tn(&at, &b, m, k, n, &mut multi, Epilogue::None),
                }
                set_num_threads(0);
                assert_eq!(single, multi, "{name} {m}x{k}x{n} diverged across thread counts");
            }
        }
    }

    #[test]
    fn simd_matches_forced_scalar_bitwise_at_odd_shapes() {
        // The SIMD dispatch contract: multiply-then-add with lanes
        // bound to ascending indices means the vectorized kernels must
        // reproduce the scalar backend BIT FOR BIT, including remainder
        // lanes (n % 8 != 0), remainder rows (m % 4 != 0), and k-panel
        // tails (k % KC != 0).  On hosts without SIMD this degenerates
        // to scalar-vs-scalar and still pins the packing rewrite.
        let _simd = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(9);
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 8, 9),
            (5, 129, 17),
            (13, 131, 33),
            (97, 150, 65),
        ];
        for (m, k, n) in shapes {
            let mut a: Vec<f32> = rng.normal_vec(m * k);
            a[(m * k) / 2] = 0.0; // exercise the exact-zero skip
            let b: Vec<f32> = rng.normal_vec(k * n);
            let bt = transpose(&b, k, n);
            let at = transpose(&a, m, k);
            let bias: Vec<f32> = rng.normal_vec(n);
            let mut scalar = vec![0.0f32; m * n];
            let mut vector = vec![0.0f32; m * n];
            let mut acc_scalar = vec![0.5f32; m * n];
            let mut acc_vector = vec![0.5f32; m * n];
            for (form, name) in [(0usize, "nn"), (1, "nt"), (2, "tn"), (3, "acc")] {
                set_force_scalar(true);
                match form {
                    0 => gemm_nn(&a, &b, m, k, n, &mut scalar, Epilogue::BiasGelu(&bias)),
                    1 => gemm_nt(&a, &bt, m, k, n, &mut scalar, Epilogue::Bias(&bias)),
                    2 => gemm_tn(&at, &b, m, k, n, &mut scalar, Epilogue::None),
                    _ => gemm_nn_acc(&a, m, k, &b, n, &mut acc_scalar),
                }
                set_force_scalar(false);
                match form {
                    0 => gemm_nn(&a, &b, m, k, n, &mut vector, Epilogue::BiasGelu(&bias)),
                    1 => gemm_nt(&a, &bt, m, k, n, &mut vector, Epilogue::Bias(&bias)),
                    2 => gemm_tn(&at, &b, m, k, n, &mut vector, Epilogue::None),
                    _ => gemm_nn_acc(&a, m, k, &b, n, &mut acc_vector),
                }
                let (s, v) = if form == 3 {
                    (&acc_scalar, &acc_vector)
                } else {
                    (&scalar, &vector)
                };
                assert_eq!(
                    s.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{name} {m}x{k}x{n}: SIMD diverged from scalar"
                );
            }
        }
    }

    #[test]
    fn epilogues_fuse_bias_and_gelu() {
        let mut rng = Pcg64::new(3);
        let (m, k, n) = (9, 11, 67);
        let a: Vec<f32> = rng.normal_vec(m * k);
        let b: Vec<f32> = rng.normal_vec(k * n);
        let bias: Vec<f32> = rng.normal_vec(n);
        let plain = naive(&a, &b, m, k, n);

        let mut c = vec![0.0f32; m * n];
        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::Bias(&bias));
        for (i, x) in c.iter().enumerate() {
            let want = plain[i] + bias[i % n];
            assert!((x - want).abs() < 1e-3, "bias: {x} vs {want}");
        }

        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::BiasGelu(&bias));
        for (i, x) in c.iter().enumerate() {
            let want = gelu(plain[i] + bias[i % n]);
            assert!((x - want).abs() < 1e-3, "bias+gelu: {x} vs {want}");
        }

        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::Gelu);
        for (i, x) in c.iter().enumerate() {
            let want = gelu(plain[i]);
            assert!((x - want).abs() < 1e-3, "gelu: {x} vs {want}");
        }
    }

    #[test]
    fn scale_epilogues_dequantize() {
        let mut rng = Pcg64::new(8);
        let (m, k, n) = (7, 13, 19);
        let a: Vec<f32> = rng.normal_vec(m * k);
        let b: Vec<f32> = rng.normal_vec(k * n);
        let bias: Vec<f32> = rng.normal_vec(n);
        let plain = naive(&a, &b, m, k, n);
        let s = 0.037f32;

        let mut c = vec![0.0f32; m * n];
        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::Scale(s));
        for (i, x) in c.iter().enumerate() {
            assert!((x - plain[i] * s).abs() < 1e-4, "scale: {x}");
        }
        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::ScaleBias(s, &bias));
        for (i, x) in c.iter().enumerate() {
            let want = plain[i] * s + bias[i % n];
            assert!((x - want).abs() < 1e-4, "scale+bias: {x} vs {want}");
        }
        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::ScaleBiasGelu(s, &bias));
        for (i, x) in c.iter().enumerate() {
            let want = gelu(plain[i] * s + bias[i % n]);
            assert!((x - want).abs() < 1e-4, "scale+bias+gelu: {x} vs {want}");
        }
    }

    #[test]
    fn dequantizing_gemm_matches_dequantized_f32_gemm() {
        let mut rng = Pcg64::new(10);
        let (m, k, n) = (6, 37, 11); // odd k: remainder lanes in the dot
        let a: Vec<f32> = rng.normal_vec(m * k);
        let w: Vec<f32> = rng.normal_vec(n * k); // (n, k) for the nt form
        let bias: Vec<f32> = rng.normal_vec(n);

        // bf16: gemm_nt_deq over raw bits must be BIT-identical to
        // gemm_nt over the rounded f32 tensor (same operation order).
        let wq16: Vec<u16> = w.iter().map(|&v| f32_to_bf16(v)).collect();
        let wr: Vec<f32> = wq16.iter().map(|&b| crate::precision::bf16_to_f32(b)).collect();
        let mut c16 = vec![0.0f32; m * n];
        let mut cref = vec![0.0f32; m * n];
        gemm_nt_deq(&a, &wq16, m, k, n, &mut c16, Epilogue::Bias(&bias));
        gemm_nt(&a, &wr, m, k, n, &mut cref, Epilogue::Bias(&bias));
        assert_eq!(
            c16.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            cref.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "bf16 dequantizing GEMM must match the rounded-f32 GEMM bitwise"
        );

        // i8: raw accumulation x·qᵀ scaled in the epilogue must match
        // the explicitly dequantized f32 GEMM closely (same math, the
        // scale applied per-element vs per-sum differs only in
        // rounding).
        let (q, scale) = quantize_i8(&w);
        let wdeq: Vec<f32> = q.iter().map(|&v| v as f32 * scale).collect();
        let mut c8 = vec![0.0f32; m * n];
        gemm_nt_deq(&a, &q, m, k, n, &mut c8, Epilogue::ScaleBias(scale, &bias));
        gemm_nt(&a, &wdeq, m, k, n, &mut cref, Epilogue::Bias(&bias));
        for (x, y) in c8.iter().zip(&cref) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "i8: {x} vs {y}");
        }
    }

    #[test]
    fn prepacked_gemm_matches_dequantizing_gemm_bitwise() {
        // The prepack pass contract: packing once at plan time must
        // not change a single output bit vs converting per call.
        let mut rng = Pcg64::new(11);
        let (m, k, n) = (5, 37, 13);
        let a: Vec<f32> = rng.normal_vec(m * k);
        let w: Vec<f32> = rng.normal_vec(n * k);
        let bias: Vec<f32> = rng.normal_vec(n);

        let wq16: Vec<u16> = w.iter().map(|&v| f32_to_bf16(v)).collect();
        let panel16 = PackedPanel::pack(&wq16, n, k);
        assert_eq!((panel16.rows(), panel16.cols()), (n, k));
        assert_eq!(panel16.bytes(), n * k * 4);
        assert_eq!(panel16.scale(), None);
        let mut c_pre = vec![0.0f32; m * n];
        let mut c_deq = vec![0.0f32; m * n];
        gemm_nt_prepacked(&a, &panel16, m, &mut c_pre, Epilogue::Bias(&bias));
        gemm_nt_deq(&a, &wq16, m, k, n, &mut c_deq, Epilogue::Bias(&bias));
        assert_eq!(
            c_pre.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            c_deq.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "bf16 prepacked GEMM diverged from the dequantizing GEMM"
        );

        // i8 panels store RAW quantized bytes and route to the
        // true-integer GEMM: bit-identical to the unpacked integer
        // route over the same bytes, and a quarter of the f32 image.
        let (q, scale) = quantize_i8(&w);
        let panel8 = PackedPanel::pack_i8(&q, n, k, scale);
        assert_eq!(panel8.scale(), Some(scale));
        assert_eq!(panel8.bytes(), n * k + 4);
        gemm_nt_prepacked(&a, &panel8, m, &mut c_pre, Epilogue::Bias(&bias));
        gemm_nt_i8(&a, &q, m, k, n, scale, &mut c_deq, Epilogue::Bias(&bias));
        assert_eq!(
            c_pre.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            c_deq.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "i8 prepacked GEMM diverged from the unpacked integer GEMM"
        );
    }

    #[test]
    fn i8_dot_max_k_is_the_exact_i32_bound() {
        // k products of ±127² must sum exactly in i32 …
        assert!(I8_DOT_MAX_K * 127 * 127 <= i32::MAX as usize);
        // … and the bound is tight (one more product can overflow).
        assert!((I8_DOT_MAX_K + 1) * 127 * 127 > i32::MAX as usize);
        // Model dims sit far below it.
        assert!(I8_DOT_MAX_K > 100_000);
    }

    #[test]
    fn f32_gemm_nt_batch_matches_solo_rows_bitwise() {
        // The dot4 microtile must not perturb per-row results: a
        // coalesced batch (m = 8, and a remainder shape m = 6) is
        // bitwise the concatenation of solo m = 1 calls — the kernel
        // half of the serving layer's batched-vs-solo equality pin.
        let mut rng = Pcg64::new(31);
        for (m, k, n) in [(8usize, 37usize, 13usize), (6, 64, 9), (5, 17, 33)] {
            let a: Vec<f32> = rng.normal_vec(m * k);
            let w: Vec<f32> = rng.normal_vec(n * k);
            let bias: Vec<f32> = rng.normal_vec(n);
            let mut batched = vec![0.0f32; m * n];
            gemm_nt(&a, &w, m, k, n, &mut batched, Epilogue::BiasGelu(&bias));
            let mut solo = vec![0.0f32; n];
            for i in 0..m {
                gemm_nt(&a[i * k..(i + 1) * k], &w, 1, k, n, &mut solo, Epilogue::BiasGelu(&bias));
                assert_eq!(
                    batched[i * n..(i + 1) * n].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    solo.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{m}x{k}x{n} row {i} diverged between batched and solo"
                );
            }
        }
    }

    #[test]
    fn integer_gemm_batch_matches_solo_rows_bitwise() {
        // Integer accumulation is exact and activation scales are
        // per-row, so batching cannot change a bit either.
        let mut rng = Pcg64::new(32);
        for (m, k, n) in [(8usize, 37usize, 13usize), (6, 64, 9), (3, 17, 7)] {
            let a: Vec<f32> = rng.normal_vec(m * k);
            let w: Vec<f32> = rng.normal_vec(n * k);
            let bias: Vec<f32> = rng.normal_vec(n);
            let (q, scale) = quantize_i8(&w);
            let mut batched = vec![0.0f32; m * n];
            gemm_nt_i8(&a, &q, m, k, n, scale, &mut batched, Epilogue::Bias(&bias));
            let mut solo = vec![0.0f32; n];
            for i in 0..m {
                gemm_nt_i8(
                    &a[i * k..(i + 1) * k],
                    &q,
                    1,
                    k,
                    n,
                    scale,
                    &mut solo,
                    Epilogue::Bias(&bias),
                );
                assert_eq!(
                    batched[i * n..(i + 1) * n].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    solo.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{m}x{k}x{n} row {i} diverged between batched and solo"
                );
            }
        }
    }

    #[test]
    fn integer_gemm_is_backend_and_thread_invariant_bitwise() {
        // The true-int8 parity pin: exact i32 accumulation makes
        // scalar vs SIMD AND 1 vs 7 threads bit-identical, including
        // k-tail remainder lanes (k % 32 != 0), odd m/n, and the 4-row
        // microtile remainder.
        let _simd = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let _threads = crate::util::threadpool::TEST_OVERRIDE_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(33);
        for (m, k, n) in [(1usize, 1usize, 1usize), (3, 17, 7), (5, 33, 13), (13, 100, 65)] {
            let a: Vec<f32> = rng.normal_vec(m * k);
            let w: Vec<f32> = rng.normal_vec(n * k);
            let bias: Vec<f32> = rng.normal_vec(n);
            let (q, scale) = quantize_i8(&w);
            let mut want = vec![0.0f32; m * n];
            set_force_scalar(true);
            set_num_threads(1);
            gemm_nt_i8(&a, &q, m, k, n, scale, &mut want, Epilogue::BiasGelu(&bias));
            let mut got = vec![0.0f32; m * n];
            for (forced, threads) in [(false, 1usize), (true, 7), (false, 7)] {
                set_force_scalar(forced);
                set_num_threads(threads);
                gemm_nt_i8(&a, &q, m, k, n, scale, &mut got, Epilogue::BiasGelu(&bias));
                assert_eq!(
                    got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{m}x{k}x{n} forced={forced} threads={threads} diverged"
                );
            }
            set_force_scalar(false);
            set_num_threads(0);
        }
    }

    #[test]
    fn integer_gemm_tracks_dequantizing_gemm_within_activation_bound() {
        // vs the old dequantizing route the ONLY new error is the
        // activation round-trip: per output element
        //   |c_int - c_deq| <= (s_row/2) · 127 · k · wscale
        // (|x - q·s| <= s/2 per activation, against weight magnitudes
        // <= 127·wscale, summed over k).  Documented in DESIGN.md
        // §Kernels; this test is the documentation's enforcement.
        let mut rng = Pcg64::new(34);
        for (m, k, n) in [(5usize, 37usize, 13usize), (8, 100, 9), (1, 7, 3)] {
            let a: Vec<f32> = rng.normal_vec(m * k);
            let w: Vec<f32> = rng.normal_vec(n * k);
            let bias: Vec<f32> = rng.normal_vec(n);
            let (q, wscale) = quantize_i8(&w);
            let (_, ascales) = crate::precision::quantize_i8_rows(&a, m, k);
            let mut c_int = vec![0.0f32; m * n];
            let mut c_deq = vec![0.0f32; m * n];
            gemm_nt_i8(&a, &q, m, k, n, wscale, &mut c_int, Epilogue::Bias(&bias));
            gemm_nt_deq(&a, &q, m, k, n, &mut c_deq, Epilogue::ScaleBias(wscale, &bias));
            for i in 0..m {
                let bound = (ascales[i] / 2.0) * 127.0 * k as f32 * wscale * 1.01 + 1e-4;
                for j in 0..n {
                    let (x, y) = (c_int[i * n + j], c_deq[i * n + j]);
                    assert!(
                        (x - y).abs() <= bound,
                        "{m}x{k}x{n} [{i},{j}]: |{x} - {y}| exceeds the activation bound {bound}"
                    );
                }
            }
        }
    }

    #[test]
    fn acc_accumulates() {
        let mut rng = Pcg64::new(4);
        let (m, k, n) = (6, 5, 4);
        let a: Vec<f32> = rng.normal_vec(m * k);
        let b: Vec<f32> = rng.normal_vec(k * n);
        let mut out = vec![1.0f32; m * n];
        gemm_nn_acc(&a, m, k, &b, n, &mut out);
        let want = naive(&a, &b, m, k, n);
        for (x, y) in out.iter().zip(&want) {
            assert!((x - (y + 1.0)).abs() < 1e-4, "{x} vs {}", y + 1.0);
        }
    }

    #[test]
    fn gelu_grad_matches_fd() {
        for x in [-2.5f32, -0.7, 0.0, 0.3, 1.9] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((fd - gelu_grad(x)).abs() < 1e-2, "x={x}: {fd} vs {}", gelu_grad(x));
        }
    }
}

//! The shared GEMM kernel layer — the ONE optimization site every
//! matmul in the crate routes through (DESIGN.md §Kernels): `Mat`'s
//! operator methods, the `wasi::{layer, wsi, lowrank_grad}` math, the
//! baselines, and the engine graph executor all end up in `gemm_nn` /
//! `gemm_nt` / `gemm_tn` below.
//!
//! Design (DESIGN.md §Kernels, EXPERIMENTS.md §Perf):
//!
//! * **Row-sliced threading** — output rows are split into disjoint
//!   contiguous ranges across `util::threadpool::parallel_ranges`
//!   workers.  Each output element is accumulated by exactly one thread
//!   in ascending-k order, so results are **bit-identical for every
//!   thread count** (pinned by `tests` below and the engine-parity
//!   suite) — `--threads` trades wall-clock only.
//! * **SIMD microkernels** — the inner loops run on the runtime-
//!   dispatched 8-lane primitives in [`super::simd`] (AVX on x86_64,
//!   NEON on aarch64, a scalar 8-lane fallback everywhere else).  The
//!   primitives use multiply-then-add (never FMA) with lanes bound to
//!   ascending element indices, so **scalar and SIMD results are
//!   bit-identical** too (pinned by the parity tests below at shapes
//!   with remainder lanes).
//! * **Packed panels** — `gemm_nn`/`gemm_tn` walk k in `KC`-wide panels
//!   and pack the active A tile into a contiguous register-blocked
//!   layout (`apack[kk*4 + r]`), so the microkernel streams one
//!   contiguous A stream and one contiguous B panel (`b[k0*n..k1*n]`
//!   is already contiguous row-major — B needs no copy) instead of
//!   striding across the source matrix per coefficient.
//! * **Register blocking** — the packed microkernel feeds each
//!   streamed B row into FOUR output rows (4x fewer B loads, four
//!   independent accumulator chains); `gemm_nt` uses the 8-lane
//!   [`dot`].
//! * **Fused epilogues** — bias add, GELU, and the reduced-precision
//!   dequantization run inside the parallel region while the output
//!   panel is still hot ([`Epilogue`]), instead of a second full sweep
//!   from memory after the join.
//! * **Dequantizing GEMM** — [`gemm_nt_deq`] is `gemm_nt` over int8 or
//!   bf16 weight payloads (`crate::precision`): weight rows dequantize
//!   block-wise into a per-thread f32 panel (each element converts once
//!   per thread, not once per output row), the dots run on the same
//!   SIMD [`dot`] as the f32 path, and the int8 per-tensor scale folds
//!   into the epilogue ([`Epilogue::ScaleBias`]).

use crate::util::threadpool::parallel_ranges;

use super::simd;

/// k-panel width for cache blocking (a KC x n B-panel of f32 at the
/// model dims this crate runs stays within L2 alongside the output
/// rows).
const KC: usize = 128;

pub const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
pub const GELU_A: f32 = 0.044_715;

/// tanh-approximation GELU (matches `python/compile/model.py`).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_A * x * x * x)).tanh())
}

/// d/dx of [`gelu`].
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    let inner = GELU_C * (x + GELU_A * x * x * x);
    let t = inner.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * x * x)
}

/// 8-lane dot product on the runtime-dispatched SIMD backend
/// (bit-identical across backends; see `linalg::simd`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot(a, b)
}

/// A weight element the dequantizing GEMM can convert to f32 in its
/// inner loop: int8 payloads (per-tensor scale applied by the
/// epilogue) and raw bf16 bits (exact conversion).
pub trait DequantElem: Copy + Send + Sync {
    fn to_f32(self) -> f32;
}

impl DequantElem for i8 {
    #[inline(always)]
    fn to_f32(self) -> f32 {
        self as f32
    }
}

/// bf16 bits (see `crate::precision::bf16_to_f32`).
impl DequantElem for u16 {
    #[inline(always)]
    fn to_f32(self) -> f32 {
        crate::precision::bf16_to_f32(self)
    }
}

/// Epilogue fused into the GEMM's parallel region, applied per output
/// row while the row is cache-hot.
#[derive(Clone, Copy)]
pub enum Epilogue<'a> {
    /// Plain C = A·B.
    None,
    /// C = A·B + bias (bias broadcast over rows; `bias.len() == n`).
    Bias(&'a [f32]),
    /// C = gelu(A·B + bias) — the inference fc1 fusion.
    BiasGelu(&'a [f32]),
    /// C = gelu(A·B).
    Gelu,
    /// C = s·(A·B) — int8 dequantization without a bias (the factored
    /// rank-space product).
    Scale(f32),
    /// C = s·(A·B) + bias — the int8 dequantizing epilogue.
    ScaleBias(f32, &'a [f32]),
    /// C = gelu(s·(A·B) + bias) — dequantize + fc1 fusion in one pass.
    ScaleBiasGelu(f32, &'a [f32]),
}

impl Epilogue<'_> {
    #[inline]
    fn apply(&self, row: &mut [f32]) {
        match self {
            Epilogue::None => {}
            Epilogue::Bias(bias) => {
                for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                    *o += bv;
                }
            }
            Epilogue::BiasGelu(bias) => {
                for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                    *o = gelu(*o + bv);
                }
            }
            Epilogue::Gelu => {
                for o in row.iter_mut() {
                    *o = gelu(*o);
                }
            }
            Epilogue::Scale(s) => {
                for o in row.iter_mut() {
                    *o *= s;
                }
            }
            Epilogue::ScaleBias(s, bias) => {
                for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                    *o = *o * s + bv;
                }
            }
            Epilogue::ScaleBiasGelu(s, bias) => {
                for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                    *o = gelu(*o * s + bv);
                }
            }
        }
    }
}

/// Shareable raw pointer for scoped-thread row writes (each thread owns
/// a disjoint row range, so no aliasing).
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// C (m x n) = A (m x k) · B (k x n), then `epi`.  Overwrites `out`.
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], epi: Epilogue) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        let panel = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo * n), (hi - lo) * n) };
        panel.fill(0.0);
        // Packed A tile, reused across k-panels (4 rows x KC depths,
        // interleaved so the microkernel reads one contiguous stream).
        let mut apack = vec![0.0f32; 4 * KC];
        // k-panel loop OUTSIDE the row loop: the KC x n slab of B stays
        // cache-resident across this thread's whole row range.  Each
        // output element still accumulates in ascending-k order, so the
        // result is independent of KC, the thread partition, and the
        // SIMD backend.
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            let kc = k1 - k0;
            let bpanel = &b[k0 * n..k1 * n];
            let mut i = lo;
            while i + 4 <= hi {
                // Pack row-by-row: each source row is read contiguously,
                // the tile interleaves as apack[kk*4 + r].
                for r in 0..4 {
                    let a_row = &a[(i + r) * k + k0..(i + r) * k + k1];
                    for (kk, &v) in a_row.iter().enumerate() {
                        apack[kk * 4 + r] = v;
                    }
                }
                let out4 = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), 4 * n) };
                let (o0, rest) = out4.split_at_mut(n);
                let (o1, rest) = rest.split_at_mut(n);
                let (o2, o3) = rest.split_at_mut(n);
                simd::update4_panel(&apack[..kc * 4], bpanel, n, [o0, o1, o2, o3]);
                i += 4;
            }
            // remainder rows: the A panel is already contiguous per row.
            for ii in i..hi {
                let out_row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(ii * n), n) };
                simd::update1_panel(&a[ii * k + k0..ii * k + k1], bpanel, n, out_row);
            }
            k0 = k1;
        }
        for i in lo..hi {
            let row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            epi.apply(row);
        }
    });
}

/// C (m x n) = A (m x k) · Bᵀ with B stored (n x k) — dot-product form,
/// no transpose materialized.  Then `epi`.  Overwrites `out`.
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], epi: Epilogue) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        for i in lo..hi {
            let out_row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            let a_row = &a[i * k..(i + 1) * k];
            for (j, o) in out_row.iter_mut().enumerate() {
                let b_row = &b[j * k..(j + 1) * k];
                *o = dot(a_row, b_row);
            }
            epi.apply(out_row);
        }
    });
}

/// Column-block width for the dequantizing GEMM: JB weight rows are
/// converted to f32 once per thread and reused across the thread's
/// whole row range, so each weight element converts `threads` times
/// per call instead of `m` times, and the inner dot runs on the SIMD
/// backend.
const JB: usize = 8;

/// [`gemm_nt`] against a reduced-precision B (int8 payloads or bf16
/// bits): C (m x n) = A (m x k) · Bᵀ with B stored (n x k).  Weight
/// rows dequantize block-wise into a per-thread f32 panel and the dot
/// products run on the same SIMD [`dot`] as the f32 path, so results
/// are bit-identical to `gemm_nt` over the dequantized tensor; the
/// int8 per-tensor scale belongs in `epi` ([`Epilogue::Scale`] forms).
/// The row partition matches `gemm_nt` exactly.
pub fn gemm_nt_deq<E: DequantElem>(
    a: &[f32],
    b: &[E],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    epi: Epilogue,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        let mut bconv = vec![0.0f32; JB * k];
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + JB).min(n);
            for (jj, j) in (j0..j1).enumerate() {
                let b_row = &b[j * k..(j + 1) * k];
                for (dst, &e) in bconv[jj * k..(jj + 1) * k].iter_mut().zip(b_row) {
                    *dst = e.to_f32();
                }
            }
            for i in lo..hi {
                let out_row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
                let a_row = &a[i * k..(i + 1) * k];
                for (jj, j) in (j0..j1).enumerate() {
                    out_row[j] = dot(a_row, &bconv[jj * k..(jj + 1) * k]);
                }
            }
            j0 = j1;
        }
        for i in lo..hi {
            let row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            epi.apply(row);
        }
    });
}

/// A pre-packed B-side panel for [`gemm_nt_prepacked`]: the dequantized
/// f32 image of an `(n x k)` reduced-precision weight tensor, packed
/// ONCE at plan time instead of per GEMM call (DESIGN.md §Pass
/// pipeline, prepack pass).
///
/// The layout is deliberately the same row-major `(n x k)` the f32
/// `gemm_nt` consumes — NOT the interleaved `apack` tile layout — so
/// the prepacked product runs the identical [`dot`] calls in the
/// identical order as [`gemm_nt_deq`] over the same payload, and the
/// bitwise-identity contract of the kernel layer survives the pass.
/// (An interleaved B layout would reorder the accumulation and is
/// exactly the renegotiation ROADMAP item 3's true-int8 microkernels
/// will make; this panel is its staging format.)  Int8 payloads pack
/// as RAW quantized magnitudes with the per-tensor scale carried
/// alongside for the epilogue, matching the deq path's `Scale` forms.
pub struct PackedPanel {
    /// Dequantized `(n x k)` row-major f32 image.
    data: Vec<f32>,
    /// Output features (B rows).
    n: usize,
    /// Reduction depth (B cols).
    k: usize,
    /// Int8 per-tensor scale to fold into the epilogue (`None` for
    /// payloads whose values are already final, e.g. bf16).
    scale: Option<f32>,
}

impl PackedPanel {
    /// Pack an `(n x k)` reduced-precision tensor into its f32 image.
    pub fn pack<E: DequantElem>(b: &[E], n: usize, k: usize, scale: Option<f32>) -> PackedPanel {
        debug_assert_eq!(b.len(), n * k);
        PackedPanel { data: b.iter().map(|e| e.to_f32()).collect(), n, k, scale }
    }

    /// Output features (B rows).
    pub fn rows(&self) -> usize {
        self.n
    }

    /// Reduction depth (B cols).
    pub fn cols(&self) -> usize {
        self.k
    }

    /// The int8 per-tensor scale the caller must fold into the
    /// epilogue (`None`: values are final).
    pub fn scale(&self) -> Option<f32> {
        self.scale
    }

    /// Resident bytes of the packed image (the prepack pass trades
    /// this memory for zero per-call conversion work).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// [`gemm_nt`] against a [`PackedPanel`]: C (m x n) = A (m x k) · Bᵀ
/// with B pre-dequantized at plan time.  Delegates to the f32
/// [`gemm_nt`] over the panel's image — same row partition, same
/// [`dot`] order — so the result is bit-identical to [`gemm_nt_deq`]
/// over the original payload (pinned below).  As with the deq path,
/// an int8 panel's `scale()` belongs in `epi`.
pub fn gemm_nt_prepacked(a: &[f32], b: &PackedPanel, m: usize, out: &mut [f32], epi: Epilogue) {
    gemm_nt(a, &b.data, m, b.k, b.n, out, epi);
}

/// C (m x n) = Aᵀ · B with A stored (k x m) — no transpose materialized.
/// Then `epi`.  Overwrites `out`.
pub fn gemm_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], epi: Epilogue) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        let panel = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo * n), (hi - lo) * n) };
        panel.fill(0.0);
        // A is stored (k x m): the per-row coefficient stream strides
        // by m, so pack it — 4-row tiles interleaved for the
        // register-blocked microkernel (the pack itself reads the
        // contiguous 4-wide runs a[kk*m + i..i+4]), single rows
        // contiguous per depth.
        let mut apack = vec![0.0f32; 4 * KC];
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            let kc = k1 - k0;
            let bpanel = &b[k0 * n..k1 * n];
            let mut i = lo;
            while i + 4 <= hi {
                for kk in 0..kc {
                    for r in 0..4 {
                        apack[kk * 4 + r] = a[(k0 + kk) * m + i + r];
                    }
                }
                let out4 = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), 4 * n) };
                let (o0, rest) = out4.split_at_mut(n);
                let (o1, rest) = rest.split_at_mut(n);
                let (o2, o3) = rest.split_at_mut(n);
                simd::update4_panel(&apack[..kc * 4], bpanel, n, [o0, o1, o2, o3]);
                i += 4;
            }
            for ii in i..hi {
                for kk in 0..kc {
                    apack[kk] = a[(k0 + kk) * m + ii];
                }
                let out_row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(ii * n), n) };
                simd::update1_panel(&apack[..kc], bpanel, n, out_row);
            }
            k0 = k1;
        }
        for i in lo..hi {
            let row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            epi.apply(row);
        }
    });
}

/// out += A · B over raw slices (A: m x k, B: k x n, out: m x n) —
/// the allocation-free accumulating form the f_LR Eq. 18 contraction
/// loop needs.  Serial on purpose: its callers already sit inside a
/// row-blocked outer loop (see `wasi::lowrank_grad`); the row update
/// still runs on the 8-lane SIMD primitive.
pub fn gemm_nn_acc(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let out_row = &mut out[i * n..(i + 1) * n];
        simd::update1_panel(a_row, b, n, out_row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;
    use crate::linalg::simd::{set_force_scalar, SIMD_TEST_LOCK};
    use crate::precision::{f32_to_bf16, quantize_i8};
    use crate::util::threadpool::set_num_threads;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn transpose(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut t = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = a[r * cols + c];
            }
        }
        t
    }

    #[test]
    fn gemm_forms_match_naive() {
        let mut rng = Pcg64::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 9, 13), (70, 150, 33), (1, 7, 1)] {
            let a: Vec<f32> = rng.normal_vec(m * k);
            let b: Vec<f32> = rng.normal_vec(k * n);
            let want = naive(&a, &b, m, k, n);

            let mut c = vec![0.0f32; m * n];
            gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::None);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "nn {m}x{k}x{n}: {x} vs {y}");
            }

            let bt = transpose(&b, k, n); // (n, k)
            gemm_nt(&a, &bt, m, k, n, &mut c, Epilogue::None);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "nt {m}x{k}x{n}: {x} vs {y}");
            }

            let at = transpose(&a, m, k); // (k, m)
            gemm_tn(&at, &b, m, k, n, &mut c, Epilogue::None);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "tn {m}x{k}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn threaded_matches_single_thread_bitwise() {
        // The deterministic row partition: every output element is
        // accumulated by exactly one thread in ascending-k order, so
        // thread count must not change a single bit.
        let _guard = crate::util::threadpool::TEST_OVERRIDE_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(2);
        // Sizes straddle the 4-row blocking and the KC panel boundary,
        // and exceed the n >= 64 threading threshold.
        for (m, k, n) in [(97, 200, 65), (130, 129, 70), (68, 33, 90)] {
            let a: Vec<f32> = rng.normal_vec(m * k);
            let b: Vec<f32> = rng.normal_vec(k * n);
            let bt = transpose(&b, k, n);
            let at = transpose(&a, m, k);
            let mut single = vec![0.0f32; m * n];
            let mut multi = vec![0.0f32; m * n];
            for (form, name) in [(0usize, "nn"), (1, "nt"), (2, "tn")] {
                set_num_threads(1);
                match form {
                    0 => gemm_nn(&a, &b, m, k, n, &mut single, Epilogue::None),
                    1 => gemm_nt(&a, &bt, m, k, n, &mut single, Epilogue::None),
                    _ => gemm_tn(&at, &b, m, k, n, &mut single, Epilogue::None),
                }
                set_num_threads(7);
                match form {
                    0 => gemm_nn(&a, &b, m, k, n, &mut multi, Epilogue::None),
                    1 => gemm_nt(&a, &bt, m, k, n, &mut multi, Epilogue::None),
                    _ => gemm_tn(&at, &b, m, k, n, &mut multi, Epilogue::None),
                }
                set_num_threads(0);
                assert_eq!(single, multi, "{name} {m}x{k}x{n} diverged across thread counts");
            }
        }
    }

    #[test]
    fn simd_matches_forced_scalar_bitwise_at_odd_shapes() {
        // The SIMD dispatch contract: multiply-then-add with lanes
        // bound to ascending indices means the vectorized kernels must
        // reproduce the scalar backend BIT FOR BIT, including remainder
        // lanes (n % 8 != 0), remainder rows (m % 4 != 0), and k-panel
        // tails (k % KC != 0).  On hosts without SIMD this degenerates
        // to scalar-vs-scalar and still pins the packing rewrite.
        let _simd = SIMD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(9);
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 8, 9),
            (5, 129, 17),
            (13, 131, 33),
            (97, 150, 65),
        ];
        for (m, k, n) in shapes {
            let mut a: Vec<f32> = rng.normal_vec(m * k);
            a[(m * k) / 2] = 0.0; // exercise the exact-zero skip
            let b: Vec<f32> = rng.normal_vec(k * n);
            let bt = transpose(&b, k, n);
            let at = transpose(&a, m, k);
            let bias: Vec<f32> = rng.normal_vec(n);
            let mut scalar = vec![0.0f32; m * n];
            let mut vector = vec![0.0f32; m * n];
            let mut acc_scalar = vec![0.5f32; m * n];
            let mut acc_vector = vec![0.5f32; m * n];
            for (form, name) in [(0usize, "nn"), (1, "nt"), (2, "tn"), (3, "acc")] {
                set_force_scalar(true);
                match form {
                    0 => gemm_nn(&a, &b, m, k, n, &mut scalar, Epilogue::BiasGelu(&bias)),
                    1 => gemm_nt(&a, &bt, m, k, n, &mut scalar, Epilogue::Bias(&bias)),
                    2 => gemm_tn(&at, &b, m, k, n, &mut scalar, Epilogue::None),
                    _ => gemm_nn_acc(&a, m, k, &b, n, &mut acc_scalar),
                }
                set_force_scalar(false);
                match form {
                    0 => gemm_nn(&a, &b, m, k, n, &mut vector, Epilogue::BiasGelu(&bias)),
                    1 => gemm_nt(&a, &bt, m, k, n, &mut vector, Epilogue::Bias(&bias)),
                    2 => gemm_tn(&at, &b, m, k, n, &mut vector, Epilogue::None),
                    _ => gemm_nn_acc(&a, m, k, &b, n, &mut acc_vector),
                }
                let (s, v) = if form == 3 {
                    (&acc_scalar, &acc_vector)
                } else {
                    (&scalar, &vector)
                };
                assert_eq!(
                    s.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{name} {m}x{k}x{n}: SIMD diverged from scalar"
                );
            }
        }
    }

    #[test]
    fn epilogues_fuse_bias_and_gelu() {
        let mut rng = Pcg64::new(3);
        let (m, k, n) = (9, 11, 67);
        let a: Vec<f32> = rng.normal_vec(m * k);
        let b: Vec<f32> = rng.normal_vec(k * n);
        let bias: Vec<f32> = rng.normal_vec(n);
        let plain = naive(&a, &b, m, k, n);

        let mut c = vec![0.0f32; m * n];
        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::Bias(&bias));
        for (i, x) in c.iter().enumerate() {
            let want = plain[i] + bias[i % n];
            assert!((x - want).abs() < 1e-3, "bias: {x} vs {want}");
        }

        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::BiasGelu(&bias));
        for (i, x) in c.iter().enumerate() {
            let want = gelu(plain[i] + bias[i % n]);
            assert!((x - want).abs() < 1e-3, "bias+gelu: {x} vs {want}");
        }

        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::Gelu);
        for (i, x) in c.iter().enumerate() {
            let want = gelu(plain[i]);
            assert!((x - want).abs() < 1e-3, "gelu: {x} vs {want}");
        }
    }

    #[test]
    fn scale_epilogues_dequantize() {
        let mut rng = Pcg64::new(8);
        let (m, k, n) = (7, 13, 19);
        let a: Vec<f32> = rng.normal_vec(m * k);
        let b: Vec<f32> = rng.normal_vec(k * n);
        let bias: Vec<f32> = rng.normal_vec(n);
        let plain = naive(&a, &b, m, k, n);
        let s = 0.037f32;

        let mut c = vec![0.0f32; m * n];
        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::Scale(s));
        for (i, x) in c.iter().enumerate() {
            assert!((x - plain[i] * s).abs() < 1e-4, "scale: {x}");
        }
        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::ScaleBias(s, &bias));
        for (i, x) in c.iter().enumerate() {
            let want = plain[i] * s + bias[i % n];
            assert!((x - want).abs() < 1e-4, "scale+bias: {x} vs {want}");
        }
        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::ScaleBiasGelu(s, &bias));
        for (i, x) in c.iter().enumerate() {
            let want = gelu(plain[i] * s + bias[i % n]);
            assert!((x - want).abs() < 1e-4, "scale+bias+gelu: {x} vs {want}");
        }
    }

    #[test]
    fn dequantizing_gemm_matches_dequantized_f32_gemm() {
        let mut rng = Pcg64::new(10);
        let (m, k, n) = (6, 37, 11); // odd k: remainder lanes in the dot
        let a: Vec<f32> = rng.normal_vec(m * k);
        let w: Vec<f32> = rng.normal_vec(n * k); // (n, k) for the nt form
        let bias: Vec<f32> = rng.normal_vec(n);

        // bf16: gemm_nt_deq over raw bits must be BIT-identical to
        // gemm_nt over the rounded f32 tensor (same operation order).
        let wq16: Vec<u16> = w.iter().map(|&v| f32_to_bf16(v)).collect();
        let wr: Vec<f32> = wq16.iter().map(|&b| crate::precision::bf16_to_f32(b)).collect();
        let mut c16 = vec![0.0f32; m * n];
        let mut cref = vec![0.0f32; m * n];
        gemm_nt_deq(&a, &wq16, m, k, n, &mut c16, Epilogue::Bias(&bias));
        gemm_nt(&a, &wr, m, k, n, &mut cref, Epilogue::Bias(&bias));
        assert_eq!(
            c16.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            cref.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "bf16 dequantizing GEMM must match the rounded-f32 GEMM bitwise"
        );

        // i8: raw accumulation x·qᵀ scaled in the epilogue must match
        // the explicitly dequantized f32 GEMM closely (same math, the
        // scale applied per-element vs per-sum differs only in
        // rounding).
        let (q, scale) = quantize_i8(&w);
        let wdeq: Vec<f32> = q.iter().map(|&v| v as f32 * scale).collect();
        let mut c8 = vec![0.0f32; m * n];
        gemm_nt_deq(&a, &q, m, k, n, &mut c8, Epilogue::ScaleBias(scale, &bias));
        gemm_nt(&a, &wdeq, m, k, n, &mut cref, Epilogue::Bias(&bias));
        for (x, y) in c8.iter().zip(&cref) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "i8: {x} vs {y}");
        }
    }

    #[test]
    fn prepacked_gemm_matches_dequantizing_gemm_bitwise() {
        // The prepack pass contract: packing once at plan time must
        // not change a single output bit vs converting per call.
        let mut rng = Pcg64::new(11);
        let (m, k, n) = (5, 37, 13);
        let a: Vec<f32> = rng.normal_vec(m * k);
        let w: Vec<f32> = rng.normal_vec(n * k);
        let bias: Vec<f32> = rng.normal_vec(n);

        let wq16: Vec<u16> = w.iter().map(|&v| f32_to_bf16(v)).collect();
        let panel16 = PackedPanel::pack(&wq16, n, k, None);
        assert_eq!((panel16.rows(), panel16.cols()), (n, k));
        assert_eq!(panel16.bytes(), n * k * 4);
        let mut c_pre = vec![0.0f32; m * n];
        let mut c_deq = vec![0.0f32; m * n];
        gemm_nt_prepacked(&a, &panel16, m, &mut c_pre, Epilogue::Bias(&bias));
        gemm_nt_deq(&a, &wq16, m, k, n, &mut c_deq, Epilogue::Bias(&bias));
        assert_eq!(
            c_pre.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            c_deq.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "bf16 prepacked GEMM diverged from the dequantizing GEMM"
        );

        let (q, scale) = quantize_i8(&w);
        let panel8 = PackedPanel::pack(&q, n, k, Some(scale));
        assert_eq!(panel8.scale(), Some(scale));
        gemm_nt_prepacked(&a, &panel8, m, &mut c_pre, Epilogue::ScaleBias(scale, &bias));
        gemm_nt_deq(&a, &q, m, k, n, &mut c_deq, Epilogue::ScaleBias(scale, &bias));
        assert_eq!(
            c_pre.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            c_deq.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "i8 prepacked GEMM diverged from the dequantizing GEMM"
        );
    }

    #[test]
    fn acc_accumulates() {
        let mut rng = Pcg64::new(4);
        let (m, k, n) = (6, 5, 4);
        let a: Vec<f32> = rng.normal_vec(m * k);
        let b: Vec<f32> = rng.normal_vec(k * n);
        let mut out = vec![1.0f32; m * n];
        gemm_nn_acc(&a, m, k, &b, n, &mut out);
        let want = naive(&a, &b, m, k, n);
        for (x, y) in out.iter().zip(&want) {
            assert!((x - (y + 1.0)).abs() < 1e-4, "{x} vs {}", y + 1.0);
        }
    }

    #[test]
    fn gelu_grad_matches_fd() {
        for x in [-2.5f32, -0.7, 0.0, 0.3, 1.9] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((fd - gelu_grad(x)).abs() < 1e-2, "x={x}: {fd} vs {}", gelu_grad(x));
        }
    }
}

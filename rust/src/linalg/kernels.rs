//! The shared GEMM kernel layer — the ONE optimization site every
//! matmul in the crate routes through (DESIGN.md §4): `Mat`'s operator
//! methods, the `wasi::{layer, wsi, lowrank_grad}` math, the baselines,
//! and the engine graph executor all end up in `gemm_nn` / `gemm_nt` /
//! `gemm_tn` below.
//!
//! Design (EXPERIMENTS.md §Perf):
//!
//! * **Row-sliced threading** — output rows are split into disjoint
//!   contiguous ranges across `util::threadpool::parallel_ranges`
//!   workers.  Each output element is accumulated by exactly one thread
//!   in ascending-k order, so results are **bit-identical for every
//!   thread count** (pinned by `tests` below and the engine-parity
//!   suite) — `--threads` trades wall-clock only.
//! * **Cache blocking** — `gemm_nn`/`gemm_tn` walk k in `KC`-wide
//!   panels so the active B panel stays cache-resident across a
//!   thread's whole row range instead of streaming all of B once per
//!   4-row block.
//! * **Register blocking** — `gemm_nn` feeds each streamed B row into
//!   FOUR output rows (4x fewer B loads, four independent FMA chains
//!   for the auto-vectorizer); `gemm_nt` uses the 8-wide unrolled
//!   [`dot`].
//! * **Fused epilogues** — bias add and GELU run inside the parallel
//!   region while the output panel is still hot ([`Epilogue`]), instead
//!   of a second full sweep from memory after the join.

use crate::util::threadpool::parallel_ranges;

/// k-panel width for cache blocking (a KC x n B-panel of f32 at the
/// model dims this crate runs stays within L2 alongside the output
/// rows).
const KC: usize = 128;

pub const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
pub const GELU_A: f32 = 0.044_715;

/// tanh-approximation GELU (matches `python/compile/model.py`).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_A * x * x * x)).tanh())
}

/// d/dx of [`gelu`].
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    let inner = GELU_C * (x + GELU_A * x * x * x);
    let t = inner.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * x * x)
}

/// Unrolled dot product (8-wide accumulators; auto-vectorizes).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for lane in 0..8 {
            acc[lane] += a[i + lane] * b[i + lane];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Epilogue fused into the GEMM's parallel region, applied per output
/// row while the row is cache-hot.
#[derive(Clone, Copy)]
pub enum Epilogue<'a> {
    /// Plain C = A·B.
    None,
    /// C = A·B + bias (bias broadcast over rows; `bias.len() == n`).
    Bias(&'a [f32]),
    /// C = gelu(A·B + bias) — the inference fc1 fusion.
    BiasGelu(&'a [f32]),
    /// C = gelu(A·B).
    Gelu,
}

impl Epilogue<'_> {
    #[inline]
    fn apply(&self, row: &mut [f32]) {
        match self {
            Epilogue::None => {}
            Epilogue::Bias(bias) => {
                for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                    *o += bv;
                }
            }
            Epilogue::BiasGelu(bias) => {
                for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                    *o = gelu(*o + bv);
                }
            }
            Epilogue::Gelu => {
                for o in row.iter_mut() {
                    *o = gelu(*o);
                }
            }
        }
    }
}

/// Shareable raw pointer for scoped-thread row writes (each thread owns
/// a disjoint row range, so no aliasing).
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// C (m x n) = A (m x k) · B (k x n), then `epi`.  Overwrites `out`.
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], epi: Epilogue) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        let panel =
            unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo * n), (hi - lo) * n) };
        panel.fill(0.0);
        // k-panel loop OUTSIDE the row loop: the KC x n slab of B stays
        // cache-resident across this thread's whole row range.  Each
        // output element still accumulates in ascending-k order, so the
        // result is independent of both KC and the thread partition.
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            let mut i = lo;
            while i + 4 <= hi {
                let out4 =
                    unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), 4 * n) };
                let (o0, rest) = out4.split_at_mut(n);
                let (o1, rest) = rest.split_at_mut(n);
                let (o2, o3) = rest.split_at_mut(n);
                for kk in k0..k1 {
                    let a0 = a[i * k + kk];
                    let a1 = a[(i + 1) * k + kk];
                    let a2 = a[(i + 2) * k + kk];
                    let a3 = a[(i + 3) * k + kk];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue;
                    }
                    let b_row = &b[kk * n..(kk + 1) * n];
                    // zip-fused form: no bounds checks in the hot loop
                    for ((((bv, p0), p1), p2), p3) in b_row
                        .iter()
                        .zip(o0.iter_mut())
                        .zip(o1.iter_mut())
                        .zip(o2.iter_mut())
                        .zip(o3.iter_mut())
                    {
                        *p0 += a0 * bv;
                        *p1 += a1 * bv;
                        *p2 += a2 * bv;
                        *p3 += a3 * bv;
                    }
                }
                i += 4;
            }
            // remainder rows
            for ii in i..hi {
                let out_row =
                    unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(ii * n), n) };
                for kk in k0..k1 {
                    let a_ik = a[ii * k + kk];
                    if a_ik == 0.0 {
                        continue;
                    }
                    let b_row = &b[kk * n..(kk + 1) * n];
                    for (o, &bv) in out_row.iter_mut().zip(b_row) {
                        *o += a_ik * bv;
                    }
                }
            }
            k0 = k1;
        }
        for i in lo..hi {
            let row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            epi.apply(row);
        }
    });
}

/// C (m x n) = A (m x k) · Bᵀ with B stored (n x k) — dot-product form,
/// no transpose materialized.  Then `epi`.  Overwrites `out`.
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], epi: Epilogue) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        for i in lo..hi {
            let out_row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            let a_row = &a[i * k..(i + 1) * k];
            for (j, o) in out_row.iter_mut().enumerate() {
                let b_row = &b[j * k..(j + 1) * k];
                *o = dot(a_row, b_row);
            }
            epi.apply(out_row);
        }
    });
}

/// C (m x n) = Aᵀ · B with A stored (k x m) — no transpose materialized.
/// Then `epi`.  Overwrites `out`.
pub fn gemm_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], epi: Epilogue) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(m, |lo, hi| {
        let panel =
            unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo * n), (hi - lo) * n) };
        panel.fill(0.0);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            for i in lo..hi {
                let out_row =
                    unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
                for kk in k0..k1 {
                    let a_ki = a[kk * m + i];
                    if a_ki == 0.0 {
                        continue;
                    }
                    let b_row = &b[kk * n..(kk + 1) * n];
                    for (o, &bv) in out_row.iter_mut().zip(b_row.iter()) {
                        *o += a_ki * bv;
                    }
                }
            }
            k0 = k1;
        }
        for i in lo..hi {
            let row = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            epi.apply(row);
        }
    });
}

/// out += A · B over raw slices (A: m x k, B: k x n, out: m x n) —
/// the allocation-free accumulating form the f_LR Eq. 18 contraction
/// loop needs.  Serial on purpose: its callers already sit inside a
/// row-blocked outer loop (see `wasi::lowrank_grad`).
pub fn gemm_nn_acc(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let out_row = &mut out[i * n..(i + 1) * n];
        for (kk, &a_ik) in a_row.iter().enumerate() {
            if a_ik == 0.0 {
                continue;
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in out_row.iter_mut().zip(b_row.iter()) {
                *o += a_ik * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;
    use crate::util::threadpool::set_num_threads;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn transpose(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut t = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = a[r * cols + c];
            }
        }
        t
    }

    #[test]
    fn gemm_forms_match_naive() {
        let mut rng = Pcg64::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 9, 13), (70, 150, 33), (1, 7, 1)] {
            let a: Vec<f32> = rng.normal_vec(m * k);
            let b: Vec<f32> = rng.normal_vec(k * n);
            let want = naive(&a, &b, m, k, n);

            let mut c = vec![0.0f32; m * n];
            gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::None);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "nn {m}x{k}x{n}: {x} vs {y}");
            }

            let bt = transpose(&b, k, n); // (n, k)
            gemm_nt(&a, &bt, m, k, n, &mut c, Epilogue::None);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "nt {m}x{k}x{n}: {x} vs {y}");
            }

            let at = transpose(&a, m, k); // (k, m)
            gemm_tn(&at, &b, m, k, n, &mut c, Epilogue::None);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "tn {m}x{k}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn threaded_matches_single_thread_bitwise() {
        // The deterministic row partition: every output element is
        // accumulated by exactly one thread in ascending-k order, so
        // thread count must not change a single bit.
        let _guard = crate::util::threadpool::TEST_OVERRIDE_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::new(2);
        // Sizes straddle the 4-row blocking and the KC panel boundary,
        // and exceed the n >= 64 threading threshold.
        for (m, k, n) in [(97, 200, 65), (130, 129, 70), (68, 33, 90)] {
            let a: Vec<f32> = rng.normal_vec(m * k);
            let b: Vec<f32> = rng.normal_vec(k * n);
            let bt = transpose(&b, k, n);
            let at = transpose(&a, m, k);
            let mut single = vec![0.0f32; m * n];
            let mut multi = vec![0.0f32; m * n];
            for (form, name) in [(0usize, "nn"), (1, "nt"), (2, "tn")] {
                set_num_threads(1);
                match form {
                    0 => gemm_nn(&a, &b, m, k, n, &mut single, Epilogue::None),
                    1 => gemm_nt(&a, &bt, m, k, n, &mut single, Epilogue::None),
                    _ => gemm_tn(&at, &b, m, k, n, &mut single, Epilogue::None),
                }
                set_num_threads(7);
                match form {
                    0 => gemm_nn(&a, &b, m, k, n, &mut multi, Epilogue::None),
                    1 => gemm_nt(&a, &bt, m, k, n, &mut multi, Epilogue::None),
                    _ => gemm_tn(&at, &b, m, k, n, &mut multi, Epilogue::None),
                }
                set_num_threads(0);
                assert_eq!(single, multi, "{name} {m}x{k}x{n} diverged across thread counts");
            }
        }
    }

    #[test]
    fn epilogues_fuse_bias_and_gelu() {
        let mut rng = Pcg64::new(3);
        let (m, k, n) = (9, 11, 67);
        let a: Vec<f32> = rng.normal_vec(m * k);
        let b: Vec<f32> = rng.normal_vec(k * n);
        let bias: Vec<f32> = rng.normal_vec(n);
        let plain = naive(&a, &b, m, k, n);

        let mut c = vec![0.0f32; m * n];
        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::Bias(&bias));
        for (i, x) in c.iter().enumerate() {
            let want = plain[i] + bias[i % n];
            assert!((x - want).abs() < 1e-3, "bias: {x} vs {want}");
        }

        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::BiasGelu(&bias));
        for (i, x) in c.iter().enumerate() {
            let want = gelu(plain[i] + bias[i % n]);
            assert!((x - want).abs() < 1e-3, "bias+gelu: {x} vs {want}");
        }

        gemm_nn(&a, &b, m, k, n, &mut c, Epilogue::Gelu);
        for (i, x) in c.iter().enumerate() {
            let want = gelu(plain[i]);
            assert!((x - want).abs() < 1e-3, "gelu: {x} vs {want}");
        }
    }

    #[test]
    fn acc_accumulates() {
        let mut rng = Pcg64::new(4);
        let (m, k, n) = (6, 5, 4);
        let a: Vec<f32> = rng.normal_vec(m * k);
        let b: Vec<f32> = rng.normal_vec(k * n);
        let mut out = vec![1.0f32; m * n];
        gemm_nn_acc(&a, m, k, &b, n, &mut out);
        let want = naive(&a, &b, m, k, n);
        for (x, y) in out.iter().zip(&want) {
            assert!((x - (y + 1.0)).abs() < 1e-4, "{x} vs {}", y + 1.0);
        }
    }

    #[test]
    fn gelu_grad_matches_fd() {
        for x in [-2.5f32, -0.7, 0.0, 0.3, 1.9] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((fd - gelu_grad(x)).abs() < 1e-2, "x={x}: {fd} vs {}", gelu_grad(x));
        }
    }
}

//! Dense row-major f32 matrix.  All matmul operator forms delegate to
//! the shared kernel layer (`linalg::kernels`) — the ONE place GEMM
//! performance work happens (threading, cache/register blocking, fused
//! epilogues); see EXPERIMENTS.md §Perf.

use super::kernels::{self, Epilogue};

pub use super::kernels::dot;

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

/// Borrowed, stride-aware view of one matrix column — the allocation-free
/// replacement for the old `Mat::col` (which built a fresh `Vec` per
/// call on the Jacobi-SVD and Gram-Schmidt hot paths).
#[derive(Clone, Copy)]
pub struct ColView<'a> {
    data: &'a [f32],
    stride: usize,
    len: usize,
}

impl<'a> ColView<'a> {
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        self.data[i * self.stride]
    }

    pub fn iter(&self) -> impl Iterator<Item = f32> + 'a {
        let (data, stride) = (self.data, self.stride);
        (0..self.len).map(move |i| data[i * stride])
    }

    /// Strided dot product without materializing either column.
    pub fn dot(&self, other: ColView<'_>) -> f32 {
        debug_assert_eq!(self.len, other.len);
        let mut s = 0.0f32;
        for i in 0..self.len {
            s += self.get(i) * other.get(i);
        }
        s
    }

    /// Squared Euclidean norm of the column.
    pub fn sq_norm(&self) -> f32 {
        let mut s = 0.0f32;
        for i in 0..self.len {
            let v = self.get(i);
            s += v * v;
        }
        s
    }

    /// Materialize the column (callers that genuinely need ownership).
    pub fn to_vec(&self) -> Vec<f32> {
        self.iter().collect()
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len(), "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn random(rows: usize, cols: usize, rng: &mut crate::data::rng::Pcg64) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Borrowed stride-aware view of column `c` (no allocation).
    pub fn col_view(&self, c: usize) -> ColView<'_> {
        assert!(c < self.cols, "column {c} out of range ({})", self.cols);
        ColView { data: &self.data[c..], stride: self.cols, len: self.rows }
    }

    /// Copy column `c` into a caller-owned buffer (reusable across
    /// calls; clears and refills `out`).
    pub fn col_into(&self, c: usize, out: &mut Vec<f32>) {
        out.clear();
        out.extend(self.col_view(c).iter());
    }

    pub fn set_col(&mut self, c: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for r in 0..self.rows {
            *self.at_mut(r, c) = v[r];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// C = A · B (kernel layer: threaded, cache/register blocked).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul inner dims");
        let mut out = Mat::zeros(self.rows, b.cols);
        kernels::gemm_nn(
            &self.data, &b.data, self.rows, self.cols, b.cols, &mut out.data, Epilogue::None,
        );
        out
    }

    /// C = Aᵀ · B  without materializing Aᵀ.
    pub fn matmul_tn(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "matmul_tn inner dims");
        let mut out = Mat::zeros(self.cols, b.cols);
        kernels::gemm_tn(
            &self.data, &b.data, self.cols, self.rows, b.cols, &mut out.data, Epilogue::None,
        );
        out
    }

    /// C = A · Bᵀ  without materializing Bᵀ (dot-product form).
    pub fn matmul_nt(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_nt inner dims");
        let mut out = Mat::zeros(self.rows, b.rows);
        kernels::gemm_nt(
            &self.data, &b.data, self.rows, self.cols, b.rows, &mut out.data, Epilogue::None,
        );
        out
    }

    /// y = A · x for a vector x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|r| dot(self.row(r), x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 9, 13), (64, 128, 32), (1, 7, 1)] {
            let a = Mat::random(m, k, &mut rng);
            let b = Mat::random(k, n, &mut rng);
            let c = a.matmul(&b);
            let c2 = naive_matmul(&a, &b);
            for (x, y) in c.data.iter().zip(&c2.data) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn transposed_forms_match() {
        let mut rng = Pcg64::new(2);
        let a = Mat::random(23, 11, &mut rng);
        let b = Mat::random(23, 7, &mut rng);
        let tn = a.matmul_tn(&b);
        let direct = a.transpose().matmul(&b);
        for (x, y) in tn.data.iter().zip(&direct.data) {
            assert!((x - y).abs() < 1e-3);
        }
        let c = Mat::random(11, 9, &mut rng);
        let d = Mat::random(14, 9, &mut rng);
        let nt = c.matmul_nt(&d);
        let direct = c.matmul(&d.transpose());
        for (x, y) in nt.data.iter().zip(&direct.data) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::new(3);
        let a = Mat::random(6, 6, &mut rng);
        let c = a.matmul(&Mat::eye(6));
        for (x, y) in c.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::new(4);
        let a = Mat::random(5, 8, &mut rng);
        let x: Vec<f32> = rng.normal_vec(8);
        let xm = Mat::from_vec(8, 1, x.clone());
        let y = a.matvec(&x);
        let ym = a.matmul(&xm);
        for (p, q) in y.iter().zip(&ym.data) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(5);
        let a = Mat::random(4, 9, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn col_view_matches_materialized_column() {
        let mut rng = Pcg64::new(6);
        let a = Mat::random(7, 5, &mut rng);
        for c in 0..a.cols {
            let view = a.col_view(c);
            assert_eq!(view.len(), a.rows);
            for r in 0..a.rows {
                assert_eq!(view.get(r), a.at(r, c));
            }
            let mut buf = Vec::new();
            a.col_into(c, &mut buf);
            assert_eq!(buf, view.to_vec());
        }
        // strided dot == dot of materialized columns
        let p = a.col_view(1).to_vec();
        let q = a.col_view(3).to_vec();
        let want: f32 = p.iter().zip(&q).map(|(x, y)| x * y).sum();
        assert!((a.col_view(1).dot(a.col_view(3)) - want).abs() < 1e-5);
        assert!((a.col_view(2).sq_norm() - a.col_view(2).dot(a.col_view(2))).abs() < 1e-6);
    }
}

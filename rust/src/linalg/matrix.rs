//! Dense row-major f32 matrix with blocked, multi-threaded matmul.
//!
//! The native engine's hot path (see EXPERIMENTS.md §Perf): `matmul`
//! splits output rows across threads and walks the k-dimension in the
//! inner loop with an 8-wide accumulator pattern the compiler
//! auto-vectorizes; `matmul_tn`/`matmul_nt` cover the transposed forms
//! the backward pass needs without materializing transposes.

use crate::util::threadpool::parallel_ranges;

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len(), "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn random(rows: usize, cols: usize, rng: &mut crate::data::rng::Pcg64) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn set_col(&mut self, c: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for r in 0..self.rows {
            *self.at_mut(r, c) = v[r];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// C = A · B  (4-row register-blocked ikj, threaded).
    ///
    /// Each B row streamed from memory feeds FOUR output rows — 4x fewer
    /// B loads and four independent FMA chains for the auto-vectorizer
    /// (see EXPERIMENTS.md §Perf for the measured delta).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul inner dims");
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut out = Mat::zeros(m, n);
        let a_data = &self.data;
        let b_data = &b.data;
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        parallel_ranges(m, |lo, hi| {
            let out_ptr = &out_ptr;
            let mut i = lo;
            while i + 4 <= hi {
                let out4 = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), 4 * n)
                };
                let (o0, rest) = out4.split_at_mut(n);
                let (o1, rest) = rest.split_at_mut(n);
                let (o2, o3) = rest.split_at_mut(n);
                for kk in 0..k {
                    let a0 = a_data[i * k + kk];
                    let a1 = a_data[(i + 1) * k + kk];
                    let a2 = a_data[(i + 2) * k + kk];
                    let a3 = a_data[(i + 3) * k + kk];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue;
                    }
                    let b_row = &b_data[kk * n..(kk + 1) * n];
                    // zip-fused form: no bounds checks in the hot loop
                    for ((((bv, p0), p1), p2), p3) in b_row
                        .iter()
                        .zip(o0.iter_mut())
                        .zip(o1.iter_mut())
                        .zip(o2.iter_mut())
                        .zip(o3.iter_mut())
                    {
                        *p0 += a0 * bv;
                        *p1 += a1 * bv;
                        *p2 += a2 * bv;
                        *p3 += a3 * bv;
                    }
                }
                i += 4;
            }
            // remainder rows
            for ii in i..hi {
                let out_row = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(ii * n), n)
                };
                let a_row = &a_data[ii * k..(ii + 1) * k];
                for (kk, &a_ik) in a_row.iter().enumerate() {
                    if a_ik == 0.0 {
                        continue;
                    }
                    let b_row = &b_data[kk * n..(kk + 1) * n];
                    for (o, &bv) in out_row.iter_mut().zip(b_row) {
                        *o += a_ik * bv;
                    }
                }
            }
        });
        out
    }

    /// C = Aᵀ · B  without materializing Aᵀ.
    pub fn matmul_tn(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "matmul_tn inner dims");
        let (k, m, n) = (self.rows, self.cols, b.cols);
        let mut out = Mat::zeros(m, n);
        let a_data = &self.data;
        let b_data = &b.data;
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        parallel_ranges(m, |lo, hi| {
            let out_ptr = &out_ptr;
            for i in lo..hi {
                let out_row = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n)
                };
                for kk in 0..k {
                    let a_ki = a_data[kk * m + i];
                    if a_ki == 0.0 {
                        continue;
                    }
                    let b_row = &b_data[kk * n..(kk + 1) * n];
                    for (o, &bv) in out_row.iter_mut().zip(b_row.iter()) {
                        *o += a_ki * bv;
                    }
                }
            }
        });
        out
    }

    /// C = A · Bᵀ  without materializing Bᵀ (dot-product form).
    pub fn matmul_nt(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_nt inner dims");
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut out = Mat::zeros(m, n);
        let a_data = &self.data;
        let b_data = &b.data;
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        parallel_ranges(m, |lo, hi| {
            let out_ptr = &out_ptr;
            for i in lo..hi {
                let out_row = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n)
                };
                let a_row = &a_data[i * k..(i + 1) * k];
                for (j, o) in out_row.iter_mut().enumerate() {
                    let b_row = &b_data[j * k..(j + 1) * k];
                    *o = dot(a_row, b_row);
                }
            }
        });
        out
    }

    /// y = A · x for a vector x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|r| dot(self.row(r), x)).collect()
    }
}

/// out += A · B over raw slices (A: m x k, B: k x n, out: m x n), using
/// the same zip-fused streaming kernel as `Mat::matmul` but accumulating
/// into caller-owned storage — the allocation-free form the f_LR
/// contraction loop needs (EXPERIMENTS.md §Perf iteration 4).
pub fn matmul_acc(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let out_row = &mut out[i * n..(i + 1) * n];
        for (kk, &a_ik) in a_row.iter().enumerate() {
            if a_ik == 0.0 {
                continue;
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in out_row.iter_mut().zip(b_row.iter()) {
                *o += a_ik * bv;
            }
        }
    }
}

/// Unrolled dot product (8-wide accumulators; auto-vectorizes).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for lane in 0..8 {
            acc[lane] += a[i + lane] * b[i + lane];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Shareable raw pointer for scoped-thread row writes (each thread owns a
/// disjoint row range, so no aliasing).
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 9, 13), (64, 128, 32), (1, 7, 1)] {
            let a = Mat::random(m, k, &mut rng);
            let b = Mat::random(k, n, &mut rng);
            let c = a.matmul(&b);
            let c2 = naive_matmul(&a, &b);
            for (x, y) in c.data.iter().zip(&c2.data) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn transposed_forms_match() {
        let mut rng = Pcg64::new(2);
        let a = Mat::random(23, 11, &mut rng);
        let b = Mat::random(23, 7, &mut rng);
        let tn = a.matmul_tn(&b);
        let direct = a.transpose().matmul(&b);
        for (x, y) in tn.data.iter().zip(&direct.data) {
            assert!((x - y).abs() < 1e-3);
        }
        let c = Mat::random(11, 9, &mut rng);
        let d = Mat::random(14, 9, &mut rng);
        let nt = c.matmul_nt(&d);
        let direct = c.matmul(&d.transpose());
        for (x, y) in nt.data.iter().zip(&direct.data) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::new(3);
        let a = Mat::random(6, 6, &mut rng);
        let c = a.matmul(&Mat::eye(6));
        for (x, y) in c.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::new(4);
        let a = Mat::random(5, 8, &mut rng);
        let x: Vec<f32> = rng.normal_vec(8);
        let xm = Mat::from_vec(8, 1, x.clone());
        let y = a.matvec(&x);
        let ym = a.matmul(&xm);
        for (p, q) in y.iter().zip(&ym.data) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(5);
        let a = Mat::random(4, 9, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }
}

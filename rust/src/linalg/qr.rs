//! Orthogonalization: modified Gram-Schmidt (what Algorithm 1 calls for)
//! and Householder QR (the numerically-bulletproof fallback used by the
//! Jacobi SVD and baseline code).

use super::matrix::{dot, Mat};

/// Modified Gram-Schmidt with re-orthogonalization (CGS2).
///
/// Returns Q (rows x cols) with orthonormal columns spanning the column
/// space of `a`.  Columns that collapse to numerical zero are replaced by
/// unit basis vectors orthogonal to the rest (rank-deficient input).
pub fn gram_schmidt(a: &Mat) -> Mat {
    let (n, r) = (a.rows, a.cols);
    let mut q = Mat::zeros(n, r);
    let mut cols: Vec<Vec<f32>> = Vec::with_capacity(r);
    for j in 0..r {
        let mut v = a.col_view(j).to_vec();
        for _pass in 0..2 {
            for qc in &cols {
                let c = dot(qc, &v);
                for (vi, qi) in v.iter_mut().zip(qc) {
                    *vi -= c * qi;
                }
            }
        }
        let nrm = dot(&v, &v).sqrt();
        if nrm < 1e-12 {
            // Degenerate column: substitute an orthogonalized basis vector.
            let mut e = vec![0.0f32; n];
            e[j % n] = 1.0;
            for qc in &cols {
                let c = dot(qc, &e);
                for (vi, qi) in e.iter_mut().zip(qc) {
                    *vi -= c * qi;
                }
            }
            let en = dot(&e, &e).sqrt().max(1e-12);
            v = e.iter().map(|x| x / en).collect();
        } else {
            for vi in v.iter_mut() {
                *vi /= nrm;
            }
        }
        q.set_col(j, &v);
        cols.push(v);
    }
    q
}

/// Householder QR: A (m x n, m >= n)  ->  (Q (m x n) thin, R (n x n)).
pub fn householder_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "householder_qr expects tall matrix");
    let mut r = a.clone();
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);

    for k in 0..n {
        // Build the Householder vector for column k below the diagonal.
        let mut x = vec![0.0f32; m - k];
        for i in k..m {
            x[i - k] = r.at(i, k);
        }
        let alpha = -x[0].signum() * dot(&x, &x).sqrt();
        let mut v = x.clone();
        v[0] -= alpha;
        let vn = dot(&v, &v).sqrt();
        if vn > 1e-12 {
            for vi in v.iter_mut() {
                *vi /= vn;
            }
            // Apply H = I - 2vvᵀ to the trailing block of R.
            for j in k..n {
                let mut c = 0.0f32;
                for i in k..m {
                    c += v[i - k] * r.at(i, j);
                }
                c *= 2.0;
                for i in k..m {
                    *r.at_mut(i, j) -= c * v[i - k];
                }
            }
        } else {
            v = vec![0.0; m - k];
        }
        vs.push(v);
    }

    // Accumulate thin Q by applying the reflectors to the first n columns
    // of the identity, in reverse.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q.data[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        if v.iter().all(|&x| x == 0.0) {
            continue;
        }
        for j in 0..n {
            let mut c = 0.0f32;
            for i in k..m {
                c += v[i - k] * q.at(i, j);
            }
            c *= 2.0;
            for i in k..m {
                *q.at_mut(i, j) -= c * v[i - k];
            }
        }
    }

    // R is the upper-triangular n x n block.
    let mut rr = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            *rr.at_mut(i, j) = r.at(i, j);
        }
    }
    (q, rr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    fn check_orthonormal(q: &Mat, tol: f32) {
        let g = q.matmul_tn(q);
        for i in 0..g.rows {
            for j in 0..g.cols {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (g.at(i, j) - want).abs() < tol,
                    "G[{i},{j}] = {}",
                    g.at(i, j)
                );
            }
        }
    }

    #[test]
    fn gs_orthonormal() {
        let mut rng = Pcg64::new(1);
        let a = Mat::random(50, 8, &mut rng);
        let q = gram_schmidt(&a);
        check_orthonormal(&q, 1e-4);
    }

    #[test]
    fn gs_spans_input() {
        // Q Qᵀ a == a when a's columns already lie in span(Q).
        let mut rng = Pcg64::new(2);
        let a = Mat::random(20, 5, &mut rng);
        let q = gram_schmidt(&a);
        let proj = q.matmul(&q.matmul_tn(&a));
        for (x, y) in proj.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn gs_handles_rank_deficiency() {
        let mut rng = Pcg64::new(3);
        let mut a = Mat::random(10, 4, &mut rng);
        let c0 = a.col_view(0).to_vec();
        a.set_col(1, &c0); // duplicate column
        let q = gram_schmidt(&a);
        check_orthonormal(&q, 1e-3);
    }

    #[test]
    fn householder_reconstructs() {
        let mut rng = Pcg64::new(4);
        let a = Mat::random(12, 6, &mut rng);
        let (q, r) = householder_qr(&a);
        check_orthonormal(&q, 1e-4);
        let qr = q.matmul(&r);
        for (x, y) in qr.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        // R upper-triangular
        for i in 0..r.rows {
            for j in 0..i {
                assert!(r.at(i, j).abs() < 1e-5);
            }
        }
    }
}

//! Criterion-lite bench harness (the vendored crate set has no criterion).
//!
//! Adaptive iteration count targeting a fixed measurement window, with
//! warmup, and median / p10 / p90 reporting.  Used by `cargo bench`
//! (benches/ have `harness = false`) and by the eval modules that need
//! wallclock numbers.

use std::time::Instant;

use crate::util::stats::percentile;

/// One benchmark's results.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms  (p10 {:>8.3}, p90 {:>8.3}, n={})",
            self.name,
            self.median_s * 1e3,
            self.p10_s * 1e3,
            self.p90_s * 1e3,
            self.iters
        )
    }
}

/// Benchmark a closure: warm up, then sample until `budget_s` of
/// measurement or `max_iters`, whichever first (at least 3 samples).
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    // Warmup: one call, or more if extremely fast.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_secs_f64();
    let mut samples = Vec::new();
    let max_iters = 10_000usize;
    let t_start = Instant::now();
    while samples.len() < 3
        || (t_start.elapsed().as_secs_f64() < budget_s && samples.len() < max_iters)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let _ = first;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_s: percentile(&samples, 50.0),
        p10_s: percentile(&samples, 10.0),
        p90_s: percentile(&samples, 90.0),
    }
}

/// Time a single execution (for expensive end-to-end cases).
pub fn bench_once<F: FnOnce()>(name: &str, f: F) -> BenchResult {
    let t0 = Instant::now();
    f();
    let dt = t0.elapsed().as_secs_f64();
    BenchResult {
        name: name.to_string(),
        iters: 1,
        median_s: dt,
        p10_s: dt,
        p90_s: dt,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_at_least_three_samples() {
        let r = bench("noop", 0.0, || {});
        assert!(r.iters >= 3);
        assert!(r.median_s >= 0.0);
    }

    #[test]
    fn median_in_range() {
        let r = bench("sleepish", 0.01, || {
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert!(r.median_s >= 150e-6, "median {}", r.median_s);
        assert!(r.p10_s <= r.median_s && r.median_s <= r.p90_s);
    }
}

//! The one job-execution path: every fine-tune in the system —
//! `Session::finetune` (CLI `train`, examples, eval exhibits) and the
//! `wasi-train serve` workers — runs a [`JobSpec`] through
//! [`execute_job`], so queueing/cancellation/streaming are features of
//! the service, not a second training loop.

use std::sync::atomic::AtomicBool;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::memory::account;
use crate::coordinator::metrics::StepRecord;
use crate::coordinator::{Checkpoint, FinetuneReport, RunStatus, TrainConfig, Trainer};
use crate::data::synth::VisionTask;
use crate::data::Loader;
use crate::precision::Precision;
use crate::store::{extract_delta, DeltaRecord};
use crate::util::threadpool::ThreadCountGuard;

use super::job::JobSpec;
use super::pool::PoolEntry;

/// Progress callbacks out of [`execute_job`]; the service maps these to
/// [`super::JobEvent`]s, the blocking session path ignores them.
#[derive(Debug, Clone, Copy)]
pub enum RunnerEvent {
    /// Engine built; training is about to start.
    Started { backend: &'static str },
    /// One training step completed.
    Step(StepRecord),
}

/// Everything a finished job yields: the public report plus the final
/// flat parameter vector (kept by the service so inference can run
/// against a finished job's personalized weights).  A `persist_delta`
/// job additionally carries its extracted subspace delta record — the
/// service stores THAT and drops `final_params` instead of retaining a
/// full copy per user.
pub struct JobOutcome {
    pub report: FinetuneReport,
    pub final_params: Vec<f32>,
    pub delta: Option<DeltaRecord>,
}

/// Run one job to completion on the caller's thread.
///
/// Cancellation: `cancel` is polled between steps; a cancelled job
/// returns an error containing `"cancelled"` (the service maps it to
/// `JobState::Failed`).  The engine is exclusive to this call, so
/// cancellation can never tear shared state.
pub fn execute_job(
    pool: &PoolEntry,
    spec: &JobSpec,
    observe: &mut dyn FnMut(RunnerEvent),
    cancel: &AtomicBool,
) -> Result<JobOutcome> {
    let cfg = &spec.config;
    // Honor cfg.threads for this run only; the guard restores the
    // caller's process-global setting on every exit path.
    let _threads = ThreadCountGuard::apply(cfg.threads);

    let entry = pool.manifest.model(&cfg.model)?;
    let mut task = VisionTask::preset(&cfg.dataset, cfg.seed)
        .ok_or_else(|| anyhow!("unknown dataset preset {:?}", cfg.dataset))?;
    if task.classes != entry.classes || task.dim != entry.input_dim {
        // Artifacts are compiled for a fixed class count and image
        // size; presets are re-instantiated to match (documented
        // substitution: the head's class-count and the input
        // resolution are artifact constants).
        let side = entry.image_side().ok_or_else(|| {
            anyhow!(
                "model {} is not an image model (input_dim {})",
                entry.name,
                entry.input_dim
            )
        })?;
        task = VisionTask::new(&cfg.dataset, entry.classes, side, 0.7, 8, cfg.seed);
    }
    let mut loader = Loader::from_task(&mut task, cfg.samples, cfg.seed);
    let tcfg = TrainConfig {
        steps: cfg.steps,
        lr0: cfg.lr0,
        log_every: cfg.log_every.unwrap_or((cfg.steps / 10).max(1)),
        verbose: cfg.verbose,
        engine: cfg.engine,
        precision: cfg.precision,
        // Delta persistence requires the frozen region to stay
        // bit-identical to the base: train subspace-only.
        subspace_only: spec.persist_delta,
    };
    let mut trainer = Trainer::new(&pool.runtime, entry, tcfg)?;

    let mut start_step = 0usize;
    if let Some(path) = &spec.resume_from {
        let ckpt = Checkpoint::load(path)?;
        ckpt.restore_into(trainer.engine.as_mut())?;
        start_step = ckpt.step as usize;
        if start_step >= cfg.steps {
            bail!(
                "checkpoint {} is at step {start_step}, which is not before \
                 the configured {} steps — nothing to resume",
                path.display(),
                cfg.steps
            );
        }
        // Fast-forward the (seed-deterministic) loader past the batches
        // the checkpointed run consumed, so the resumed trajectory is
        // bit-identical to the uninterrupted one — PROVIDED the spec
        // repeats the checkpointed recipe (dataset/samples/seed/lr0);
        // the v1 checkpoint records only model+step, so that part of
        // the contract is the caller's (JobSpec::resume_from docs).
        let batch = trainer.engine.entry().batch;
        for _ in 0..start_step {
            let _ = loader.next_batch(batch);
        }
    }

    observe(RunnerEvent::Started { backend: trainer.engine.backend() });
    let status = trainer.run_observed(
        &mut loader,
        start_step,
        &mut |r| observe(RunnerEvent::Step(*r)),
        cancel,
    )?;
    if status == RunStatus::Cancelled {
        bail!("cancelled at client request");
    }
    let val = trainer.validate(&pool.runtime, &loader)?;
    if let Some(path) = &spec.checkpoint_to {
        Checkpoint::from_engine(trainer.engine.as_ref(), cfg.steps as u64).save(path)?;
    }
    let report = FinetuneReport {
        model: cfg.model.clone(),
        dataset: cfg.dataset.clone(),
        engine: trainer.engine.backend(),
        precision: cfg.precision,
        final_loss: trainer.metrics.smoothed_loss(),
        val_accuracy: val,
        mean_step_seconds: trainer.metrics.mean_step_seconds(),
        total_seconds: trainer.metrics.total_seconds(),
        memory: account(entry),
        loss_curve: trainer.metrics.loss_curve(50),
    };
    let delta = if spec.persist_delta {
        // Extraction verifies bit-exactly that the frozen region still
        // equals the (precision-adjusted) base; a drifted job fails
        // loudly instead of persisting a lossy record.
        let base = pool.initial_params(&cfg.model)?;
        Some(extract_delta(entry, &base, trainer.engine.params(), cfg.precision)?)
    } else {
        None
    };
    Ok(JobOutcome { report, final_params: trainer.engine.params().to_vec(), delta })
}

/// A pool inference request (shared by the service's `infer` command
/// and the CLI's `wasi-train infer`).
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub model: String,
    pub engine: crate::engine::EngineKind,
    /// Weight precision to serve at: `Bf16`/`I8` route to the pool's
    /// quantized-on-load shared engine (native only).
    pub precision: Precision,
    /// Seed for the synthetic probe batch when no input is supplied.
    pub seed: u64,
    /// Flat input rows (batch × input_dim); `None` = generate one
    /// synthetic labelled batch and report its accuracy.
    pub x: Option<Vec<f32>>,
}

/// Inference result: predictions, plus accuracy when the input was the
/// labelled synthetic probe batch.  `logits` carries the raw pre-argmax
/// rows so callers (the micro-batching bit-identity pins in
/// `tests/net.rs`, notably) can compare outputs bitwise, not just at
/// the argmax level.
#[derive(Debug, Clone)]
pub struct InferOutput {
    pub backend: String,
    pub precision: Precision,
    pub preds: Vec<usize>,
    pub batch: usize,
    pub correct: Option<usize>,
    pub logits: Vec<f32>,
}

/// The parameter source a pool inference reads from.
pub enum InferParams<'a> {
    /// The variant's initial/pretrained params.
    Base,
    /// A finished job's retained full parameter vector.
    Full(&'a [f32]),
    /// A finished delta-persisted job's record, applied against the
    /// pool's shared frozen base at request time (DESIGN.md §Variant
    /// store) — the f32 path serves zero-copy via the overlay view.
    Delta(&'a DeltaRecord),
}

/// Run pool inference with explicit params (`None` = the variant's
/// initial/pretrained params).  Shared by the service and the CLI.
pub fn run_infer(
    pool: &PoolEntry,
    req: &InferRequest,
    params: Option<&[f32]>,
) -> Result<InferOutput> {
    match params {
        Some(p) => run_infer_with(pool, req, InferParams::Full(p)),
        None => run_infer_with(pool, req, InferParams::Base),
    }
}

/// [`run_infer`] generalized over the parameter source, including the
/// delta-apply path.
pub fn run_infer_with(
    pool: &PoolEntry,
    req: &InferRequest,
    source: InferParams<'_>,
) -> Result<InferOutput> {
    run_infer_keyed(pool, req, source, None)
}

/// [`run_infer_with`] plus an optional cache key for the packed
/// reduced-precision parameter set.  The service passes a finished
/// job's key so repeated personalized requests reuse one quantize+pack
/// ([`PoolEntry::packed_for`]); `None` (ad-hoc params) packs
/// transiently as before.
pub fn run_infer_keyed(
    pool: &PoolEntry,
    req: &InferRequest,
    source: InferParams<'_>,
    cache_key: Option<&str>,
) -> Result<InferOutput> {
    let mut outs = run_infer_batch_keyed(pool, std::slice::from_ref(req), source, cache_key)?;
    outs.pop().ok_or_else(|| anyhow!("infer batch returned no output"))
}

/// [`run_infer_keyed`] over a *group* of requests sharing one
/// `(model, engine, precision)` pool entry and one parameter source —
/// the execution site of the network front-end's micro-batcher
/// (`net/batcher.rs`, DESIGN.md §Network front-end).
///
/// All requests' input rows are stacked into ONE engine call through
/// the arena-planned batched walk, and the logits are split back per
/// request afterwards.  Every inference GEMM in the native engine is
/// row-independent (`linalg::kernels`: per-row dot products, fixed
/// ascending-k accumulation order), and the graph walk itself is
/// per-batch-element, so the stacked call is **bitwise identical** to
/// running each request alone — pinned at all three precisions in
/// `tests/net.rs`.  An HLO engine makes no such shape promise, so a
/// multi-request group without a native engine runs each request's
/// rows through its own call instead (same results, no stacking win).
pub fn run_infer_batch_keyed(
    pool: &PoolEntry,
    reqs: &[InferRequest],
    source: InferParams<'_>,
    cache_key: Option<&str>,
) -> Result<Vec<InferOutput>> {
    let first = reqs.first().ok_or_else(|| anyhow!("empty infer batch"))?;
    for r in &reqs[1..] {
        if r.model != first.model || r.engine != first.engine || r.precision != first.precision {
            bail!(
                "infer batch mixes pool keys: ({}, {:?}, {}) vs ({}, {:?}, {}) — \
                 the batcher must only coalesce requests sharing one entry",
                first.model,
                first.engine,
                first.precision,
                r.model,
                r.engine,
                r.precision
            );
        }
    }
    let entry = pool.manifest.model(&first.model)?;
    if let InferParams::Full(p) = &source {
        if p.len() != entry.params_len {
            bail!(
                "params length {} does not match model {} ({} expected) — \
                 inference against a job from a different variant?",
                p.len(),
                entry.name,
                entry.params_len
            );
        }
    }
    if let InferParams::Delta(rec) = &source {
        if rec.model != entry.name {
            bail!(
                "delta record is for model {}, request is for {} — refusing \
                 a cross-variant apply",
                rec.model,
                entry.name
            );
        }
    }
    let pooled = pool.shared_infer_at(&first.model, first.engine, first.precision)?;
    let engine = pooled.engine();

    // Per-request input prep (explicit rows, or the labelled synthetic
    // probe batch seeded per request).
    let mut xs: Vec<Vec<f32>> = Vec::with_capacity(reqs.len());
    let mut labels: Vec<Option<Vec<usize>>> = Vec::with_capacity(reqs.len());
    for req in reqs {
        match &req.x {
            Some(x) => {
                if x.is_empty() || x.len() % entry.input_dim != 0 {
                    bail!(
                        "input length {} is not a positive multiple of input_dim {}",
                        x.len(),
                        entry.input_dim
                    );
                }
                xs.push(x.clone());
                labels.push(None);
            }
            None => {
                let side = entry.image_side().ok_or_else(|| {
                    anyhow!(
                        "model {} is not an image model (input_dim {}); \
                         supply explicit inputs",
                        entry.name,
                        entry.input_dim
                    )
                })?;
                let mut task = VisionTask::new("infer", entry.classes, side, 0.7, 8, req.seed);
                let (x, _, l) = task.batch_onehot(entry.batch);
                xs.push(x);
                labels.push(Some(l));
            }
        }
    }

    let logits_per_req: Vec<Vec<f32>> = if reqs.len() == 1 || pooled.native().is_some() {
        // One stacked call; split the logit rows back out per request.
        let stacked: Vec<f32> = xs.iter().flat_map(|x| x.iter().copied()).collect();
        let logits = infer_logits(pool, &pooled, first, &source, cache_key, &stacked)?;
        let mut off = 0usize;
        let mut split = Vec::with_capacity(reqs.len());
        for x in &xs {
            let n = (x.len() / entry.input_dim) * entry.classes;
            split.push(logits[off..off + n].to_vec());
            off += n;
        }
        split
    } else {
        xs.iter()
            .map(|x| infer_logits(pool, &pooled, first, &source, cache_key, x))
            .collect::<Result<_>>()?
    };

    let mut outs = Vec::with_capacity(reqs.len());
    for (i, req) in reqs.iter().enumerate() {
        let logits = &logits_per_req[i];
        let preds = crate::engine::ops::argmax_rows(logits, entry.classes);
        let correct = labels[i]
            .as_ref()
            .map(|l| preds.iter().zip(l).filter(|(p, q)| p == q).count());
        outs.push(InferOutput {
            backend: engine.backend().to_string(),
            precision: req.precision,
            batch: preds.len(),
            preds,
            correct,
            logits: logits.clone(),
        });
    }
    Ok(outs)
}

/// The (precision × parameter-source) inference matrix, at the logits
/// level.  `x` may be one request's rows or a whole micro-batch's
/// stacked rows — the callee never depends on the row count.
fn infer_logits(
    pool: &PoolEntry,
    pooled: &super::pool::PooledInfer<'_>,
    req: &InferRequest,
    source: &InferParams<'_>,
    cache_key: Option<&str>,
    x: &[f32],
) -> Result<Vec<f32>> {
    let engine = pooled.engine();
    if req.precision == Precision::F32 {
        match source {
            InferParams::Full(p) => engine.infer(p, x),
            InferParams::Base => {
                let initial = pool.initial_params(&req.model)?;
                engine.infer(&initial, x)
            }
            InferParams::Delta(rec) => {
                let base = pool.initial_params(&req.model)?;
                if rec.train_precision == Precision::F32 {
                    if let Some(native) = pooled.native() {
                        // Zero-copy delta apply: factors overlay the
                        // shared base inside the walk — bit-identical
                        // to predicting on the materialized vector.
                        let overlay = rec.overlay(&base)?;
                        native.infer_overlay(&overlay, x)
                    } else {
                        engine.infer(&rec.apply(&base)?, x)
                    }
                } else {
                    // A bf16-trained job's frozen region is the rounded
                    // base; apply() reproduces it exactly, transiently.
                    engine.infer(&rec.apply(&base)?, x)
                }
            }
        }
    } else {
        // Reduced precision resolves to the shared native engine
        // (shared_infer_at rejects HLO): pool params serve from the
        // quantized-on-load packed set, a finished job's personalized
        // params are packed for this request.
        let native = pooled
            .native()
            .ok_or_else(|| anyhow!("precision {} requires the native engine", req.precision))?;
        match source {
            InferParams::Full(p) => {
                let packed = match cache_key {
                    Some(key) => pool.packed_for(key, req.precision, || {
                        native.pack_params(p, req.precision)
                    })?,
                    None => std::sync::Arc::new(native.pack_params(p, req.precision)?),
                };
                native.infer_packed(&packed, x)
            }
            InferParams::Base => native.infer_quantized(x),
            InferParams::Delta(rec) => {
                // Transiently materialize, then pack exactly as the
                // retained-full path would — the packed views are
                // bit-identical because the inputs are.
                let base = pool.initial_params(&req.model)?;
                let packed = match cache_key {
                    Some(key) => pool.packed_for(key, req.precision, || {
                        native.pack_params(&rec.apply(&base)?, req.precision)
                    })?,
                    None => {
                        std::sync::Arc::new(native.pack_params(&rec.apply(&base)?, req.precision)?)
                    }
                };
                native.infer_packed(&packed, x)
            }
        }
    }
}

//! Job types: what a client submits ([`JobSpec`]), the handle it gets
//! back ([`JobId`]), the lifecycle it observes ([`JobState`]), and the
//! per-step stream it can subscribe to ([`JobEvent`]).

use std::path::PathBuf;

use crate::coordinator::metrics::StepRecord;
use crate::coordinator::{FinetuneConfig, FinetuneReport};

/// One fine-tuning job: a [`FinetuneConfig`] plus service-level knobs.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Artifact directory; `None` = the service's default directory.
    pub artifacts: Option<PathBuf>,
    /// The training recipe (variant, dataset, steps, engine, ...).
    pub config: FinetuneConfig,
    /// Restore this checkpoint before training and continue from its
    /// step (the loader is fast-forwarded, the LR schedule indexes by
    /// absolute step, so the resumed trajectory is bit-identical to an
    /// uninterrupted run).
    ///
    /// Caller contract: the resuming `config` must repeat the
    /// checkpointed run's recipe (dataset, samples, seed, lr0) with a
    /// larger step count.  The v1 checkpoint format records only the
    /// model name and step, so a mismatched recipe resumes on a
    /// different data/LR stream without error — the model check is the
    /// only one the file can back.
    pub resume_from: Option<PathBuf>,
    /// Save a checkpoint of the final params/state here on completion.
    pub checkpoint_to: Option<PathBuf>,
    /// Persist the finished job as a variant-store delta record
    /// (`persist:"delta"`): training is restricted to the WASI
    /// subspace, and on completion only the factor tensors are kept —
    /// the service retains NO full parameter copy for the job
    /// (DESIGN.md §Variant store).  Requires a factored variant and an
    /// attached store.
    pub persist_delta: bool,
}

impl JobSpec {
    pub fn new(config: FinetuneConfig) -> JobSpec {
        JobSpec {
            artifacts: None,
            config,
            resume_from: None,
            checkpoint_to: None,
            persist_delta: false,
        }
    }
}

/// Opaque job handle, unique within one service.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Lifecycle of a job: `Queued -> Running{step, loss} -> Done(report)`
/// or `Failed(error)`; cancellation surfaces as `Failed("cancelled")`.
#[derive(Debug, Clone)]
pub enum JobState {
    Queued,
    Running { step: usize, loss: f32 },
    Done(FinetuneReport),
    Failed(String),
}

impl JobState {
    /// Terminal states never change again.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done(_) | JobState::Failed(_))
    }

    /// Protocol label (`queued` / `running` / `done` / `failed`).
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running { .. } => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
        }
    }
}

/// One entry in a job's streamed event channel.
#[derive(Debug, Clone)]
pub enum JobEvent {
    /// The worker picked the job up and built its engine.
    Started { job: JobId, model: String, backend: &'static str },
    /// One training step completed.
    Step { job: JobId, record: StepRecord },
    /// Terminal: the job finished with a report.
    Done { job: JobId, report: FinetuneReport },
    /// Terminal: the job errored (or was cancelled).
    Failed { job: JobId, error: String },
}

impl JobEvent {
    pub fn job(&self) -> JobId {
        match self {
            JobEvent::Started { job, .. }
            | JobEvent::Step { job, .. }
            | JobEvent::Done { job, .. }
            | JobEvent::Failed { job, .. } => *job,
        }
    }
}

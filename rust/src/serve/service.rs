//! The job service: a fixed pool of worker threads executing
//! [`JobSpec`]s from a FIFO queue over a shared [`ModelPool`], with
//! per-job state tracking, cancellation, and streamed events.
//!
//! Concurrency model (DESIGN.md §serve):
//!
//! * `submit` validates the spec (artifact dir loads, variant exists),
//!   allocates a [`JobId`], creates the job's event channel, and
//!   enqueues — it never blocks on training;
//! * N worker threads pop jobs FIFO; each builds an exclusive train
//!   engine through the pool and runs `serve::runner::execute_job`;
//! * inference requests run on the *caller's* thread against the
//!   pool's shared infer engines, so they interleave freely with
//!   running jobs;
//! * determinism: jobs touch no shared mutable state besides the
//!   runtime's executable cache (append-only) and the kernel-layer
//!   thread count (bit-deterministic by construction), so concurrent
//!   jobs produce trajectories bit-identical to sequential runs.

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::coordinator::FinetuneReport;
use crate::store::VariantStore;

use super::job::{JobEvent, JobId, JobSpec, JobState};
use super::pool::{ModelPool, PoolEntry};
use super::runner::{self, InferOutput, InferParams, InferRequest, RunnerEvent};

/// The variant-store key a job's delta record persists under.
pub fn delta_key(id: JobId) -> String {
    format!("job-{id}")
}

/// What a [`FaultHook`] tells a worker to do at an injection point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Proceed normally (the default everywhere).
    None,
    /// Set the job's cancel flag, as if a client cancelled it — the
    /// runner observes the flag at its next step boundary.
    Cancel,
    /// Panic on the worker thread mid-job.  The service must contain
    /// the panic (`catch_unwind`), fail the job terminally, and keep
    /// the worker alive — the invariant the scenario harness pins.
    Panic,
}

/// Test-only fault injection: the scenario harness implements this to
/// perturb workers at deterministic points.  Hooks are called with NO
/// service locks held, and `on_step` fires before each training step is
/// applied (step index as the runner reports it, 1-based).
pub trait FaultHook: Send + Sync {
    /// Called on the worker thread right after a job leaves the queue.
    fn on_job_start(&self, _job: JobId) -> FaultAction {
        FaultAction::None
    }
    /// Called on the worker thread at each step boundary.
    fn on_step(&self, _job: JobId, _step: usize) -> FaultAction {
        FaultAction::None
    }
}

/// Service construction parameters.
#[derive(Clone)]
pub struct ServiceConfig {
    /// Default artifact directory (jobs/requests may name another).
    pub artifacts: PathBuf,
    /// Fixed worker-thread count (clamped to ≥ 1).
    pub workers: usize,
    /// Fault-injection hook (tests and the scenario harness only;
    /// `None` in production paths).
    pub faults: Option<Arc<dyn FaultHook>>,
    /// Variant-store directory (`serve --store DIR`).  `None` disables
    /// delta persistence: `persist:"delta"` submissions are rejected.
    pub store: Option<PathBuf>,
    /// Resident-set byte budget for the variant store
    /// (`--memory-budget-mb` × 2²⁰; 0 = unbounded).  Ignored without
    /// `store`.
    pub memory_budget_bytes: usize,
}

impl std::fmt::Debug for ServiceConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServiceConfig")
            .field("artifacts", &self.artifacts)
            .field("workers", &self.workers)
            .field("faults", &self.faults.is_some())
            .field("store", &self.store)
            .field("memory_budget_bytes", &self.memory_budget_bytes)
            .finish()
    }
}

impl ServiceConfig {
    pub fn new(artifacts: impl Into<PathBuf>) -> ServiceConfig {
        ServiceConfig {
            artifacts: artifacts.into(),
            workers: 2,
            faults: None,
            store: None,
            memory_budget_bytes: 0,
        }
    }

    pub fn with_workers(mut self, workers: usize) -> ServiceConfig {
        self.workers = workers;
        self
    }

    pub fn with_faults(mut self, faults: Arc<dyn FaultHook>) -> ServiceConfig {
        self.faults = Some(faults);
        self
    }

    /// Attach a variant store at `dir` with a resident budget of
    /// `budget_bytes` (0 = unbounded).
    pub fn with_store(mut self, dir: impl Into<PathBuf>, budget_bytes: usize) -> ServiceConfig {
        self.store = Some(dir.into());
        self.memory_budget_bytes = budget_bytes;
        self
    }
}

/// Best-effort text of a panic payload (`&str` / `String` payloads;
/// anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// How a finished job's personalized weights are served: the retained
/// full parameter vector, or (delta-persisted jobs) the variant-store
/// key of the subspace record to apply over the shared frozen base.
enum JobSource {
    Full(Arc<Vec<f32>>),
    Delta(String),
}

struct JobEntry {
    spec: JobSpec,
    state: JobState,
    cancel: Arc<AtomicBool>,
    /// Sender side of the event stream; dropped (set to `None`) at the
    /// terminal transition so receivers observe disconnect.
    tx: Option<Sender<JobEvent>>,
    /// Receiver side, parked here until a client claims the stream.
    rx: Option<Receiver<JobEvent>>,
    /// Final flat params of a `Done` job (personalized inference).
    final_params: Option<Arc<Vec<f32>>>,
}

struct Shared {
    pool: ModelPool,
    default_artifacts: PathBuf,
    queue: Mutex<VecDeque<JobId>>,
    queue_cond: Condvar,
    jobs: Mutex<BTreeMap<u64, JobEntry>>,
    /// Notified on every job state transition (`wait` blocks on this).
    jobs_cond: Condvar,
    next_id: AtomicU64,
    shutdown: AtomicBool,
    /// Fault-injection hook (scenario harness; `None` in production).
    faults: Option<Arc<dyn FaultHook>>,
    /// Variant store for delta-persisted jobs (`None` = persistence
    /// disabled).  Also attached to the default pool entry.
    store: Option<Arc<VariantStore>>,
}

impl Shared {
    fn send_event(tx: &Option<Sender<JobEvent>>, ev: JobEvent) {
        if let Some(tx) = tx {
            // A receiver may have been dropped without draining; that
            // must never fail the job itself.
            let _ = tx.send(ev);
        }
    }

    /// Apply a fault action at an injection point.  `Cancel` flips the
    /// job's own cancel flag (the runner observes it at the next step
    /// boundary); `Panic` unwinds — `run_one` contains it.
    fn apply_fault(action: FaultAction, id: JobId, step: usize, cancel: &AtomicBool) {
        match action {
            FaultAction::None => {}
            FaultAction::Cancel => cancel.store(true, Ordering::Relaxed),
            FaultAction::Panic => {
                panic!("injected worker death (job {id}, step {step})")
            }
        }
    }

    /// Execute one queued job on the current (worker) thread.
    fn run_one(&self, id: JobId) {
        let (spec, cancel, tx) = {
            let mut jobs = self.jobs.lock().unwrap();
            let Some(j) = jobs.get_mut(&id.0) else { return };
            if !matches!(j.state, JobState::Queued) {
                return; // cancelled while queued
            }
            j.state = JobState::Running { step: 0, loss: f32::NAN };
            (j.spec.clone(), j.cancel.clone(), j.tx.clone())
        };
        self.jobs_cond.notify_all();

        // The job body runs under `catch_unwind`: a panicking worker
        // (a kernel bug, or the fault hook's injected death) must fail
        // THIS job terminally and leave the worker thread serving the
        // queue — one bad job must never wedge the service.  The
        // closure only touches lock guards transiently (never across
        // the unwind edge), so AssertUnwindSafe is sound: a poisoned
        // Mutex would abort via the unwrap in the next locker anyway.
        let faults = self.faults.clone();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || -> Result<runner::JobOutcome> {
                if let Some(h) = &faults {
                    Self::apply_fault(h.on_job_start(id), id, 0, &cancel);
                }
                let dir = spec
                    .artifacts
                    .clone()
                    .unwrap_or_else(|| self.default_artifacts.clone());
                let entry = self.pool.open(dir)?;
                runner::execute_job(
                    &entry,
                    &spec,
                    &mut |ev| match ev {
                        RunnerEvent::Started { backend } => {
                            Self::send_event(
                                &tx,
                                JobEvent::Started {
                                    job: id,
                                    model: spec.config.model.clone(),
                                    backend,
                                },
                            );
                        }
                        RunnerEvent::Step(record) => {
                            {
                                let mut jobs = self.jobs.lock().unwrap();
                                if let Some(j) = jobs.get_mut(&id.0) {
                                    j.state = JobState::Running {
                                        step: record.step,
                                        loss: record.loss,
                                    };
                                }
                            }
                            self.jobs_cond.notify_all();
                            let step = record.step;
                            Self::send_event(&tx, JobEvent::Step { job: id, record });
                            if let Some(h) = &faults {
                                Self::apply_fault(h.on_step(id, step), id, step, &cancel);
                            }
                        }
                    },
                    &cancel,
                )
            },
        ));
        let outcome: Result<runner::JobOutcome> = match outcome {
            Ok(r) => r,
            Err(payload) => Err(anyhow!(
                "worker panicked mid-job: {}",
                panic_message(payload.as_ref())
            )),
        };
        // Persist a delta job's record BEFORE the terminal transition
        // (disk I/O outside the jobs lock): a failed write fails the
        // job — a Done delta job whose record is not on disk would have
        // nothing to serve.  The full parameter vector is dropped here;
        // the store is the job's only retained state.
        let outcome = outcome.and_then(|mut out| {
            if let Some(rec) = out.delta.take() {
                let store = self.store.as_ref().ok_or_else(|| {
                    anyhow!("delta job finished but the service has no variant store attached")
                })?;
                store.put(&delta_key(id), rec)?;
                out.final_params = Vec::new();
            }
            Ok(out)
        });

        let mut jobs = self.jobs.lock().unwrap();
        if let Some(j) = jobs.get_mut(&id.0) {
            // Terminal states never change again — belt and braces
            // against any path that could have failed the job while it
            // ran (none should exist: cancel only fails Queued jobs).
            if !j.state.is_terminal() {
                match outcome {
                    Ok(out) => {
                        Self::send_event(
                            &tx,
                            JobEvent::Done { job: id, report: out.report.clone() },
                        );
                        j.final_params = if j.spec.persist_delta {
                            None // the variant store holds the delta record
                        } else {
                            Some(Arc::new(out.final_params))
                        };
                        j.state = JobState::Done(out.report);
                    }
                    Err(e) => {
                        let error = format!("{e:#}");
                        Self::send_event(&tx, JobEvent::Failed { job: id, error: error.clone() });
                        j.state = JobState::Failed(error);
                    }
                }
            }
            j.tx = None; // disconnect the stream (with the local clone below)
        }
        drop(jobs);
        drop(tx);
        self.jobs_cond.notify_all();
    }

    fn worker_loop(self: Arc<Self>) {
        loop {
            let id = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if let Some(id) = q.pop_front() {
                        break id;
                    }
                    if self.shutdown.load(Ordering::Relaxed) {
                        return;
                    }
                    q = self.queue_cond.wait(q).unwrap();
                }
            };
            self.run_one(id);
        }
    }

    /// Fail a job that has not started running (shutdown drain / queued
    /// cancel).  Strictly `Queued` → `Failed`: a job a worker already
    /// picked up stays owned by that worker (its cancel flag, if set,
    /// stops it at the next step), so terminal states are written by
    /// exactly one party and never change again.  Caller must hold no
    /// job/queue locks.
    fn fail_if_queued(&self, id: JobId, error: &str) {
        let mut jobs = self.jobs.lock().unwrap();
        if let Some(j) = jobs.get_mut(&id.0) {
            if !matches!(j.state, JobState::Queued) {
                return;
            }
            Self::send_event(&j.tx, JobEvent::Failed { job: id, error: error.to_string() });
            j.state = JobState::Failed(error.to_string());
            j.tx = None;
        }
        drop(jobs);
        self.jobs_cond.notify_all();
    }
}

/// A running multi-session job service.  Cheap handles are not
/// clonable on purpose: ownership marks who is responsible for
/// [`Service::shutdown`] (also invoked by `Drop`).
pub struct Service {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Service {
    /// Load the default artifact directory and spawn the worker pool.
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let store = cfg
            .store
            .as_ref()
            .map(|dir| VariantStore::open(dir, cfg.memory_budget_bytes).map(Arc::new))
            .transpose()?;
        let shared = Arc::new(Shared {
            pool: ModelPool::new(),
            default_artifacts: cfg.artifacts.clone(),
            queue: Mutex::new(VecDeque::new()),
            queue_cond: Condvar::new(),
            jobs: Mutex::new(BTreeMap::new()),
            jobs_cond: Condvar::new(),
            next_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            faults: cfg.faults.clone(),
            store,
        });
        // Eager-load the default dir so a bad --artifacts fails at
        // startup, not at first submit.
        let entry = shared.pool.open(&cfg.artifacts)?;
        if let Some(store) = &shared.store {
            entry.attach_store(store.clone());
        }
        let workers = (0..cfg.workers.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("wasi-serve-worker-{i}"))
                    .spawn(move || shared.worker_loop())
                    .expect("spawn service worker")
            })
            .collect();
        Ok(Service { shared, workers: Mutex::new(workers) })
    }

    /// The service's model pool (shared runtime/manifest handles).
    pub fn pool(&self) -> &ModelPool {
        &self.shared.pool
    }

    /// The pool entry for the service's default artifact directory.
    pub fn default_entry(&self) -> Result<Arc<PoolEntry>> {
        self.shared.pool.open(&self.shared.default_artifacts)
    }

    /// Validate and enqueue a job; returns immediately with its id.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId> {
        // Validate up front so the client gets a synchronous error for
        // a bad directory/variant instead of a failed job later.
        let dir = spec
            .artifacts
            .clone()
            .unwrap_or_else(|| self.shared.default_artifacts.clone());
        let entry = self.shared.pool.open(dir)?;
        let model = entry.manifest.model(&spec.config.model)?;
        if spec.config.steps == 0 {
            return Err(anyhow!("job must run at least one step"));
        }
        if spec.persist_delta {
            // Delta persistence needs (a) an attached store, (b) the
            // service's default artifact set (store keys are scoped to
            // one artifact directory), and (c) a factored variant —
            // a vanilla model has no subspace to restrict training to.
            if self.shared.store.is_none() {
                return Err(anyhow!(
                    "persist:\"delta\" requires a variant store; start the \
                     service with --store DIR"
                ));
            }
            if let Some(d) = spec.artifacts.as_deref() {
                if d != self.shared.default_artifacts {
                    return Err(anyhow!(
                        "persist:\"delta\" jobs must train against the service's \
                         default artifact directory (the store serves one shared base)"
                    ));
                }
            }
            if model.weight_ranks.is_empty() {
                return Err(anyhow!(
                    "model {} has no factored (subspace) layers; delta \
                     persistence requires a WASI variant",
                    model.name
                ));
            }
        }

        let id = JobId(self.shared.next_id.fetch_add(1, Ordering::Relaxed));
        let (tx, rx) = channel();
        {
            let mut jobs = self.shared.jobs.lock().unwrap();
            jobs.insert(
                id.0,
                JobEntry {
                    spec,
                    state: JobState::Queued,
                    cancel: Arc::new(AtomicBool::new(false)),
                    tx: Some(tx),
                    rx: Some(rx),
                    final_params: None,
                },
            );
        }
        {
            // The shutdown flag is checked under the queue lock:
            // `shutdown` sets it before draining under the same lock,
            // so a job can never slip in after the drain and sit
            // Queued forever with no worker left to run it.
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.shutdown.load(Ordering::Relaxed) {
                drop(q);
                self.shared.jobs.lock().unwrap().remove(&id.0);
                return Err(anyhow!("service is shut down"));
            }
            q.push_back(id);
        }
        self.shared.queue_cond.notify_one();
        Ok(id)
    }

    /// Number of jobs waiting in the FIFO queue (telemetry).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Number of jobs currently in the `Running` state (telemetry).
    pub fn running_count(&self) -> usize {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .values()
            .filter(|j| matches!(j.state, JobState::Running { .. }))
            .count()
    }

    /// Current state of a job (`None` = unknown id).
    pub fn status(&self, id: JobId) -> Option<JobState> {
        self.shared.jobs.lock().unwrap().get(&id.0).map(|j| j.state.clone())
    }

    /// All job ids with their states, submission-ordered.
    pub fn jobs(&self) -> Vec<(JobId, JobState)> {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .iter()
            .map(|(id, j)| (JobId(*id), j.state.clone()))
            .collect()
    }

    /// Claim a job's event stream (single consumer; `None` if the id is
    /// unknown or the stream was already claimed).  The stream yields
    /// `Started`/`Step` events and ends with `Done`/`Failed`, after
    /// which the channel disconnects.
    pub fn take_events(&self, id: JobId) -> Option<Receiver<JobEvent>> {
        self.shared.jobs.lock().unwrap().get_mut(&id.0).and_then(|j| j.rx.take())
    }

    /// Drain the events buffered since the last call without claiming
    /// the stream (`None` = unknown id or stream claimed elsewhere).
    pub fn drain_events(&self, id: JobId) -> Option<Vec<JobEvent>> {
        let jobs = self.shared.jobs.lock().unwrap();
        let rx = jobs.get(&id.0)?.rx.as_ref()?;
        let mut out = Vec::new();
        while let Ok(ev) = rx.try_recv() {
            out.push(ev);
        }
        Some(out)
    }

    /// Block until the job reaches a terminal state; `Done` yields the
    /// report, `Failed` the error.
    pub fn wait(&self, id: JobId) -> Result<FinetuneReport> {
        let mut jobs = self.shared.jobs.lock().unwrap();
        loop {
            match jobs.get(&id.0) {
                None => return Err(anyhow!("unknown job {id}")),
                Some(j) => match &j.state {
                    JobState::Done(report) => return Ok(report.clone()),
                    JobState::Failed(e) => return Err(anyhow!("job {id} failed: {e}")),
                    _ => {}
                },
            }
            jobs = self.shared.jobs_cond.wait(jobs).unwrap();
        }
    }

    /// Request cancellation.  A still-queued job fails immediately; a
    /// running job observes the flag at its next step boundary and
    /// fails from its own worker.  Returns false for unknown ids and
    /// jobs already terminal.
    pub fn cancel(&self, id: JobId) -> bool {
        {
            let jobs = self.shared.jobs.lock().unwrap();
            match jobs.get(&id.0) {
                None => return false,
                Some(j) if j.state.is_terminal() => return false,
                Some(j) => j.cancel.store(true, Ordering::Relaxed),
            }
        }
        // Dequeue FIRST so no worker can pick the job up afterwards,
        // then fail it only if it is still Queued — a worker that
        // already popped it owns its state transitions (it either sees
        // the Failed write below while still Queued and skips, or runs
        // until the cancel flag stops it).  Exactly one party ever
        // writes the terminal state.
        self.shared.queue.lock().unwrap().retain(|q| *q != id);
        self.shared.fail_if_queued(id, "cancelled at client request");
        true
    }

    /// Drop a terminal job's record — report, buffered events, the
    /// retained final params, AND (delta-persisted jobs) the job's
    /// variant-store record, both resident and on disk.  Long-lived
    /// services call this (protocol `forget`) once a job's results are
    /// consumed; without it every finished job pins one model-sized
    /// param vector (or one delta record) forever.  Returns false for
    /// unknown ids and jobs that are still queued/running.
    pub fn forget(&self, id: JobId) -> bool {
        let (persisted, dir) = {
            let mut jobs = self.shared.jobs.lock().unwrap();
            match jobs.get(&id.0) {
                Some(j) if j.state.is_terminal() => {
                    let persisted = j.spec.persist_delta;
                    let dir = j
                        .spec
                        .artifacts
                        .clone()
                        .unwrap_or_else(|| self.shared.default_artifacts.clone());
                    jobs.remove(&id.0);
                    (persisted, dir)
                }
                _ => return false,
            }
        };
        // Drop the job's cached packed inference params (if its pool
        // entry is even loaded) — a forgotten job must pin nothing.
        if let Some(entry) = self.shared.pool.peek(&dir) {
            entry.invalidate_packed(&delta_key(id));
        }
        if persisted {
            if let Some(store) = &self.shared.store {
                // Best-effort: a Failed delta job never wrote a record,
                // and forget must still drop its bookkeeping.
                let _ = store.remove(&delta_key(id));
            }
        }
        true
    }

    /// Final flat params of a `Done` job (personalized inference).
    pub fn job_params(&self, id: JobId) -> Option<Arc<Vec<f32>>> {
        self.shared.jobs.lock().unwrap().get(&id.0).and_then(|j| j.final_params.clone())
    }

    /// Parameter source of a `Done` job, checked against the variant
    /// AND artifact directory the caller wants to serve — a
    /// params-length coincidence (same-named variant from another
    /// directory, or two eps variants with equal shapes) must never
    /// silently serve the wrong weights.  A delta-persisted job yields
    /// its store key; everything else yields the retained full vector.
    fn job_source_for_model(
        &self,
        id: JobId,
        model: &str,
        dir: &std::path::Path,
    ) -> Result<JobSource> {
        let jobs = self.shared.jobs.lock().unwrap();
        let j = jobs
            .get(&id.0)
            .ok_or_else(|| anyhow!("unknown job {id}"))?;
        if j.spec.config.model != model {
            return Err(anyhow!(
                "job {id} trained variant {:?}, not {model:?} — personalized \
                 params are variant-specific",
                j.spec.config.model
            ));
        }
        let job_dir = j
            .spec
            .artifacts
            .clone()
            .unwrap_or_else(|| self.shared.default_artifacts.clone());
        if job_dir != dir {
            return Err(anyhow!(
                "job {id} trained against artifacts {}, not {} — personalized \
                 params are artifact-set-specific",
                job_dir.display(),
                dir.display()
            ));
        }
        if j.spec.persist_delta {
            return match &j.state {
                JobState::Done(_) => Ok(JobSource::Delta(delta_key(id))),
                other => Err(anyhow!(
                    "job {id} has no delta record yet (state: {})",
                    other.label()
                )),
            };
        }
        j.final_params
            .clone()
            .map(JobSource::Full)
            .ok_or_else(|| {
                anyhow!("job {id} has no final params yet (state: {})", j.state.label())
            })
    }

    /// Pool inference on the caller's thread; interleaves with running
    /// jobs.  `artifacts`/`job` select whose params to serve: a `Done`
    /// job's personalized weights (a retained full vector, or a delta
    /// record fetched from the variant store and applied against the
    /// shared frozen base at request time), or the variant's pretrained
    /// params.
    pub fn infer(
        &self,
        artifacts: Option<&std::path::Path>,
        req: &InferRequest,
        job: Option<JobId>,
    ) -> Result<InferOutput> {
        let mut outs = self.infer_batch(artifacts, std::slice::from_ref(req), job)?;
        outs.pop().ok_or_else(|| anyhow!("infer batch returned no output"))
    }

    /// [`Service::infer`] over a micro-batch: every request must target
    /// the same `(model, engine, precision)` pool entry and the same
    /// parameter source (`artifacts`/`job`), which is exactly the
    /// coalescing key of the network front-end's batcher
    /// ([`crate::net::BatchKey`]).  The group's input rows run through
    /// ONE stacked engine call and fan back out per request,
    /// bit-identical to serving each alone
    /// ([`runner::run_infer_batch_keyed`]).
    pub fn infer_batch(
        &self,
        artifacts: Option<&std::path::Path>,
        reqs: &[InferRequest],
        job: Option<JobId>,
    ) -> Result<Vec<InferOutput>> {
        let first = reqs.first().ok_or_else(|| anyhow!("empty infer batch"))?;
        let dir = artifacts
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| self.shared.default_artifacts.clone());
        let entry = self.shared.pool.open(&dir)?;
        match job {
            None => runner::run_infer_batch_keyed(&entry, reqs, InferParams::Base, None),
            Some(id) => {
                // A job's key doubles as the packed-params cache key:
                // repeated reduced-precision requests against one Done
                // job quantize+pack once (invalidated by `forget`).
                let cache_key = delta_key(id);
                match self.job_source_for_model(id, &first.model, &dir)? {
                    JobSource::Full(p) => runner::run_infer_batch_keyed(
                        &entry,
                        reqs,
                        InferParams::Full(&p),
                        Some(&cache_key),
                    ),
                    JobSource::Delta(key) => {
                        let store = self.shared.store.as_ref().ok_or_else(|| {
                            anyhow!("job {id} persisted a delta but no store is attached")
                        })?;
                        // `get` reloads from disk if the record was paged
                        // out — eviction must never fail a request.
                        let rec = store.get(&key)?;
                        runner::run_infer_batch_keyed(
                            &entry,
                            reqs,
                            InferParams::Delta(&rec),
                            Some(&cache_key),
                        )
                    }
                }
            }
        }
    }

    /// The service's variant store, when one is attached.
    pub fn store(&self) -> Option<Arc<VariantStore>> {
        self.shared.store.clone()
    }

    /// Stop accepting work, fail still-queued jobs, cancel running ones
    /// at their next step boundary, and join the workers — shutdown is
    /// prompt even mid-way through a long job.  Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        let drained: Vec<JobId> = {
            let mut q = self.shared.queue.lock().unwrap();
            q.drain(..).collect()
        };
        for id in drained {
            self.shared.fail_if_queued(id, "service shut down before the job ran");
        }
        // Running jobs stop at their next step boundary (their workers
        // write the terminal Failed state), so the join below is
        // bounded by one training step, not a whole job.
        for j in self.shared.jobs.lock().unwrap().values() {
            if !j.state.is_terminal() {
                j.cancel.store(true, Ordering::Relaxed);
            }
        }
        self.shared.queue_cond.notify_all();
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        for w in workers {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FinetuneConfig;
    use crate::engine::demo::{write_demo_artifacts, DemoConfig};
    use crate::engine::EngineKind;

    fn demo_service(tag: &str, workers: usize) -> Service {
        let dir = std::env::temp_dir().join(format!("wasi_service_test_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        Service::start(ServiceConfig::new(dir).with_workers(workers)).unwrap()
    }

    fn quick_cfg(model: &str, steps: usize) -> FinetuneConfig {
        FinetuneConfig::builder()
            .model(model)
            .samples(32)
            .steps(steps)
            .lr0(0.1)
            .engine(EngineKind::Native)
            .build()
    }

    #[test]
    fn submit_wait_done_with_events() {
        let svc = demo_service("basic", 1);
        let id = svc.submit(JobSpec::new(quick_cfg("vit_demo_wasi_eps80", 5))).unwrap();
        let rx = svc.take_events(id).expect("fresh job exposes its stream");
        assert!(svc.take_events(id).is_none(), "stream is single-consumer");
        let report = svc.wait(id).unwrap();
        assert_eq!(report.engine, "native");
        let events: Vec<JobEvent> = rx.iter().collect();
        assert!(matches!(events.first(), Some(JobEvent::Started { .. })), "{events:?}");
        let steps = events.iter().filter(|e| matches!(e, JobEvent::Step { .. })).count();
        assert_eq!(steps, 5);
        assert!(matches!(events.last(), Some(JobEvent::Done { .. })));
        assert!(matches!(svc.status(id), Some(JobState::Done(_))));
        assert!(svc.job_params(id).is_some());
        svc.shutdown();
    }

    #[test]
    fn submit_validates_model_synchronously() {
        let svc = demo_service("validate", 1);
        let err = svc.submit(JobSpec::new(quick_cfg("no_such_model", 3))).unwrap_err();
        assert!(format!("{err:#}").contains("no_such_model"), "{err:#}");
        let err = svc.submit(JobSpec::new(quick_cfg("vit_demo_vanilla", 0))).unwrap_err();
        assert!(format!("{err:#}").contains("at least one step"), "{err:#}");
        svc.shutdown();
    }

    #[test]
    fn cancel_queued_job_fails_fast() {
        // One worker busy with a long job -> the second job sits queued
        // and must fail immediately on cancel.
        let svc = demo_service("cancel", 1);
        // Long enough that it is still running when cancelled below
        // (cancellation is polled at step boundaries, so the cancel
        // itself resolves fast).
        let long = svc.submit(JobSpec::new(quick_cfg("vit_demo_vanilla", 5000))).unwrap();
        let queued = svc.submit(JobSpec::new(quick_cfg("vit_demo_wasi_eps80", 50))).unwrap();
        assert!(svc.cancel(queued));
        match svc.wait(queued) {
            Err(e) => assert!(format!("{e:#}").contains("cancelled"), "{e:#}"),
            Ok(_) => panic!("cancelled queued job must not complete"),
        }
        // Cancel the running job too; it stops at a step boundary.
        assert!(svc.cancel(long));
        assert!(svc.wait(long).is_err());
        assert!(!svc.cancel(long), "terminal jobs report not-cancellable");
        svc.shutdown();
    }

    #[test]
    fn infer_interleaves_and_serves_job_params() {
        let svc = demo_service("infer", 2);
        let req = InferRequest {
            model: "vit_demo_wasi_eps80".into(),
            engine: EngineKind::Auto,
            precision: crate::precision::Precision::F32,
            seed: 233,
            x: None,
        };
        // Pretrained params while a job is running.
        let id = svc.submit(JobSpec::new(quick_cfg("vit_demo_wasi_eps80", 30))).unwrap();
        let out = svc.infer(None, &req, None).unwrap();
        assert_eq!(out.backend, "native");
        assert!(out.correct.is_some());
        assert_eq!(out.batch, out.preds.len());
        // Unknown-job params error before the job is done... (id+1 never exists)
        assert!(svc.infer(None, &req, Some(JobId(id.0 + 1000))).is_err());
        svc.wait(id).unwrap();
        // ...and resolve after it finishes.
        let personalized = svc.infer(None, &req, Some(id)).unwrap();
        assert_eq!(personalized.batch, out.batch);
        // A job's personalized params are variant-specific: asking a
        // DIFFERENT model to serve them must error even if the flat
        // lengths happened to coincide.
        let cross = InferRequest { model: "vit_demo_vanilla".into(), ..req.clone() };
        let err = svc.infer(None, &cross, Some(id)).unwrap_err();
        assert!(format!("{err:#}").contains("variant"), "{err:#}");
        svc.shutdown();
    }

    #[test]
    fn forget_releases_terminal_jobs_only() {
        let svc = demo_service("forget", 1);
        // One worker busy on a long job keeps the second deterministically
        // queued: a non-terminal job must not be forgettable.
        let long = svc.submit(JobSpec::new(quick_cfg("vit_demo_vanilla", 5000))).unwrap();
        let queued = svc.submit(JobSpec::new(quick_cfg("vit_demo_wasi_eps80", 3))).unwrap();
        assert!(!svc.forget(queued), "queued jobs are not forgettable");
        assert!(svc.cancel(long));
        assert!(svc.wait(long).is_err());
        let report = svc.wait(queued);
        assert!(report.is_ok(), "{report:?}");
        assert!(svc.forget(queued), "done jobs are forgettable");
        assert!(svc.status(queued).is_none(), "forgotten job must vanish");
        assert!(svc.job_params(queued).is_none());
        assert!(!svc.forget(queued), "double forget reports false");
        svc.shutdown();
    }

    #[test]
    fn delta_jobs_persist_to_store_and_forget_drops_the_record() {
        let dir = std::env::temp_dir().join("wasi_service_test_delta");
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        let store_dir = dir.join("store");
        let svc = Service::start(
            ServiceConfig::new(dir).with_workers(1).with_store(&store_dir, 64 << 20),
        )
        .unwrap();
        // A vanilla variant has no subspace to persist...
        let mut bad = JobSpec::new(quick_cfg("vit_demo_vanilla", 3));
        bad.persist_delta = true;
        let err = svc.submit(bad).unwrap_err();
        assert!(format!("{err:#}").contains("no factored"), "{err:#}");
        // ...a WASI variant persists only its factors.
        let mut spec = JobSpec::new(quick_cfg("vit_demo_wasi_eps80", 5));
        spec.persist_delta = true;
        let id = svc.submit(spec).unwrap();
        svc.wait(id).unwrap();
        assert!(svc.job_params(id).is_none(), "delta jobs retain no full params");
        let store = svc.store().unwrap();
        assert!(store.is_resident(&delta_key(id)), "record lands resident");
        let req = InferRequest {
            model: "vit_demo_wasi_eps80".into(),
            engine: EngineKind::Auto,
            precision: crate::precision::Precision::F32,
            seed: 233,
            x: None,
        };
        let out = svc.infer(None, &req, Some(id)).unwrap();
        assert_eq!(out.batch, out.preds.len());
        // Eviction must be transparent: page everything out, infer again.
        store.evict_all();
        let after = svc.infer(None, &req, Some(id)).unwrap();
        assert_eq!(out.preds, after.preds, "reload must be bit-identical");
        assert!(svc.forget(id));
        assert!(store.list().unwrap().is_empty(), "forget drops the disk record");
        svc.shutdown();

        // Without an attached store, delta submissions are rejected.
        let svc = demo_service("delta_nostore", 1);
        let mut spec = JobSpec::new(quick_cfg("vit_demo_wasi_eps80", 3));
        spec.persist_delta = true;
        let err = svc.submit(spec).unwrap_err();
        assert!(format!("{err:#}").contains("--store"), "{err:#}");
        svc.shutdown();
    }

    /// A worker panic mid-job (injected via the fault hook) must fail
    /// that job terminally and leave the worker thread alive for the
    /// next job — the containment invariant the soak harness pins.
    #[test]
    fn worker_panic_is_contained_and_worker_survives() {
        struct PanicSecondStep;
        impl FaultHook for PanicSecondStep {
            fn on_step(&self, job: JobId, step: usize) -> FaultAction {
                // Kill only the first job, at its second step.
                if job.0 == 1 && step == 2 {
                    FaultAction::Panic
                } else {
                    FaultAction::None
                }
            }
        }
        let dir = std::env::temp_dir().join("wasi_service_test_panic");
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        let svc = Service::start(
            ServiceConfig::new(dir)
                .with_workers(1)
                .with_faults(Arc::new(PanicSecondStep)),
        )
        .unwrap();
        // Silence the default panic-hook backtrace for the injected
        // death (process-wide filter; real panics still print).
        crate::scenario::faults::silence_injected_panics();
        let doomed = svc.submit(JobSpec::new(quick_cfg("vit_demo_vanilla", 10))).unwrap();
        let err = svc.wait(doomed).unwrap_err();
        assert!(
            format!("{err:#}").contains("worker panicked mid-job"),
            "{err:#}"
        );
        assert!(format!("{err:#}").contains("injected worker death"), "{err:#}");
        // The single worker survived the unwind: a second job runs.
        let next = svc.submit(JobSpec::new(quick_cfg("vit_demo_wasi_eps80", 3))).unwrap();
        svc.wait(next).unwrap();
        assert_eq!(svc.queue_depth(), 0);
        assert_eq!(svc.running_count(), 0);
        svc.shutdown();
    }

    #[test]
    fn shutdown_fails_queued_jobs_and_is_idempotent() {
        let svc = demo_service("shutdown", 1);
        // Two jobs, one worker: at most the first is running when
        // shutdown drains the queue immediately after submit, so the
        // second must fail without running (if the worker hadn't even
        // popped the first yet, both drain — also fine).
        let _first = svc.submit(JobSpec::new(quick_cfg("vit_demo_vanilla", 40))).unwrap();
        let queued = svc.submit(JobSpec::new(quick_cfg("vit_demo_wasi_eps80", 40))).unwrap();
        svc.shutdown();
        svc.shutdown();
        match svc.status(queued) {
            Some(JobState::Failed(e)) => assert!(e.contains("shut down"), "{e}"),
            other => panic!("queued job must fail on shutdown, got {other:?}"),
        }
        assert!(svc.submit(JobSpec::new(quick_cfg("vit_demo_vanilla", 3))).is_err());
    }
}

//! JSON-lines protocol for `wasi-train serve`: one request object per
//! stdin line, one (or for streamed events, several) response object(s)
//! per line on stdout.
//!
//! Requests: `{"cmd": "submit"|"status"|"events"|"infer"|"cancel"|
//! "forget"|"store"|"store-stats"|"stats"|"shutdown", ...}`.  Every
//! response carries `"ok"` plus either the payload or `"error"`.  See
//! DESIGN.md §serve for the full schema and README for a transcript.
//!
//! The same protocol runs over two transports: newline-delimited on
//! stdio (this module's [`serve_lines`]) and length-prefix-framed over
//! TCP (`crate::net`, `serve --listen`), which reuses [`handle_line`]
//! per frame and threads an optional request `"id"` through at the
//! framing layer.

use std::io::{BufRead, Write};
use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::coordinator::FinetuneConfig;
use crate::util::json::{arr, finite_num as fnum, num, obj, str as jstr, Json};

use super::job::{JobEvent, JobId, JobSpec, JobState};
use super::runner::InferRequest;
use super::service::Service;

/// Accepted keys of the `infer` command (one definition for the stdio
/// dispatch table and the socket front-end's [`parse_infer_frame`]).
pub(crate) const INFER_KEYS: &[&str] =
    &["model", "engine", "precision", "seed", "x", "job", "artifacts"];

/// What the stdio loop should do after a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flow {
    Continue,
    Shutdown,
}

pub(crate) fn error_line(cmd: &str, e: &anyhow::Error) -> Json {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("cmd", jstr(cmd)),
        ("error", jstr(format!("{e:#}"))),
    ])
}

fn state_fields(state: &JobState, fields: &mut Vec<(&'static str, Json)>) {
    fields.push(("state", jstr(state.label())));
    match state {
        JobState::Queued => {}
        JobState::Running { step, loss } => {
            fields.push(("step", num(*step as f64)));
            fields.push(("loss", fnum(*loss as f64)));
        }
        JobState::Done(report) => fields.push(("report", report.to_json())),
        JobState::Failed(e) => fields.push(("error", jstr(e.clone()))),
    }
}

fn event_json(ev: &JobEvent) -> Json {
    let mut fields: Vec<(&'static str, Json)> = vec![
        ("ok", Json::Bool(true)),
        ("job", num(ev.job().0 as f64)),
    ];
    match ev {
        JobEvent::Started { model, backend, .. } => {
            fields.push(("event", jstr("started")));
            fields.push(("model", jstr(model.clone())));
            fields.push(("engine", jstr(*backend)));
        }
        JobEvent::Step { record, .. } => {
            fields.push(("event", jstr("step")));
            fields.push(("step", num(record.step as f64)));
            fields.push(("loss", fnum(record.loss as f64)));
            fields.push(("acc", fnum(record.accuracy as f64)));
            fields.push(("lr", num(record.lr as f64)));
            fields.push(("ms", num(record.seconds * 1e3)));
        }
        JobEvent::Done { report, .. } => {
            fields.push(("event", jstr("done")));
            fields.push(("report", report.to_json()));
        }
        JobEvent::Failed { error, .. } => {
            fields.push(("event", jstr("failed")));
            fields.push(("error", jstr(error.clone())));
        }
    }
    obj(fields)
}

/// Reject request keys outside the command's accepted set — the
/// protocol twin of the CLI's unknown-`--option` rejection, so a
/// misspelled `"step"` errors instead of silently training the default
/// step count.
fn check_keys(req: &Json, cmd: &str, accepted: &[&str]) -> Result<()> {
    let Some(m) = req.as_obj() else {
        return Err(anyhow!("request must be a JSON object"));
    };
    for k in m.keys() {
        if k != "cmd" && !accepted.contains(&k.as_str()) {
            return Err(anyhow!(
                "unknown key {k:?} for {cmd:?}; accepted: {}",
                accepted.join(", ")
            ));
        }
    }
    Ok(())
}

fn req_usize(req: &Json, key: &str) -> Result<Option<usize>> {
    match req.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| Some(n as usize))
            .ok_or_else(|| anyhow!("{key:?} must be a non-negative integer")),
    }
}

fn req_job(req: &Json) -> Result<JobId> {
    Ok(JobId(
        req_usize(req, "job")?.ok_or_else(|| anyhow!("missing \"job\""))? as u64,
    ))
}

/// Optional string-valued key as a path; a present-but-wrongly-typed
/// value is an error, never a silent `None` (a mistyped `resume_from`
/// must not silently train from scratch).
fn req_path(req: &Json, key: &str) -> Result<Option<PathBuf>> {
    match req.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(|s| Some(PathBuf::from(s)))
            .ok_or_else(|| anyhow!("{key:?} must be a string")),
    }
}

/// Optional boolean key, type-strict like [`req_path`].
fn req_bool(req: &Json, key: &str) -> Result<bool> {
    match req.get(key) {
        None => Ok(false),
        Some(v) => v.as_bool().ok_or_else(|| anyhow!("{key:?} must be a boolean")),
    }
}

/// Parse a `submit` request into a [`JobSpec`] (defaults mirror
/// `wasi-train train`, minus verbosity — serve streams events instead).
fn parse_submit(req: &Json) -> Result<JobSpec> {
    let model = req
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("submit requires \"model\""))?;
    let mut b = FinetuneConfig::builder().model(model);
    if let Some(d) = req.get("dataset") {
        b = b.dataset(d.as_str().ok_or_else(|| anyhow!("\"dataset\" must be a string"))?);
    }
    if let Some(steps) = req_usize(req, "steps")? {
        b = b.steps(steps);
    }
    if let Some(samples) = req_usize(req, "samples")? {
        b = b.samples(samples);
    }
    if let Some(seed) = req_usize(req, "seed")? {
        b = b.seed(seed as u64);
    }
    if let Some(lr) = req.get("lr") {
        let lr = lr.as_f64().ok_or_else(|| anyhow!("\"lr\" must be a number"))?;
        // `1e999` parses to +inf; an infinite/NaN learning rate would
        // silently destroy the params mid-train, so reject it here.
        if !lr.is_finite() {
            return Err(anyhow!("\"lr\" must be finite"));
        }
        b = b.lr0(lr as f32);
    }
    if let Some(engine) = req.get("engine") {
        let s = engine.as_str().ok_or_else(|| anyhow!("\"engine\" must be a string"))?;
        b = b.engine(s.parse()?);
    }
    if let Some(precision) = req.get("precision") {
        let s = precision
            .as_str()
            .ok_or_else(|| anyhow!("\"precision\" must be a string"))?;
        b = b.precision(s.parse()?);
    }
    let mut spec = JobSpec::new(b.build());
    spec.artifacts = req_path(req, "artifacts")?;
    spec.resume_from = req_path(req, "resume_from")?;
    spec.checkpoint_to = req_path(req, "checkpoint_to")?;
    // `persist:"delta"` restricts training to the subspace and keeps
    // only the factor record (DESIGN.md §Variant store); "full" is the
    // default retained-params behavior, accepted for explicitness.
    match req.get("persist") {
        None => {}
        Some(v) => match v.as_str() {
            Some("full") => {}
            Some("delta") => spec.persist_delta = true,
            _ => return Err(anyhow!("\"persist\" must be \"delta\" or \"full\"")),
        },
    }
    Ok(spec)
}

fn parse_infer(req: &Json) -> Result<InferRequest> {
    let model = req
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("infer requires \"model\""))?;
    let engine = match req.get("engine").and_then(|v| v.as_str()) {
        Some(s) => s.parse()?,
        None => crate::engine::EngineKind::Auto,
    };
    let precision = match req.get("precision") {
        None => crate::precision::Precision::F32,
        Some(v) => v
            .as_str()
            .ok_or_else(|| anyhow!("\"precision\" must be a string"))?
            .parse()?,
    };
    let x = match req.get("x") {
        None => None,
        Some(v) => {
            let xs = v
                .f64_vec()
                .map_err(|_| anyhow!("\"x\" must be an array of numbers"))?;
            // NaN/inf inputs (e.g. `1e999`) would propagate through the
            // forward pass into garbage predictions — error in-band.
            if xs.iter().any(|f| !f.is_finite()) {
                return Err(anyhow!("\"x\" values must all be finite"));
            }
            Some(xs.into_iter().map(|f| f as f32).collect::<Vec<f32>>())
        }
    };
    Ok(InferRequest {
        model: model.to_string(),
        engine,
        precision,
        seed: req_usize(req, "seed")?.unwrap_or(233) as u64,
        x,
    })
}

/// Parse a full `infer` request frame — key validation, the
/// [`InferRequest`] itself, and its parameter-source selectors — shared
/// by [`handle_line`]'s dispatch and the socket front-end's
/// micro-batching path (`crate::net`), so both transports accept and
/// reject exactly the same requests.
pub(crate) fn parse_infer_frame(
    req: &Json,
) -> Result<(InferRequest, Option<PathBuf>, Option<JobId>)> {
    check_keys(req, "infer", INFER_KEYS)?;
    let ireq = parse_infer(req)?;
    let artifacts = req_path(req, "artifacts")?;
    let job = req_usize(req, "job")?.map(|j| JobId(j as u64));
    Ok((ireq, artifacts, job))
}

/// Render one infer result as its protocol response object (shared by
/// the dispatch arm and the socket front-end).
pub(crate) fn infer_response(model: &str, out: &super::runner::InferOutput) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("cmd", jstr("infer")),
        ("model", jstr(model)),
        ("engine", jstr(out.backend.clone())),
        ("precision", jstr(out.precision.to_string())),
        ("batch", num(out.batch as f64)),
        ("preds", arr(out.preds.iter().map(|p| num(*p as f64)))),
    ];
    if let Some(c) = out.correct {
        fields.push(("correct", num(c as f64)));
    }
    obj(fields)
}

/// Service-level gauges for the `stats` command (the socket front-end
/// appends its connection/batching counters to these).
pub fn service_stat_fields(svc: &Service) -> Vec<(&'static str, Json)> {
    let entry = svc.default_entry().ok();
    vec![
        ("queue_depth", num(svc.queue_depth() as f64)),
        ("running", num(svc.running_count() as f64)),
        ("jobs", num(svc.jobs().len() as f64)),
        (
            "pool_infer_loads",
            num(entry.as_ref().map(|e| e.infer_loads()).unwrap_or(0) as f64),
        ),
        (
            "pool_infer_evictions",
            num(entry.as_ref().map(|e| e.infer_evictions()).unwrap_or(0) as f64),
        ),
    ]
}

/// The attached variant store, or the in-band error every store command
/// answers when the service was started without `--store`.
fn no_store_err(svc: &Service) -> Result<std::sync::Arc<crate::store::VariantStore>> {
    svc.store().ok_or_else(|| {
        anyhow!("no variant store attached; start the service with --store DIR")
    })
}

/// [`crate::store::StoreStats`] as protocol/report JSON fields (shared
/// with the soak report and `wasi-train store`).
pub fn store_stat_fields(s: &crate::store::StoreStats) -> Vec<(&'static str, Json)> {
    vec![
        ("resident", num(s.resident as f64)),
        ("resident_bytes", num(s.resident_bytes as f64)),
        ("budget_bytes", num(s.budget_bytes as f64)),
        ("disk_records", num(s.disk_records as f64)),
        ("disk_bytes", num(s.disk_bytes as f64)),
        ("hits", num(s.hits as f64)),
        ("misses", num(s.misses as f64)),
        ("reloads", num(s.reloads as f64)),
        ("evictions", num(s.evictions as f64)),
        ("puts", num(s.puts as f64)),
    ]
}

/// Handle one request line, writing response line(s) to `out`.  Request
/// errors become `{"ok":false,...}` lines; only I/O failures propagate.
pub fn handle_line(svc: &Service, line: &str, out: &mut dyn Write) -> std::io::Result<Flow> {
    let (cmd, response) = match Json::parse(line) {
        Err(e) => ("?".to_string(), Err(anyhow!("bad request JSON: {e:#}"))),
        Ok(req) => {
            let cmd = req
                .get("cmd")
                .and_then(|v| v.as_str())
                .unwrap_or("?")
                .to_string();
            let r = dispatch(svc, &cmd, &req, out)?;
            (cmd, r)
        }
    };
    // Only an ACCEPTED shutdown request stops the session — a rejected
    // one (unknown key) was reported as an error and must not execute
    // its side effect.
    let accepted_shutdown = cmd == "shutdown" && response.is_ok();
    match response {
        Ok(Some(json)) => writeln!(out, "{json}")?,
        Ok(None) => {} // streamed its own lines
        Err(e) => writeln!(out, "{}", error_line(&cmd, &e))?,
    }
    Ok(if accepted_shutdown { Flow::Shutdown } else { Flow::Continue })
}

/// Dispatch one parsed request.  `Ok(Some(_))` = single response line,
/// `Ok(None)` = the handler streamed lines itself, `Err` = request
/// error (reported, not fatal).  The outer `io::Result` carries real
/// write failures.
fn dispatch(
    svc: &Service,
    cmd: &str,
    req: &Json,
    out: &mut dyn Write,
) -> std::io::Result<Result<Option<Json>>> {
    // Key validation runs only for KNOWN commands — a misspelled cmd
    // must surface the unknown-cmd error below, not a misleading
    // unknown-key complaint with an empty accepted set.
    let accepted: Option<&[&str]> = match cmd {
        "submit" => Some(&[
            "model", "dataset", "steps", "samples", "seed", "lr", "engine", "precision",
            "artifacts", "resume_from", "checkpoint_to", "persist",
        ]),
        "status" | "cancel" | "forget" => Some(&["job"]),
        "events" => Some(&["job", "wait"]),
        "infer" => Some(INFER_KEYS),
        "store" | "store-stats" | "stats" => Some(&[]),
        "shutdown" => Some(&[]),
        _ => None,
    };
    if let Some(accepted) = accepted {
        if let Err(e) = check_keys(req, cmd, accepted) {
            return Ok(Err(e));
        }
    }
    let result: Result<Option<Json>> = match cmd {
        "submit" => parse_submit(req).and_then(|spec| {
            let id = svc.submit(spec)?;
            Ok(Some(obj(vec![
                ("ok", Json::Bool(true)),
                ("cmd", jstr("submit")),
                ("job", num(id.0 as f64)),
                ("state", jstr("queued")),
            ])))
        }),
        "status" => req_job(req).and_then(|id| {
            let state = svc.status(id).ok_or_else(|| anyhow!("unknown job {id}"))?;
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("cmd", jstr("status")),
                ("job", num(id.0 as f64)),
            ];
            state_fields(&state, &mut fields);
            Ok(Some(obj(fields)))
        }),
        "events" => {
            match req_bool(req, "wait").and_then(|wait| req_job(req).map(|id| (id, wait))) {
                Err(e) => Err(e),
                Ok((id, true)) => {
                    // Stream: claim the receiver and emit one line per
                    // event until the job's terminal event disconnects
                    // the channel, then a final status line.
                    match svc.take_events(id) {
                        None if svc.status(id).is_none() => Err(anyhow!("unknown job {id}")),
                        None => Err(anyhow!(
                            "job {id}'s event stream was already claimed; poll with \
                             {{\"cmd\":\"status\"}} instead"
                        )),
                        Some(rx) => {
                            for ev in rx.iter() {
                                writeln!(out, "{}", event_json(&ev))?;
                                out.flush()?;
                            }
                            match svc.status(id) {
                                None => Err(anyhow!("job {id} vanished")),
                                Some(state) => {
                                    let mut fields = vec![
                                        ("ok", Json::Bool(true)),
                                        ("cmd", jstr("events")),
                                        ("job", num(id.0 as f64)),
                                    ];
                                    state_fields(&state, &mut fields);
                                    Ok(Some(obj(fields)))
                                }
                            }
                        }
                    }
                }
                Ok((id, false)) => match svc.drain_events(id) {
                    None if svc.status(id).is_none() => Err(anyhow!("unknown job {id}")),
                    None => Err(anyhow!("job {id}'s event stream was already claimed")),
                    Some(events) => {
                        let state = svc.status(id).ok_or_else(|| anyhow!("job {id} vanished"))?;
                        let mut fields = vec![
                            ("ok", Json::Bool(true)),
                            ("cmd", jstr("events")),
                            ("job", num(id.0 as f64)),
                            ("events", arr(events.iter().map(event_json))),
                        ];
                        state_fields(&state, &mut fields);
                        Ok(Some(obj(fields)))
                    }
                },
            }
        }
        "infer" => parse_infer_frame(req).and_then(|(ireq, artifacts, job)| {
            let infer_out = svc.infer(artifacts.as_deref(), &ireq, job)?;
            Ok(Some(infer_response(&ireq.model, &infer_out)))
        }),
        "cancel" => req_job(req).map(|id| {
            let cancelled = svc.cancel(id);
            Some(obj(vec![
                ("ok", Json::Bool(true)),
                ("cmd", jstr("cancel")),
                ("job", num(id.0 as f64)),
                ("cancelled", Json::Bool(cancelled)),
            ]))
        }),
        "forget" => req_job(req).map(|id| {
            let forgotten = svc.forget(id);
            Some(obj(vec![
                ("ok", Json::Bool(true)),
                ("cmd", jstr("forget")),
                ("job", num(id.0 as f64)),
                ("forgotten", Json::Bool(forgotten)),
            ]))
        }),
        "store" => no_store_err(svc).and_then(|store| {
            let records = store.list()?;
            let resident: std::collections::BTreeSet<String> =
                store.resident_keys().into_iter().collect();
            Ok(Some(obj(vec![
                ("ok", Json::Bool(true)),
                ("cmd", jstr("store")),
                ("dir", jstr(store.dir().display().to_string())),
                (
                    "records",
                    arr(records.iter().map(|(k, bytes)| {
                        obj(vec![
                            ("key", jstr(k.clone())),
                            ("bytes", num(*bytes as f64)),
                            ("resident", Json::Bool(resident.contains(k))),
                        ])
                    })),
                ),
            ])))
        }),
        "store-stats" => no_store_err(svc).and_then(|store| {
            let s = store.stats()?;
            let mut fields = vec![("ok", Json::Bool(true)), ("cmd", jstr("store-stats"))];
            fields.extend(store_stat_fields(&s));
            Ok(Some(obj(fields)))
        }),
        "stats" => {
            let mut fields = vec![("ok", Json::Bool(true)), ("cmd", jstr("stats"))];
            fields.extend(service_stat_fields(svc));
            Ok(Some(obj(fields)))
        }
        "shutdown" => Ok(Some(obj(vec![
            ("ok", Json::Bool(true)),
            ("cmd", jstr("shutdown")),
        ]))),
        other => Err(anyhow!(
            "unknown cmd {other:?}; expected submit|status|events|infer|cancel|forget\
             |store|store-stats|stats|shutdown"
        )),
    };
    Ok(result)
}

/// The serve loop: read JSON-lines requests until EOF or `shutdown`,
/// writing responses to `out`.  Blank lines are skipped; request errors
/// — including a line that is not valid UTF-8 — are reported in-band
/// (a malformed frame must never kill the whole session; only real I/O
/// failures propagate).  Used by `wasi-train serve` over real
/// stdin/stdout and by tests over in-memory buffers.
pub fn serve_lines(svc: &Service, mut input: impl BufRead, mut out: impl Write) -> Result<()> {
    let mut buf = Vec::new();
    loop {
        buf.clear();
        if input.read_until(b'\n', &mut buf)? == 0 {
            return Ok(()); // EOF
        }
        let flow = match std::str::from_utf8(&buf) {
            Err(e) => {
                writeln!(
                    out,
                    "{}",
                    error_line("?", &anyhow!("request line is not valid UTF-8: {e}"))
                )?;
                Flow::Continue
            }
            Ok(line) => {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                handle_line(svc, line, &mut out)?
            }
        };
        out.flush()?;
        if flow == Flow::Shutdown {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::demo::{write_demo_artifacts, DemoConfig};
    use crate::serve::service::ServiceConfig;

    fn demo_service(tag: &str) -> Service {
        let dir = std::env::temp_dir().join(format!("wasi_proto_test_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        Service::start(ServiceConfig::new(dir).with_workers(1)).unwrap()
    }

    fn run_session(svc: &Service, lines: &[&str]) -> Vec<Json> {
        let input = lines.join("\n");
        let mut out = Vec::new();
        serve_lines(svc, input.as_bytes(), &mut out).unwrap();
        String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).expect("every response line is JSON"))
            .collect()
    }

    #[test]
    fn submit_events_infer_shutdown_roundtrip() {
        let svc = demo_service("roundtrip");
        let responses = run_session(
            &svc,
            &[
                r#"{"cmd":"submit","model":"vit_demo_wasi_eps80","steps":4,"samples":32,"engine":"native"}"#,
                r#"{"cmd":"events","job":1,"wait":true}"#,
                r#"{"cmd":"status","job":1}"#,
                r#"{"cmd":"infer","model":"vit_demo_vanilla","seed":7}"#,
                r#"{"cmd":"infer","model":"vit_demo_wasi_eps80","job":1}"#,
                r#"{"cmd":"shutdown"}"#,
            ],
        );
        svc.shutdown();
        // submit ack.
        assert_eq!(responses[0].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(responses[0].get("job").and_then(|v| v.as_usize()), Some(1));
        // streamed events: started + 4 steps + done, then the final
        // status line of the events command, then the status reply.
        let started = &responses[1];
        assert_eq!(started.get("event").and_then(|v| v.as_str()), Some("started"));
        let step_lines: Vec<&Json> = responses
            .iter()
            .filter(|r| r.get("event").and_then(|v| v.as_str()) == Some("step"))
            .collect();
        assert_eq!(step_lines.len(), 4);
        assert!(step_lines[0].get("loss").and_then(|v| v.as_f64()).is_some());
        let done: Vec<&Json> = responses
            .iter()
            .filter(|r| r.get("event").and_then(|v| v.as_str()) == Some("done"))
            .collect();
        assert_eq!(done.len(), 1);
        assert!(done[0].get("report").and_then(|r| r.get("val_accuracy")).is_some());
        // Both the events-final and status lines carry state=done.
        let dones = responses
            .iter()
            .filter(|r| r.get("state").and_then(|v| v.as_str()) == Some("done"))
            .count();
        assert!(dones >= 2, "{responses:?}");
        // infer on pretrained and on job-1 personalized params.
        let infers: Vec<&Json> = responses
            .iter()
            .filter(|r| r.get("cmd").and_then(|v| v.as_str()) == Some("infer"))
            .collect();
        assert_eq!(infers.len(), 2);
        for i in &infers {
            assert_eq!(i.get("ok"), Some(&Json::Bool(true)));
            let nonempty = i.get("preds").and_then(|v| v.as_arr()).map(|a| !a.is_empty());
            assert!(nonempty.unwrap_or(false));
        }
        assert!(infers[0].get("correct").and_then(|v| v.as_usize()).is_some());
        // shutdown ack is the last line.
        assert_eq!(
            responses.last().unwrap().get("cmd").and_then(|v| v.as_str()),
            Some("shutdown")
        );
    }

    #[test]
    fn persist_delta_round_trip_and_store_commands() {
        let dir = std::env::temp_dir().join("wasi_proto_test_store");
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        let store_dir = dir.join("store");
        let svc = Service::start(
            ServiceConfig::new(dir).with_workers(1).with_store(&store_dir, 64 << 20),
        )
        .unwrap();
        let responses = run_session(
            &svc,
            &[
                concat!(
                    r#"{"cmd":"submit","model":"vit_demo_wasi_eps80","#,
                    r#""steps":4,"samples":32,"persist":"delta"}"#
                ),
                r#"{"cmd":"events","job":1,"wait":true}"#,
                r#"{"cmd":"infer","model":"vit_demo_wasi_eps80","job":1}"#,
                r#"{"cmd":"store"}"#,
                r#"{"cmd":"store-stats"}"#,
                r#"{"cmd":"forget","job":1}"#,
                r#"{"cmd":"store"}"#,
                r#"{"cmd":"submit","model":"vit_demo_wasi_eps80","persist":"sideways"}"#,
                r#"{"cmd":"shutdown"}"#,
            ],
        );
        svc.shutdown();
        // The delta job served personalized inference...
        let infer = responses
            .iter()
            .find(|r| r.get("cmd").and_then(|v| v.as_str()) == Some("infer"))
            .unwrap();
        assert_eq!(infer.get("ok"), Some(&Json::Bool(true)), "{infer}");
        // ...its record shows up in `store` (resident, nonzero bytes)...
        let stores: Vec<&Json> = responses
            .iter()
            .filter(|r| r.get("cmd").and_then(|v| v.as_str()) == Some("store"))
            .collect();
        assert_eq!(stores.len(), 2, "{responses:?}");
        let records = stores[0].get("records").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(records.len(), 1, "{}", stores[0]);
        assert_eq!(records[0].get("key").and_then(|v| v.as_str()), Some("job-1"));
        assert_eq!(records[0].get("resident"), Some(&Json::Bool(true)));
        assert!(records[0].get("bytes").and_then(|v| v.as_usize()).unwrap() > 0);
        // ...store-stats counted the put...
        let stats = responses
            .iter()
            .find(|r| r.get("cmd").and_then(|v| v.as_str()) == Some("store-stats"))
            .unwrap();
        assert_eq!(stats.get("puts").and_then(|v| v.as_usize()), Some(1), "{stats}");
        // ...forget dropped it from the store...
        let records = stores[1].get("records").and_then(|v| v.as_arr()).unwrap();
        assert!(records.is_empty(), "{}", stores[1]);
        // ...and a bogus persist mode errors in-band.
        let bad = &responses[responses.len() - 2];
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)), "{bad}");
        assert!(bad
            .get("error")
            .and_then(|v| v.as_str())
            .unwrap()
            .contains("persist"));
    }

    #[test]
    fn request_errors_are_in_band_not_fatal() {
        let svc = demo_service("errors");
        let responses = run_session(
            &svc,
            &[
                "this is not json",
                r#"{"cmd":"frobnicate"}"#,
                r#"{"cmd":"submit","steps":3}"#,
                r#"{"cmd":"submit","model":"no_such_model","steps":3}"#,
                r#"{"cmd":"status","job":99}"#,
                r#"{"cmd":"cancel","job":99}"#,
                r#"{"cmd":"events","job":99}"#,
                r#"{"cmd":"submit","model":"vit_demo_vanilla","steps":"three"}"#,
                r#"{"cmd":"shutdown"}"#,
            ],
        );
        svc.shutdown();
        // All but cancel + shutdown are errors; the loop survives them all.
        assert_eq!(responses.len(), 9);
        for (i, r) in responses.iter().enumerate() {
            let ok = r.get("ok").and_then(|v| v.as_bool()).unwrap();
            match i {
                5 => {
                    // cancel of an unknown job is ok:true, cancelled:false.
                    assert!(ok, "{r}");
                    assert_eq!(r.get("cancelled"), Some(&Json::Bool(false)));
                }
                8 => assert!(ok, "{r}"),
                _ => {
                    assert!(!ok, "line {i} should be an error: {r}");
                    assert!(r.get("error").and_then(|v| v.as_str()).is_some());
                }
            }
        }
        assert!(responses[1]
            .get("error")
            .and_then(|v| v.as_str())
            .unwrap()
            .contains("unknown cmd"));
    }

    #[test]
    fn unknown_request_keys_are_rejected() {
        // The protocol twin of the CLI's `--step 50` rejection: a
        // misspelled key must error, not silently train defaults.
        let svc = demo_service("keys");
        let responses = run_session(
            &svc,
            &[
                r#"{"cmd":"submit","model":"vit_demo_vanilla","step":5}"#,
                r#"{"cmd":"status","job":1,"wait":true}"#,
                r#"{"cmd":"submit","model":"vit_demo_vanilla","resume_from":123}"#,
                r#"{"cmd":"events","job":1,"wait":1}"#,
                r#"{"cmd":"stat","job":1}"#,
                r#"{"cmd":"shutdown","graceful":true}"#,
                r#"{"cmd":"shutdown"}"#,
            ],
        );
        svc.shutdown();
        let err = responses[0].get("error").and_then(|v| v.as_str()).unwrap();
        assert!(err.contains("unknown key \"step\""), "{err}");
        assert!(err.contains("steps"), "accepted set must be listed: {err}");
        // "wait" belongs to events, not status.
        assert_eq!(responses[1].get("ok"), Some(&Json::Bool(false)));
        // Accepted keys with the WRONG TYPE error too — a mistyped
        // resume_from must not silently train from scratch, and a
        // non-bool wait must not silently degrade to a drain.
        let err = responses[2].get("error").and_then(|v| v.as_str()).unwrap();
        assert!(err.contains("\"resume_from\" must be a string"), "{err}");
        let err = responses[3].get("error").and_then(|v| v.as_str()).unwrap();
        assert!(err.contains("\"wait\" must be a boolean"), "{err}");
        // A misspelled cmd gets the unknown-CMD error, not a misleading
        // unknown-key complaint with an empty accepted set.
        let err = responses[4].get("error").and_then(|v| v.as_str()).unwrap();
        assert!(err.contains("unknown cmd"), "{err}");
        // A REJECTED shutdown (unknown key) must not stop the session —
        // the clean shutdown after it still got processed.
        assert_eq!(responses[5].get("ok"), Some(&Json::Bool(false)));
        assert_eq!(responses.len(), 7, "{responses:?}");
        assert_eq!(
            responses[6].get("cmd").and_then(|v| v.as_str()),
            Some("shutdown")
        );
        assert_eq!(responses[6].get("ok"), Some(&Json::Bool(true)));
    }

    /// Property-style fuzz (satellite of the scenario harness): every
    /// adversarial frame — truncated, oversized, NaN/inf-bearing,
    /// unknown-key, garbage — must produce at least one in-band JSON
    /// response line (never a panic, never a silent drop), and the
    /// session must stay alive afterwards.
    #[test]
    fn fuzzed_frames_always_answer_in_band() {
        let svc = demo_service("fuzz");
        // Templates reference models that do NOT exist so no frame can
        // start real training (keeps 200 cases fast and non-blocking —
        // `events wait:true` on an unknown job errors immediately).
        let templates = [
            r#"{"cmd":"submit","model":"m0","steps":3,"lr":0.1}"#,
            r#"{"cmd":"status","job":7}"#,
            r#"{"cmd":"events","job":7,"wait":true}"#,
            r#"{"cmd":"infer","model":"m1","x":[0.5,1.5],"seed":3}"#,
            r#"{"cmd":"cancel","job":2}"#,
            r#"{"cmd":"forget","job":2}"#,
        ];
        crate::util::proptest::check("proto_fuzz", 200, |g| {
            let base = templates[g.usize_in(0, templates.len() - 1)];
            let frame: String = match g.usize_in(0, 4) {
                // Truncate at a random char boundary.
                0 => {
                    let cut = g.usize_in(0, base.len());
                    base.chars().take(cut).collect()
                }
                // Replace every number with an overflow literal (inf).
                1 => {
                    let mut s = String::new();
                    for c in base.chars() {
                        if c.is_ascii_digit() {
                            s.push_str("1e999");
                        } else {
                            s.push(c);
                        }
                    }
                    s
                }
                // Graft an unknown key (sometimes oversized).
                2 => {
                    let filler = "z".repeat(g.usize_in(1, 4096));
                    format!(
                        "{},\"{}\":\"{}\"}}",
                        &base[..base.len() - 1],
                        "bogus_key",
                        filler
                    )
                }
                // Oversized frame: a deep-ish array payload.
                3 => {
                    let n = g.usize_in(256, 2048);
                    let xs: Vec<String> = (0..n).map(|i| format!("{i}")).collect();
                    format!(r#"{{"cmd":"infer","model":"m1","x":[{}]}}"#, xs.join(","))
                }
                // Random ASCII garbage.
                _ => {
                    let n = g.usize_in(1, 64);
                    (0..n)
                        .map(|_| (g.usize_in(0x20, 0x7e) as u8) as char)
                        .collect()
                }
            };
            let mut out = Vec::new();
            let flow = handle_line(&svc, frame.trim(), &mut out)
                .map_err(|e| format!("I/O error escaped for frame {frame:?}: {e}"))?;
            if flow != Flow::Continue {
                return Err(format!("fuzz frame triggered shutdown: {frame:?}"));
            }
            let text = String::from_utf8(out).map_err(|e| e.to_string())?;
            if frame.trim().is_empty() {
                return Ok(()); // handle_line on "" answers bad-JSON below
            }
            let lines: Vec<&str> = text.lines().collect();
            if lines.is_empty() {
                return Err(format!("silent drop for frame {frame:?}"));
            }
            for l in &lines {
                let v = Json::parse(l)
                    .map_err(|e| format!("non-JSON response {l:?} for {frame:?}: {e}"))?;
                if v.get("ok").and_then(|o| o.as_bool()).is_none() {
                    return Err(format!("response without ok flag: {l}"));
                }
            }
            Ok(())
        });
        // The session still works after 200 hostile frames.
        let responses =
            run_session(&svc, &[r#"{"cmd":"infer","model":"vit_demo_vanilla"}"#]);
        assert_eq!(responses[0].get("ok"), Some(&Json::Bool(true)), "{responses:?}");
        svc.shutdown();
    }

    /// Non-UTF8 and NaN/inf-bearing frames through the full byte-level
    /// serve loop: each must answer `ok:false` in-band and the loop
    /// must keep serving (only real I/O failures may end a session).
    #[test]
    fn non_utf8_and_nonfinite_frames_error_in_band() {
        let svc = demo_service("utf8");
        let mut input: Vec<u8> = Vec::new();
        input.extend_from_slice(b"\xff\xfe garbage bytes\n");
        input.extend_from_slice(br#"{"cmd":"infer","model":"vit_demo_vanilla","x":[1e999]}"#);
        input.push(b'\n');
        input.extend_from_slice(b"{\"cmd\":\"status\",\"job\":\xc3\x28}\n"); // overlong-ish UTF-8
        input.extend_from_slice(
            br#"{"cmd":"submit","model":"vit_demo_vanilla","steps":2,"lr":1e999}"#,
        );
        input.push(b'\n');
        input.extend_from_slice(br#"{"cmd":"shutdown"}"#);
        input.push(b'\n');
        let mut out = Vec::new();
        serve_lines(&svc, &input[..], &mut out).unwrap();
        svc.shutdown();
        let responses: Vec<Json> = String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).expect("every response line is JSON"))
            .collect();
        assert_eq!(responses.len(), 5, "{responses:?}");
        let errs: Vec<&str> = responses[..4]
            .iter()
            .map(|r| {
                assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
                r.get("error").and_then(|v| v.as_str()).unwrap()
            })
            .collect();
        assert!(errs[0].contains("not valid UTF-8"), "{}", errs[0]);
        assert!(errs[1].contains("finite"), "{}", errs[1]);
        assert!(errs[2].contains("not valid UTF-8"), "{}", errs[2]);
        assert!(errs[3].contains("finite"), "{}", errs[3]);
        // The shutdown ack still arrives — the session survived.
        assert_eq!(responses[4].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(
            responses[4].get("cmd").and_then(|v| v.as_str()),
            Some("shutdown")
        );
    }

    #[test]
    fn status_polling_sees_queued_then_terminal() {
        let svc = demo_service("poll");
        // Submit without waiting; drain events until the job is done.
        let responses = run_session(
            &svc,
            &[r#"{"cmd":"submit","model":"vit_demo_vanilla","steps":3,"samples":32}"#],
        );
        assert_eq!(responses[0].get("state").and_then(|v| v.as_str()), Some("queued"));
        let id = JobId(1);
        svc.wait(id).unwrap();
        let responses = run_session(&svc, &[r#"{"cmd":"events","job":1}"#]);
        let r = &responses[0];
        assert_eq!(r.get("state").and_then(|v| v.as_str()), Some("done"));
        let events = r.get("events").and_then(|v| v.as_arr()).unwrap();
        // started + 3 steps + done, all buffered.
        assert_eq!(events.len(), 5, "{r}");
        svc.shutdown();
    }
}

//! `ModelPool` — load each artifact directory once, hand out engines.
//!
//! Sharing rules (DESIGN.md §serve):
//!
//! * one [`PoolEntry`] per artifact directory: the runtime (with its
//!   compiled-executable caches) and the parsed manifest are loaded
//!   once and shared by every job, inference request, and `Session`
//!   wrapping the entry;
//! * **train engines are exclusive** — each carries mutable
//!   params/state, so [`PoolEntry::train_engine`] constructs a fresh
//!   one per job (the flat vectors are per-job state; the heavy shared
//!   pieces — runtime caches, manifest — are behind the entry);
//! * **infer engines are shared** — inference is stateless between
//!   calls (`infer(&self, params, x)`), so the pool caches one native
//!   engine per (variant, precision) and every request borrows it
//!   concurrently.  Reduced-precision entries **quantize on load**
//!   (DESIGN.md §Precision): the packed bf16/int8 weight set is built
//!   once when the cache entry is created, so every subsequent request
//!   serves from the compact representation.  HLO inference engines
//!   borrow the runtime (their executables live in its cache), so they
//!   are constructed per call instead — the compile cache makes that a
//!   map lookup.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::engine::{self, EngineKind, InferEngine, NativeInferEngine, TrainEngine};
use crate::precision::Precision;
use crate::runtime::{Manifest, Runtime};

/// One loaded artifact directory: runtime + manifest + shared caches.
pub struct PoolEntry {
    pub dir: PathBuf,
    pub runtime: Runtime,
    pub manifest: Manifest,
    /// Initial flat parameter vectors, loaded once per variant (the
    /// params served by pool inference when no job is referenced).
    init_params: Mutex<BTreeMap<String, Arc<Vec<f32>>>>,
    /// Shared native inference engines, one per (variant, precision);
    /// reduced-precision entries hold their quantized-on-load weights.
    infer_cache: Mutex<BTreeMap<(String, Precision), Arc<NativeInferEngine>>>,
}

impl PoolEntry {
    /// Load `<dir>/manifest.json` and construct the best available
    /// runtime.  Called once per directory by [`ModelPool::open`].
    pub fn open(dir: impl AsRef<Path>) -> Result<Arc<PoolEntry>> {
        let dir = dir.as_ref().to_path_buf();
        Ok(Arc::new(PoolEntry {
            runtime: Runtime::cpu()?,
            manifest: Manifest::load(&dir)?,
            dir,
            init_params: Mutex::new(BTreeMap::new()),
            infer_cache: Mutex::new(BTreeMap::new()),
        }))
    }

    /// A fresh, exclusive training engine for one variant (one per job).
    pub fn train_engine(
        &self,
        model: &str,
        kind: EngineKind,
    ) -> Result<Box<dyn TrainEngine + '_>> {
        engine::train_engine(&self.runtime, self.manifest.model(model)?, kind)
    }

    /// The variant's initial flat parameter vector, loaded once and
    /// shared (pool inference for variants with no finished job).
    pub fn initial_params(&self, model: &str) -> Result<Arc<Vec<f32>>> {
        let mut cache = self.init_params.lock().unwrap();
        if let Some(p) = cache.get(model) {
            return Ok(p.clone());
        }
        let params = Arc::new(self.manifest.model(model)?.load_params()?);
        cache.insert(model.to_string(), params.clone());
        Ok(params)
    }

    /// An inference engine for one variant, shared when possible
    /// (f32 storage — see [`PoolEntry::shared_infer_at`]).
    pub fn shared_infer(&self, model: &str, kind: EngineKind) -> Result<PooledInfer<'_>> {
        self.shared_infer_at(model, kind, Precision::F32)
    }

    /// An inference engine for one variant at a weight-storage
    /// precision, shared when possible.
    ///
    /// Mirrors `engine::infer_engine`'s selection rule (`auto` on a
    /// train-artifact-free variant is native); native engines come out
    /// of the per-(variant, precision) cache — reduced-precision
    /// entries quantize the variant's initial params on first load —
    /// and HLO engines (f32-only) are built per call.
    pub fn shared_infer_at(
        &self,
        model: &str,
        kind: EngineKind,
        precision: Precision,
    ) -> Result<PooledInfer<'_>> {
        let entry = self.manifest.model(model)?;
        let resolved = match kind {
            EngineKind::Auto if entry.train_hlo.is_none() => EngineKind::Native,
            EngineKind::Auto if precision != Precision::F32 => EngineKind::Native,
            k => k.resolve(&self.runtime),
        };
        if resolved == EngineKind::Hlo {
            if precision != Precision::F32 {
                return Err(anyhow!(
                    "precision {precision} requires the native engine; the HLO \
                     inference step is f32-only"
                ));
            }
            return Ok(PooledInfer::PerCall(engine::infer_engine(
                &self.runtime,
                entry,
                EngineKind::Hlo,
            )?));
        }
        let key = (model.to_string(), precision);
        if let Some(e) = self.infer_cache.lock().unwrap().get(&key) {
            return Ok(PooledInfer::Shared(e.clone()));
        }
        // Build OUTSIDE the cache lock (graph construction + whole-model
        // quantization must not block unrelated requests) from the
        // already-cached initial params — no second disk read.  A racing
        // builder is harmless: first insert wins, both engines are valid.
        let eng = if precision == Precision::F32 {
            Arc::new(NativeInferEngine::load(entry)?)
        } else {
            let params = self.initial_params(model)?;
            Arc::new(NativeInferEngine::load_quantized_from(entry, &params, precision)?)
        };
        let mut cache = self.infer_cache.lock().unwrap();
        let eng = cache.entry(key).or_insert(eng).clone();
        Ok(PooledInfer::Shared(eng))
    }

    /// Number of variants with a cached shared inference engine
    /// (introspection for tests and the bench record).
    pub fn cached_infer_engines(&self) -> usize {
        self.infer_cache.lock().unwrap().len()
    }
}

/// A pool inference engine handle: either the shared per-variant native
/// engine or a per-call HLO wrapper (see [`PoolEntry::shared_infer`]).
pub enum PooledInfer<'rt> {
    Shared(Arc<NativeInferEngine>),
    PerCall(Box<dyn InferEngine + 'rt>),
}

impl PooledInfer<'_> {
    pub fn engine(&self) -> &dyn InferEngine {
        match self {
            PooledInfer::Shared(e) => e.as_ref(),
            PooledInfer::PerCall(b) => b.as_ref(),
        }
    }

    /// The concrete native engine, when shared — the reduced-precision
    /// paths (`infer_quantized`, `pack_params`) live on it.
    pub fn native(&self) -> Option<&NativeInferEngine> {
        match self {
            PooledInfer::Shared(e) => Some(e.as_ref()),
            PooledInfer::PerCall(_) => None,
        }
    }
}

/// Artifact-directory → [`PoolEntry`] cache: the serving core loads
/// each directory/variant once however many jobs and requests hit it.
pub struct ModelPool {
    entries: Mutex<BTreeMap<PathBuf, Arc<PoolEntry>>>,
}

impl ModelPool {
    pub fn new() -> ModelPool {
        ModelPool { entries: Mutex::new(BTreeMap::new()) }
    }

    /// The entry for an artifact directory, loading it on first use.
    /// Keyed by the path as given (no canonicalization: serving across
    /// spellings of one directory costs a duplicate load, never
    /// correctness).
    pub fn open(&self, dir: impl AsRef<Path>) -> Result<Arc<PoolEntry>> {
        let key = dir.as_ref().to_path_buf();
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.get(&key) {
            return Ok(e.clone());
        }
        let entry = PoolEntry::open(&key)
            .map_err(|e| anyhow!("loading artifact dir {}: {e:#}", key.display()))?;
        entries.insert(key, entry.clone());
        Ok(entry)
    }

    /// Number of loaded artifact directories.
    pub fn loaded_dirs(&self) -> usize {
        self.entries.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::demo::{write_demo_artifacts, DemoConfig};

    fn demo_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("wasi_pool_test_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        dir
    }

    #[test]
    fn pool_loads_each_dir_once() {
        let dir = demo_dir("once");
        let pool = ModelPool::new();
        let a = pool.open(&dir).unwrap();
        let b = pool.open(&dir).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second open must hit the cache");
        assert_eq!(pool.loaded_dirs(), 1);
    }

    #[test]
    fn pool_open_missing_dir_errors_with_path() {
        let pool = ModelPool::new();
        let missing = std::env::temp_dir().join("wasi_pool_no_such_dir");
        let err = pool.open(&missing).unwrap_err();
        assert!(format!("{err:#}").contains("wasi_pool_no_such_dir"), "{err:#}");
    }

    #[test]
    fn infer_engines_are_shared_train_engines_are_not() {
        let dir = demo_dir("share");
        let entry = PoolEntry::open(&dir).unwrap();
        let a = entry.shared_infer("vit_demo_vanilla", EngineKind::Auto).unwrap();
        let b = entry.shared_infer("vit_demo_vanilla", EngineKind::Auto).unwrap();
        match (&a, &b) {
            (PooledInfer::Shared(x), PooledInfer::Shared(y)) => {
                assert!(Arc::ptr_eq(x, y), "infer engines must be shared per variant")
            }
            _ => panic!("demo variants must resolve to the shared native engine"),
        }
        assert_eq!(entry.cached_infer_engines(), 1);

        // Train engines are fresh per call: stepping one must not
        // perturb the other (exclusive params/state).
        let mut t1 = entry.train_engine("vit_demo_vanilla", EngineKind::Native).unwrap();
        let t2 = entry.train_engine("vit_demo_vanilla", EngineKind::Native).unwrap();
        let before = t2.params().to_vec();
        let mut task =
            crate::data::synth::VisionTask::new("pool", t1.entry().classes, 16, 0.5, 4, 3);
        let (x, y, _) = task.batch_onehot(t1.entry().batch);
        t1.step(&x, &y, 0.1).unwrap();
        assert_eq!(t2.params(), &before[..], "train engines must be exclusive");
    }

    #[test]
    fn quantized_infer_engines_cache_per_variant_and_precision() {
        let dir = demo_dir("quant");
        let entry = PoolEntry::open(&dir).unwrap();
        let f = entry
            .shared_infer_at("vit_demo_vanilla", EngineKind::Auto, Precision::F32)
            .unwrap();
        let a = entry
            .shared_infer_at("vit_demo_vanilla", EngineKind::Auto, Precision::I8)
            .unwrap();
        let b = entry
            .shared_infer_at("vit_demo_vanilla", EngineKind::Auto, Precision::I8)
            .unwrap();
        match (&a, &b) {
            (PooledInfer::Shared(x), PooledInfer::Shared(y)) => {
                assert!(Arc::ptr_eq(x, y), "int8 engines must share the quantized load")
            }
            _ => panic!("demo variants must resolve to shared native engines"),
        }
        // Distinct cache entries per precision; the quantized one holds
        // its packed weights (quantize-on-load, not per request).
        assert_eq!(entry.cached_infer_engines(), 2);
        let native = a.native().expect("shared native engine");
        assert_eq!(native.precision(), Precision::I8);
        let entry_len = entry.manifest.model("vit_demo_vanilla").unwrap().params_len;
        assert!(native.packed_bytes().unwrap() < entry_len * 4);
        assert!(f.native().unwrap().packed_bytes().is_none());
    }

    #[test]
    fn initial_params_cached_and_length_checked() {
        let dir = demo_dir("params");
        let entry = PoolEntry::open(&dir).unwrap();
        let p1 = entry.initial_params("vit_demo_vanilla").unwrap();
        let p2 = entry.initial_params("vit_demo_vanilla").unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        let want = entry.manifest.model("vit_demo_vanilla").unwrap().params_len;
        assert_eq!(p1.len(), want);
        assert!(entry.initial_params("no_such_model").is_err());
    }
}

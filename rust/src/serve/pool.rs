//! `ModelPool` — load each artifact directory once, hand out engines.
//!
//! Sharing rules (DESIGN.md §serve):
//!
//! * one [`PoolEntry`] per artifact directory: the runtime (with its
//!   compiled-executable caches) and the parsed manifest are loaded
//!   once and shared by every job, inference request, and `Session`
//!   wrapping the entry;
//! * **train engines are exclusive** — each carries mutable
//!   params/state, so [`PoolEntry::train_engine`] constructs a fresh
//!   one per job (the flat vectors are per-job state; the heavy shared
//!   pieces — runtime caches, manifest — are behind the entry);
//! * **infer engines are shared** — inference is stateless between
//!   calls (`infer(&self, params, x)`), so the pool caches one native
//!   engine per (variant, precision) and every request borrows it
//!   concurrently.  Reduced-precision entries **quantize on load**
//!   (DESIGN.md §Precision): the packed bf16/int8 weight set — int8
//!   panels hold raw quantized bytes served by the true-integer GEMM —
//!   is built once when the cache entry is created, so every
//!   subsequent request serves from the compact representation.  HLO
//!   inference engines
//!   borrow the runtime (their executables live in its cache), so they
//!   are constructed per call instead — the compile cache makes that a
//!   map lookup.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::engine::{self, EngineKind, InferEngine, NativeInferEngine, TrainEngine};
use crate::precision::Precision;
use crate::runtime::{Manifest, Runtime};
use crate::store::VariantStore;

/// One loaded artifact directory: runtime + manifest + shared caches.
pub struct PoolEntry {
    pub dir: PathBuf,
    pub runtime: Runtime,
    pub manifest: Manifest,
    /// Initial flat parameter vectors, loaded once per variant (the
    /// params served by pool inference when no job is referenced).
    init_params: Mutex<BTreeMap<String, Arc<Vec<f32>>>>,
    /// Shared native inference engines, one per (variant, precision);
    /// reduced-precision entries hold their quantized-on-load weights.
    /// Each key maps to a build *slot*: the outer lock only registers
    /// slots (never held across a build), while the per-key slot lock
    /// serializes builders of the SAME key so every entry is
    /// constructed exactly once — concurrent mixed-precision requests
    /// for one variant build their three entries in parallel, and a
    /// racing pair on one key shares the single winner's engine.
    infer_cache: Mutex<BTreeMap<(String, Precision), Arc<InferSlot>>>,
    /// Completed engine builds (exactly-once telemetry: equals the
    /// number of distinct keys ever built, counting rebuilds after
    /// eviction).
    infer_loads: AtomicU64,
    /// Cache entries removed by [`PoolEntry::evict_infer`].
    infer_evictions: AtomicU64,
    /// Packed reduced-precision parameter sets for finished
    /// personalized jobs, keyed by (job key, precision) — repeated
    /// `infer` requests against the same Done job reuse one
    /// quantize+pack instead of re-packing per request (ISSUE 8
    /// satellite; invalidated by `forget`).
    packed_jobs: Mutex<BTreeMap<(String, Precision), Arc<crate::engine::PackedParams>>>,
    /// [`PoolEntry::packed_for`] cache hits / misses (bench telemetry).
    prepack_hits: AtomicU64,
    prepack_misses: AtomicU64,
    /// The attached variant store, when serving personalized deltas
    /// (`serve --store`, DESIGN.md §Variant store).
    variant_store: Mutex<Option<Arc<VariantStore>>>,
}

/// A per-(variant, precision) build slot (see `infer_cache`).
type InferSlot = Mutex<Option<Arc<NativeInferEngine>>>;

impl PoolEntry {
    /// Load `<dir>/manifest.json` and construct the best available
    /// runtime.  Called once per directory by [`ModelPool::open`].
    pub fn open(dir: impl AsRef<Path>) -> Result<Arc<PoolEntry>> {
        let dir = dir.as_ref().to_path_buf();
        Ok(Arc::new(PoolEntry {
            runtime: Runtime::cpu()?,
            manifest: Manifest::load(&dir)?,
            dir,
            init_params: Mutex::new(BTreeMap::new()),
            infer_cache: Mutex::new(BTreeMap::new()),
            infer_loads: AtomicU64::new(0),
            infer_evictions: AtomicU64::new(0),
            packed_jobs: Mutex::new(BTreeMap::new()),
            prepack_hits: AtomicU64::new(0),
            prepack_misses: AtomicU64::new(0),
            variant_store: Mutex::new(None),
        }))
    }

    /// Attach a variant store so delta-persisted jobs can be served
    /// and `forget` can drop their records.
    pub fn attach_store(&self, store: Arc<VariantStore>) {
        *self.variant_store.lock().unwrap() = Some(store);
    }

    /// The attached variant store, if any.
    pub fn variant_store(&self) -> Option<Arc<VariantStore>> {
        self.variant_store.lock().unwrap().clone()
    }

    /// A fresh, exclusive training engine for one variant (one per job).
    pub fn train_engine(
        &self,
        model: &str,
        kind: EngineKind,
    ) -> Result<Box<dyn TrainEngine + '_>> {
        engine::train_engine(&self.runtime, self.manifest.model(model)?, kind)
    }

    /// The variant's initial flat parameter vector, loaded once and
    /// shared (pool inference for variants with no finished job).
    pub fn initial_params(&self, model: &str) -> Result<Arc<Vec<f32>>> {
        let mut cache = self.init_params.lock().unwrap();
        if let Some(p) = cache.get(model) {
            return Ok(p.clone());
        }
        let params = Arc::new(self.manifest.model(model)?.load_params()?);
        cache.insert(model.to_string(), params.clone());
        Ok(params)
    }

    /// An inference engine for one variant, shared when possible
    /// (f32 storage — see [`PoolEntry::shared_infer_at`]).
    pub fn shared_infer(&self, model: &str, kind: EngineKind) -> Result<PooledInfer<'_>> {
        self.shared_infer_at(model, kind, Precision::F32)
    }

    /// An inference engine for one variant at a weight-storage
    /// precision, shared when possible.
    ///
    /// Mirrors `engine::infer_engine`'s selection rule (`auto` on a
    /// train-artifact-free variant is native); native engines come out
    /// of the per-(variant, precision) cache — reduced-precision
    /// entries quantize the variant's initial params on first load —
    /// and HLO engines (f32-only) are built per call.
    pub fn shared_infer_at(
        &self,
        model: &str,
        kind: EngineKind,
        precision: Precision,
    ) -> Result<PooledInfer<'_>> {
        let entry = self.manifest.model(model)?;
        let resolved = match kind {
            EngineKind::Auto if entry.train_hlo.is_none() => EngineKind::Native,
            EngineKind::Auto if precision != Precision::F32 => EngineKind::Native,
            k => k.resolve(&self.runtime),
        };
        if resolved == EngineKind::Hlo {
            if precision != Precision::F32 {
                return Err(anyhow!(
                    "precision {precision} requires the native engine; the HLO \
                     inference step is f32-only"
                ));
            }
            return Ok(PooledInfer::PerCall(engine::infer_engine(
                &self.runtime,
                entry,
                EngineKind::Hlo,
            )?));
        }
        let key = (model.to_string(), precision);
        // Register (or find) the key's build slot under the outer lock,
        // then build while holding ONLY the slot lock: same-key racers
        // queue behind the first builder and reuse its engine (each
        // entry is loaded exactly once — the quantize-on-load work is
        // never duplicated), while distinct keys build in parallel.
        let slot = {
            let mut cache = self.infer_cache.lock().unwrap();
            cache.entry(key).or_insert_with(|| Arc::new(Mutex::new(None))).clone()
        };
        let mut filled = slot.lock().unwrap();
        if let Some(e) = filled.as_ref() {
            return Ok(PooledInfer::Shared(e.clone()));
        }
        let eng = if precision == Precision::F32 {
            Arc::new(NativeInferEngine::load(entry)?)
        } else {
            let params = self.initial_params(model)?;
            Arc::new(NativeInferEngine::load_quantized_from(entry, &params, precision)?)
        };
        *filled = Some(eng.clone());
        self.infer_loads.fetch_add(1, Ordering::Relaxed);
        Ok(PooledInfer::Shared(eng))
    }

    /// Drop a cached (variant, precision) inference engine so the next
    /// request rebuilds it (the scenario harness's eviction-under-use
    /// fault).  In-flight holders of the shared `Arc` keep serving from
    /// the old engine — eviction is a cache decision, never a
    /// correctness hazard.  Returns false when nothing was cached.
    pub fn evict_infer(&self, model: &str, precision: Precision) -> bool {
        let slot = self
            .infer_cache
            .lock()
            .unwrap()
            .remove(&(model.to_string(), precision));
        match slot {
            Some(s) => {
                // Only count slots that actually held a built engine;
                // an un-built slot's racer re-registers harmlessly.
                let had = s.lock().unwrap().is_some();
                if had {
                    self.infer_evictions.fetch_add(1, Ordering::Relaxed);
                }
                had
            }
            None => false,
        }
    }

    /// Number of variants with a cached shared inference engine
    /// (introspection for tests and the bench record).
    pub fn cached_infer_engines(&self) -> usize {
        self.cached_infer_keys().len()
    }

    /// The (variant, precision) keys with a BUILT cached engine —
    /// pool-occupancy telemetry for the soak report.
    pub fn cached_infer_keys(&self) -> Vec<(String, Precision)> {
        self.infer_cache
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, slot)| slot.lock().unwrap().is_some())
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// The cached packed parameter set for a finished job at one
    /// precision, building (and caching) it on first use.  The builder
    /// runs under the map lock: packs of one job are serialized, which
    /// is exactly the exactly-once guarantee the cache exists for, and
    /// pack time is small against a request round trip.
    pub fn packed_for(
        &self,
        key: &str,
        precision: Precision,
        build: impl FnOnce() -> Result<crate::engine::PackedParams>,
    ) -> Result<Arc<crate::engine::PackedParams>> {
        let mut cache = self.packed_jobs.lock().unwrap();
        if let Some(p) = cache.get(&(key.to_string(), precision)) {
            self.prepack_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(p.clone());
        }
        self.prepack_misses.fetch_add(1, Ordering::Relaxed);
        let packed = Arc::new(build()?);
        cache.insert((key.to_string(), precision), packed.clone());
        Ok(packed)
    }

    /// Drop every cached packed set for one job key (`forget`, or a
    /// re-run job landing on the same key with new params).
    pub fn invalidate_packed(&self, key: &str) {
        self.packed_jobs.lock().unwrap().retain(|(k, _), _| k != key);
    }

    /// [`PoolEntry::packed_for`] cache hits since open.
    pub fn prepack_hits(&self) -> u64 {
        self.prepack_hits.load(Ordering::Relaxed)
    }

    /// [`PoolEntry::packed_for`] cache misses (= builds) since open.
    pub fn prepack_misses(&self) -> u64 {
        self.prepack_misses.load(Ordering::Relaxed)
    }

    /// Completed engine builds since open (exactly-once telemetry).
    pub fn infer_loads(&self) -> u64 {
        self.infer_loads.load(Ordering::Relaxed)
    }

    /// Cache evictions since open ([`PoolEntry::evict_infer`]).
    pub fn infer_evictions(&self) -> u64 {
        self.infer_evictions.load(Ordering::Relaxed)
    }
}

/// A pool inference engine handle: either the shared per-variant native
/// engine or a per-call HLO wrapper (see [`PoolEntry::shared_infer`]).
pub enum PooledInfer<'rt> {
    Shared(Arc<NativeInferEngine>),
    PerCall(Box<dyn InferEngine + 'rt>),
}

impl PooledInfer<'_> {
    pub fn engine(&self) -> &dyn InferEngine {
        match self {
            PooledInfer::Shared(e) => e.as_ref(),
            PooledInfer::PerCall(b) => b.as_ref(),
        }
    }

    /// The concrete native engine, when shared — the reduced-precision
    /// paths (`infer_quantized`, `pack_params`) live on it.
    pub fn native(&self) -> Option<&NativeInferEngine> {
        match self {
            PooledInfer::Shared(e) => Some(e.as_ref()),
            PooledInfer::PerCall(_) => None,
        }
    }
}

/// Artifact-directory → [`PoolEntry`] cache: the serving core loads
/// each directory/variant once however many jobs and requests hit it.
pub struct ModelPool {
    entries: Mutex<BTreeMap<PathBuf, Arc<PoolEntry>>>,
}

impl ModelPool {
    pub fn new() -> ModelPool {
        ModelPool { entries: Mutex::new(BTreeMap::new()) }
    }

    /// The entry for an artifact directory, loading it on first use.
    /// Keyed by the path as given (no canonicalization: serving across
    /// spellings of one directory costs a duplicate load, never
    /// correctness).
    pub fn open(&self, dir: impl AsRef<Path>) -> Result<Arc<PoolEntry>> {
        let key = dir.as_ref().to_path_buf();
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.get(&key) {
            return Ok(e.clone());
        }
        let entry = PoolEntry::open(&key)
            .map_err(|e| anyhow!("loading artifact dir {}: {e:#}", key.display()))?;
        entries.insert(key, entry.clone());
        Ok(entry)
    }

    /// The entry for an artifact directory ONLY if already loaded —
    /// cache-invalidation paths (`forget`) must not load a directory
    /// just to clear caches that cannot exist.
    pub fn peek(&self, dir: impl AsRef<Path>) -> Option<Arc<PoolEntry>> {
        self.entries.lock().unwrap().get(dir.as_ref()).cloned()
    }

    /// Number of loaded artifact directories.
    pub fn loaded_dirs(&self) -> usize {
        self.entries.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::demo::{write_demo_artifacts, DemoConfig};

    fn demo_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("wasi_pool_test_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        dir
    }

    #[test]
    fn pool_loads_each_dir_once() {
        let dir = demo_dir("once");
        let pool = ModelPool::new();
        let a = pool.open(&dir).unwrap();
        let b = pool.open(&dir).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second open must hit the cache");
        assert_eq!(pool.loaded_dirs(), 1);
    }

    #[test]
    fn pool_open_missing_dir_errors_with_path() {
        let pool = ModelPool::new();
        let missing = std::env::temp_dir().join("wasi_pool_no_such_dir");
        let err = pool.open(&missing).unwrap_err();
        assert!(format!("{err:#}").contains("wasi_pool_no_such_dir"), "{err:#}");
    }

    #[test]
    fn infer_engines_are_shared_train_engines_are_not() {
        let dir = demo_dir("share");
        let entry = PoolEntry::open(&dir).unwrap();
        let a = entry.shared_infer("vit_demo_vanilla", EngineKind::Auto).unwrap();
        let b = entry.shared_infer("vit_demo_vanilla", EngineKind::Auto).unwrap();
        match (&a, &b) {
            (PooledInfer::Shared(x), PooledInfer::Shared(y)) => {
                assert!(Arc::ptr_eq(x, y), "infer engines must be shared per variant")
            }
            _ => panic!("demo variants must resolve to the shared native engine"),
        }
        assert_eq!(entry.cached_infer_engines(), 1);

        // Train engines are fresh per call: stepping one must not
        // perturb the other (exclusive params/state).
        let mut t1 = entry.train_engine("vit_demo_vanilla", EngineKind::Native).unwrap();
        let t2 = entry.train_engine("vit_demo_vanilla", EngineKind::Native).unwrap();
        let before = t2.params().to_vec();
        let mut task =
            crate::data::synth::VisionTask::new("pool", t1.entry().classes, 16, 0.5, 4, 3);
        let (x, y, _) = task.batch_onehot(t1.entry().batch);
        t1.step(&x, &y, 0.1).unwrap();
        assert_eq!(t2.params(), &before[..], "train engines must be exclusive");
    }

    #[test]
    fn quantized_infer_engines_cache_per_variant_and_precision() {
        let dir = demo_dir("quant");
        let entry = PoolEntry::open(&dir).unwrap();
        let f = entry
            .shared_infer_at("vit_demo_vanilla", EngineKind::Auto, Precision::F32)
            .unwrap();
        let a = entry
            .shared_infer_at("vit_demo_vanilla", EngineKind::Auto, Precision::I8)
            .unwrap();
        let b = entry
            .shared_infer_at("vit_demo_vanilla", EngineKind::Auto, Precision::I8)
            .unwrap();
        match (&a, &b) {
            (PooledInfer::Shared(x), PooledInfer::Shared(y)) => {
                assert!(Arc::ptr_eq(x, y), "int8 engines must share the quantized load")
            }
            _ => panic!("demo variants must resolve to shared native engines"),
        }
        // Distinct cache entries per precision; the quantized one holds
        // its packed weights (quantize-on-load, not per request).
        assert_eq!(entry.cached_infer_engines(), 2);
        let native = a.native().expect("shared native engine");
        assert_eq!(native.precision(), Precision::I8);
        let entry_len = entry.manifest.model("vit_demo_vanilla").unwrap().params_len;
        assert!(native.packed_bytes().unwrap() < entry_len * 4);
        assert!(f.native().unwrap().packed_bytes().is_none());
    }

    #[test]
    fn evict_infer_rebuilds_and_counts() {
        let dir = demo_dir("evict");
        let entry = PoolEntry::open(&dir).unwrap();
        let a = entry
            .shared_infer_at("vit_demo_vanilla", EngineKind::Auto, Precision::I8)
            .unwrap();
        assert_eq!(entry.infer_loads(), 1);
        assert_eq!(entry.cached_infer_keys(), vec![("vit_demo_vanilla".to_string(), Precision::I8)]);
        // Evicting a missing key is a no-op...
        assert!(!entry.evict_infer("vit_demo_vanilla", Precision::F32));
        assert_eq!(entry.infer_evictions(), 0);
        // ...evicting the cached one counts and empties the cache...
        assert!(entry.evict_infer("vit_demo_vanilla", Precision::I8));
        assert_eq!(entry.infer_evictions(), 1);
        assert_eq!(entry.cached_infer_engines(), 0);
        // ...while the in-flight handle keeps serving, and the next
        // request rebuilds (a second exactly-once load).
        let old = a.native().unwrap();
        assert_eq!(old.precision(), Precision::I8);
        let b = entry
            .shared_infer_at("vit_demo_vanilla", EngineKind::Auto, Precision::I8)
            .unwrap();
        assert_eq!(entry.infer_loads(), 2);
        match (&a, &b) {
            (PooledInfer::Shared(x), PooledInfer::Shared(y)) => {
                assert!(!Arc::ptr_eq(x, y), "evicted engine must be rebuilt")
            }
            _ => panic!("demo variants must resolve to shared native engines"),
        }
    }

    #[test]
    fn packed_job_cache_hits_and_invalidates() {
        let dir = demo_dir("packcache");
        let entry = PoolEntry::open(&dir).unwrap();
        let pooled = entry
            .shared_infer_at("vit_demo_vanilla", EngineKind::Auto, Precision::I8)
            .unwrap();
        let native = pooled.native().unwrap();
        let params = entry.initial_params("vit_demo_vanilla").unwrap();
        let a = entry
            .packed_for("job-1", Precision::I8, || native.pack_params(&params, Precision::I8))
            .unwrap();
        let b = entry
            .packed_for("job-1", Precision::I8, || native.pack_params(&params, Precision::I8))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second request must reuse the packed set");
        assert_eq!((entry.prepack_hits(), entry.prepack_misses()), (1, 1));
        entry.invalidate_packed("job-1");
        let c = entry
            .packed_for("job-1", Precision::I8, || native.pack_params(&params, Precision::I8))
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "forget must drop the cached pack");
        assert_eq!(entry.prepack_misses(), 2);
    }

    #[test]
    fn initial_params_cached_and_length_checked() {
        let dir = demo_dir("params");
        let entry = PoolEntry::open(&dir).unwrap();
        let p1 = entry.initial_params("vit_demo_vanilla").unwrap();
        let p2 = entry.initial_params("vit_demo_vanilla").unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        let want = entry.manifest.model("vit_demo_vanilla").unwrap().params_len;
        assert_eq!(p1.len(), want);
        assert!(entry.initial_params("no_such_model").is_err());
    }
}

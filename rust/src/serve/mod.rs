//! Multi-session job service: the concurrent serving core behind
//! `wasi-train serve`, the CLI `train` subcommand, and every embedded
//! [`crate::coordinator::Session`].
//!
//! The paper's deployment shape is a long-lived on-device process
//! handling many personalization jobs (fine-tunes) while continuing to
//! serve inference.  This module is that coordinator surface, cut into
//! four layers:
//!
//! * [`pool`] — [`ModelPool`]: each artifact directory/variant loads
//!   once; train engines are handed out exclusively per job, inference
//!   engines are shared across requests;
//! * [`job`] — the job API: [`JobSpec`] → [`JobId`] →
//!   [`JobState`]`{Queued, Running{step, loss}, Done(report), Failed}`
//!   plus the streamed [`JobEvent`] per-step progress channel;
//! * [`service`] — [`Service`]: a fixed worker-thread scheduler with
//!   FIFO queueing, cancellation, blocking waits, and pool inference
//!   that interleaves with running jobs;
//! * [`proto`] — the JSON-lines protocol (`submit` / `status` /
//!   `events` / `infer` / `cancel` / `forget` / `store` /
//!   `store-stats` / `stats` / `shutdown`) `wasi-train serve` speaks
//!   over stdin/stdout — and, length-prefix framed, over the socket
//!   front-end ([`crate::net`], `serve --listen`), which multiplexes
//!   many connections onto one service and micro-batches concurrent
//!   `infer` requests through [`Service::infer_batch`].
//!
//! A service started with `--store DIR` additionally persists
//! `persist:"delta"` jobs to a [`crate::store::VariantStore`]: only the
//! subspace factor record is kept (no full parameter copy per user),
//! and personalized inference applies it against the pool's shared
//! frozen base at request time (DESIGN.md §Variant store).
//!
//! [`runner`] holds the single job-execution path all of the above
//! share — `Session::finetune` is "run one job synchronously", the
//! service workers are "run queued jobs on N threads".  Determinism is
//! preserved end to end: concurrent jobs produce trajectories
//! bit-identical to sequential runs (pinned in `tests/serve.rs`).

pub mod job;
pub mod pool;
pub mod proto;
pub mod runner;
pub mod service;

pub use job::{JobEvent, JobId, JobSpec, JobState};
pub use pool::{ModelPool, PoolEntry, PooledInfer};
pub use proto::{handle_line, serve_lines, service_stat_fields, store_stat_fields, Flow};
pub use runner::{
    run_infer, run_infer_batch_keyed, run_infer_keyed, run_infer_with, InferOutput, InferParams,
    InferRequest, RunnerEvent,
};
pub use service::{delta_key, FaultAction, FaultHook, Service, ServiceConfig};

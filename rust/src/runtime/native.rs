//! Native fallback runtime: the `Runtime`/`Executable` surface with no
//! PJRT, built on the `linalg` engine.
//!
//! The build-time pipeline emits two families of artifacts:
//!
//! * **kernel artifacts** (`kernel.*.hlo.txt`) — single-op programs
//!   whose reference math is defined in this repository
//!   (`python/compile/kernels/ref.py`): the WASI low-rank forward
//!   `Y = X Rᵀ Lᵀ` (Eq. 8), the dense forward `Y = X Wᵀ` (Eq. 1), and
//!   the un-orthogonalized power step `A (Aᵀ U)`.  The native backend
//!   recognizes these by artifact name and executes the math directly
//!   with [`Mat`] — it does **not** interpret HLO.  Inputs are matched
//!   by shape, not position, because different call sites pass them in
//!   different orders (manifest map order vs. test order).
//! * **model artifacts** (train/infer steps) — full transformer
//!   computation graphs lowered from JAX.  Executing those requires the
//!   PJRT backend; the native runtime returns a descriptive error
//!   pointing at the `pjrt` cargo feature.
//!
//! Loading is cheap (an existence check + classification), so
//! `coordinator::Session` opens and every artifact-free code path —
//! `cost-model`, `calibrate`, `list`, `plan-ranks`, and the native eval
//! exhibits — runs in builds with zero external dependencies.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::matrix::Mat;

/// Program classes the native backend knows how to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Program {
    /// `Y = X Rᵀ Lᵀ` — kernel.lowrank_pallas / kernel.lowrank_ref.
    LowrankLinear,
    /// `Y = X Wᵀ` — kernel.dense.
    DenseLinear,
    /// `A (Aᵀ U)` — kernel.power_pallas.
    PowerStep,
    /// Anything else (model train/infer HLO): needs PJRT.
    Opaque,
}

fn classify(path: &Path) -> Program {
    let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
    if !name.starts_with("kernel.") {
        return Program::Opaque;
    }
    if name.contains("lowrank") {
        Program::LowrankLinear
    } else if name.contains("power") {
        Program::PowerStep
    } else if name.contains("dense") {
        Program::DenseLinear
    } else {
        Program::Opaque
    }
}

struct NativeArtifact {
    path: PathBuf,
    program: Program,
}

/// Pure-rust runtime: same surface as the PJRT client, no `xla`.
pub struct NativeRuntime {
    cache: Mutex<HashMap<PathBuf, usize>>,
    artifacts: Mutex<Vec<NativeArtifact>>,
}

impl NativeRuntime {
    pub fn new() -> Self {
        NativeRuntime {
            cache: Mutex::new(HashMap::new()),
            artifacts: Mutex::new(Vec::new()),
        }
    }

    pub fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    /// Register an artifact (cached).  Verifies the file exists and
    /// classifies it; execution strategy is decided here, errors about
    /// non-executable programs are deferred to `run_f32` so that merely
    /// loading a manifest's worth of artifacts never fails.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<NativeExecutable<'_>> {
        let path = path.as_ref().to_path_buf();
        {
            let cache = self.cache.lock().unwrap();
            if let Some(&idx) = cache.get(&path) {
                return Ok(NativeExecutable { runtime: self, idx });
            }
        }
        std::fs::metadata(&path).with_context(|| {
            format!("artifact {} not found (run `make artifacts`)", path.display())
        })?;
        let program = classify(&path);
        let mut arts = self.artifacts.lock().unwrap();
        arts.push(NativeArtifact { path: path.clone(), program });
        let idx = arts.len() - 1;
        drop(arts);
        self.cache.lock().unwrap().insert(path, idx);
        Ok(NativeExecutable { runtime: self, idx })
    }
}

impl Default for NativeRuntime {
    fn default() -> Self {
        Self::new()
    }
}

/// Handle to a loaded native program.
#[derive(Clone, Copy)]
pub struct NativeExecutable<'rt> {
    runtime: &'rt NativeRuntime,
    idx: usize,
}

impl NativeExecutable<'_> {
    /// Execute with f32-vector inputs, shapes supplied per input.
    /// Output format matches the PJRT path: one flat vector per output
    /// tuple element.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let (program, path) = {
            let arts = self.runtime.artifacts.lock().unwrap();
            let a = &arts[self.idx];
            (a.program, a.path.clone())
        };
        match program {
            Program::LowrankLinear => run_lowrank(inputs),
            Program::DenseLinear => run_dense(inputs),
            Program::PowerStep => run_power(inputs),
            Program::Opaque => Err(anyhow!(
                "native runtime cannot execute AOT HLO program {}; \
                 rebuild with `cargo build --features pjrt` and the real \
                 `xla` crate to run full model steps (see README)",
                path.display()
            )),
        }
    }
}

/// Flatten leading dims: an (..., I) input viewed as a (rows, I) matrix.
fn as_matrix(data: &[f32], shape: &[usize]) -> Result<Mat> {
    let cols = *shape.last().ok_or_else(|| anyhow!("rank-0 input where tensor expected"))?;
    let numel: usize = shape.iter().product();
    if numel != data.len() || cols == 0 {
        bail!("input shape {shape:?} inconsistent with {} elements", data.len());
    }
    Ok(Mat::from_vec(numel / cols, cols, data.to_vec()))
}

/// `Y = X Rᵀ Lᵀ` with x (..., I), r (K, I), l (O, K); inputs matched by
/// shape so argument order does not matter.
fn run_lowrank(inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
    if inputs.len() != 3 {
        bail!("lowrank kernel expects 3 inputs (x, l, r), got {}", inputs.len());
    }
    let xi = inputs
        .iter()
        .position(|(_, s)| s.len() >= 3)
        .ok_or_else(|| anyhow!("lowrank kernel: no rank-3 activation input"))?;
    let (x_data, x_shape) = inputs[xi];
    let x = as_matrix(x_data, x_shape)?;
    let others: Vec<&(&[f32], &[usize])> = inputs
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != xi)
        .map(|(_, v)| v)
        .collect();
    let (a, b) = (others[0], others[1]);
    if a.1.len() != 2 || b.1.len() != 2 {
        bail!("lowrank kernel: factor inputs must be matrices");
    }
    // r has cols == I; l has cols == r.rows (== K).
    let i_dim = x.cols;
    let a_is_r = a.1[1] == i_dim && b.1[1] == a.1[0];
    let b_is_r = b.1[1] == i_dim && a.1[1] == b.1[0];
    let (r_in, l_in) = match (a_is_r, b_is_r) {
        (true, false) => (a, b),
        (false, true) => (b, a),
        // Fully square factors fit both readings; guessing would return
        // a numerically wrong product with Ok status, so refuse.
        (true, true) => bail!(
            "lowrank kernel: factor shapes {:?} and {:?} are ambiguous (square); \
             cannot identify (l, r) by shape",
            a.1,
            b.1
        ),
        (false, false) => bail!(
            "lowrank kernel: cannot identify (l, r) from shapes {:?} and {:?} with I={i_dim}",
            a.1,
            b.1
        ),
    };
    let r = as_matrix(r_in.0, r_in.1)?;
    let l = as_matrix(l_in.0, l_in.1)?;
    let h = x.matmul_nt(&r); // (rows, K)
    let y = h.matmul_nt(&l); // (rows, O)
    Ok(vec![y.data])
}

/// `Y = X Wᵀ` with x (..., I), w (O, I).
fn run_dense(inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
    if inputs.len() != 2 {
        bail!("dense kernel expects 2 inputs (x, w), got {}", inputs.len());
    }
    let xi = inputs
        .iter()
        .position(|(_, s)| s.len() >= 3)
        .ok_or_else(|| anyhow!("dense kernel: no rank-3 activation input"))?;
    let (x_data, x_shape) = inputs[xi];
    let x = as_matrix(x_data, x_shape)?;
    let (w_data, w_shape) = inputs[1 - xi];
    if w_shape.len() != 2 || w_shape[1] != x.cols {
        bail!("dense kernel: weight shape {w_shape:?} does not match I={}", x.cols);
    }
    let w = as_matrix(w_data, w_shape)?;
    Ok(vec![x.matmul_nt(&w).data])
}

/// Power step `A (Aᵀ U)` with a (D, M), u (D, R) — both matrices share
/// their leading dim; u is the narrower one.
fn run_power(inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
    if inputs.len() != 2 {
        bail!("power kernel expects 2 inputs (a, u), got {}", inputs.len());
    }
    let (p, q) = (inputs[0], inputs[1]);
    if p.1.len() != 2 || q.1.len() != 2 || p.1[0] != q.1[0] {
        bail!("power kernel: inputs {:?} and {:?} must share a leading dim", p.1, q.1);
    }
    if p.1[1] == q.1[1] {
        // A (Aᵀ U) and U (Uᵀ A) differ; equal widths make the roles
        // undecidable by shape — refuse rather than silently guess.
        bail!("power kernel: inputs {:?} and {:?} are ambiguous (equal widths)", p.1, q.1);
    }
    let (a_in, u_in) = if p.1[1] > q.1[1] { (p, q) } else { (q, p) };
    let a = as_matrix(a_in.0, a_in.1)?;
    let u = as_matrix(u_in.0, u_in.1)?;
    let inner = a.matmul_tn(&u); // (M, R)
    Ok(vec![a.matmul(&inner).data])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg64;

    fn touch(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(name);
        std::fs::write(&p, "HloModule stub\n").unwrap();
        p
    }

    #[test]
    fn classifies_by_artifact_name() {
        assert_eq!(classify(Path::new("kernel.lowrank_pallas.hlo.txt")), Program::LowrankLinear);
        assert_eq!(classify(Path::new("kernel.lowrank_ref.hlo.txt")), Program::LowrankLinear);
        assert_eq!(classify(Path::new("kernel.dense.hlo.txt")), Program::DenseLinear);
        assert_eq!(classify(Path::new("kernel.power_pallas.hlo.txt")), Program::PowerStep);
        assert_eq!(classify(Path::new("vit_vanilla.train.hlo.txt")), Program::Opaque);
    }

    #[test]
    fn lowrank_matches_direct_math_in_any_input_order() {
        let rt = NativeRuntime::new();
        let path = touch("kernel.lowrank_ref.hlo.txt");
        let exe = rt.load(&path).unwrap();
        let (b, n, i, k, o) = (2usize, 3, 5, 4, 6);
        let mut rng = Pcg64::new(1);
        let x = rng.normal_vec(b * n * i);
        let l = rng.normal_vec(o * k);
        let r = rng.normal_vec(k * i);
        let x_shape = [b, n, i];
        let l_shape = [o, k];
        let r_shape = [k, i];
        // integration-test order (x, l, r) and manifest order (l, r, x)
        let out1 = exe
            .run_f32(&[(&x, &x_shape), (&l, &l_shape), (&r, &r_shape)])
            .unwrap();
        let out2 = exe
            .run_f32(&[(&l, &l_shape), (&r, &r_shape), (&x, &x_shape)])
            .unwrap();
        assert_eq!(out1, out2);
        let xm = Mat::from_vec(b * n, i, x.clone());
        let lm = Mat::from_vec(o, k, l.clone());
        let rm = Mat::from_vec(k, i, r.clone());
        let want = xm.matmul_nt(&rm).matmul_nt(&lm);
        assert_eq!(out1.len(), 1);
        for (a, w) in out1[0].iter().zip(&want.data) {
            assert!((a - w).abs() < 1e-4, "{a} vs {w}");
        }
    }

    #[test]
    fn dense_and_power_execute() {
        let rt = NativeRuntime::new();
        let mut rng = Pcg64::new(2);

        let dense = rt.load(touch("kernel.dense.hlo.txt")).unwrap();
        let (b, n, i, o) = (2usize, 4, 6, 3);
        let x = rng.normal_vec(b * n * i);
        let w = rng.normal_vec(o * i);
        let out = dense
            .run_f32(&[(&w, &[o, i][..]), (&x, &[b, n, i][..])])
            .unwrap();
        let want = Mat::from_vec(b * n, i, x.clone()).matmul_nt(&Mat::from_vec(o, i, w.clone()));
        assert_eq!(out[0].len(), b * n * o);
        for (a, ww) in out[0].iter().zip(&want.data) {
            assert!((a - ww).abs() < 1e-4);
        }

        let power = rt.load(touch("kernel.power_pallas.hlo.txt")).unwrap();
        let (d, m, r) = (5usize, 9, 2);
        let a = rng.normal_vec(d * m);
        let u = rng.normal_vec(d * r);
        let out = power
            .run_f32(&[(&a, &[d, m][..]), (&u, &[d, r][..])])
            .unwrap();
        let am = Mat::from_vec(d, m, a.clone());
        let um = Mat::from_vec(d, r, u.clone());
        let want = am.matmul(&am.matmul_tn(&um));
        assert_eq!(out[0].len(), d * r);
        for (x_, w_) in out[0].iter().zip(&want.data) {
            assert!((x_ - w_).abs() < 1e-4);
        }
    }

    #[test]
    fn model_hlo_requires_pjrt() {
        let rt = NativeRuntime::new();
        let exe = rt.load(touch("vit_vanilla.train.hlo.txt")).unwrap();
        let x = [0.0f32; 4];
        let err = exe.run_f32(&[(&x, &[4][..])]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
    }

    #[test]
    fn load_is_cached_per_path() {
        let rt = NativeRuntime::new();
        let path = touch("kernel.lowrank_cache_test.hlo.txt");
        let a = rt.load(&path).unwrap();
        let b = rt.load(&path).unwrap();
        assert_eq!(a.idx, b.idx);
        assert_eq!(rt.artifacts.lock().unwrap().len(), 1);
    }
}

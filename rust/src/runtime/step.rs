//! Typed wrappers over the compiled train/infer executables.
//!
//! A train step is one `execute` of
//!   (params, state, x, y_onehot, lr) -> (params', state', loss, acc)
//! with params/state round-tripping host-side between calls (the
//! coordinator owns them; see coordinator::trainer).

use anyhow::{anyhow, Result};

use super::artifacts::ModelEntry;
use super::{Executable, Runtime};

/// Output of one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepOutput {
    pub loss: f32,
    pub accuracy: f32,
}

/// A compiled, ready-to-run training step for one model variant.
pub struct TrainStep<'rt> {
    exe: Executable<'rt>,
    pub entry: ModelEntry,
    pub params: Vec<f32>,
    pub state: Vec<f32>,
}

impl<'rt> TrainStep<'rt> {
    /// Compile the variant's train HLO and load its initial params/state.
    pub fn load(rt: &'rt Runtime, entry: &ModelEntry) -> Result<Self> {
        let hlo = entry
            .train_hlo
            .as_ref()
            .ok_or_else(|| anyhow!("model {} has no train artifact", entry.name))?;
        let exe = rt.load(hlo)?;
        let params = entry.load_params()?;
        let state = entry.load_state()?;
        Ok(TrainStep { exe, entry: entry.clone(), params, state })
    }

    /// One SGD step on a batch.  `x` is (batch, input_dim) flat,
    /// `y_onehot` is (batch, classes) flat.
    pub fn step(&mut self, x: &[f32], y_onehot: &[f32], lr: f32) -> Result<StepOutput> {
        let b = self.entry.batch;
        if x.len() != b * self.entry.input_dim {
            return Err(anyhow!(
                "x length {} != batch {} * input_dim {}",
                x.len(),
                b,
                self.entry.input_dim
            ));
        }
        if y_onehot.len() != b * self.entry.classes {
            return Err(anyhow!("y length {} mismatch", y_onehot.len()));
        }
        let lr_arr = [lr];
        // XLA prunes the zero-length state parameter from the lowered
        // signature (vanilla / adapter-only variants), so only feed it
        // when the variant actually carries ASI state.
        let p_shape = [self.entry.params_len];
        let s_shape = [self.entry.state_len];
        let x_shape = [b, self.entry.input_dim];
        let y_shape = [b, self.entry.classes];
        let scalar: [usize; 0] = [];
        let mut inputs: Vec<(&[f32], &[usize])> = vec![(&self.params, &p_shape)];
        if self.entry.state_len > 0 {
            inputs.push((&self.state, &s_shape));
        }
        inputs.push((x, &x_shape));
        inputs.push((y_onehot, &y_shape));
        inputs.push((&lr_arr, &scalar));
        let outputs = self.exe.run_f32(&inputs)?;
        if outputs.len() != 4 {
            return Err(anyhow!("train step returned {} outputs", outputs.len()));
        }
        self.params = outputs[0].clone();
        self.state = outputs[1].clone();
        Ok(StepOutput { loss: outputs[2][0], accuracy: outputs[3][0] })
    }

    /// Slice one named tensor out of the flat parameter vector.
    pub fn tensor(&self, name: &str) -> Option<(&[f32], Vec<usize>)> {
        let spec = self.entry.param_spec.iter().find(|t| t.name == name)?;
        let n = spec.numel();
        Some((&self.params[spec.offset..spec.offset + n], spec.shape.clone()))
    }
}

/// A compiled inference step: (params, x) -> logits.
pub struct InferStep<'rt> {
    exe: Executable<'rt>,
    pub entry: ModelEntry,
}

impl<'rt> InferStep<'rt> {
    pub fn load(rt: &'rt Runtime, entry: &ModelEntry) -> Result<Self> {
        let exe = rt.load(&entry.infer_hlo)?;
        Ok(InferStep { exe, entry: entry.clone() })
    }

    /// Run on a batch with explicit params (usually TrainStep::params).
    pub fn infer(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let b = self.entry.batch;
        let outputs = self.exe.run_f32(&[
            (params, &[self.entry.params_len]),
            (x, &[b, self.entry.input_dim]),
        ])?;
        Ok(outputs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("infer returned no outputs"))?)
    }

    /// Argmax labels for a batch of logits.
    pub fn predict(&self, params: &[f32], x: &[f32]) -> Result<Vec<usize>> {
        let logits = self.infer(params, x)?;
        let c = self.entry.classes;
        Ok(logits
            .chunks(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect())
    }
}

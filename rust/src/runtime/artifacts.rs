//! Manifest loading: `artifacts/manifest.json` ties HLO files, initial
//! parameter/state vectors, rank plans, spectra and the perplexity table
//! together.  Written once by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;
use crate::wasi::rank_select::PerplexityTable;

/// One tensor in the flat parameter/state layout.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One model variant (vanilla or WASI at some ε).
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub train_hlo: Option<PathBuf>,
    pub infer_hlo: PathBuf,
    pub params_file: PathBuf,
    pub state_file: Option<PathBuf>,
    pub params_len: usize,
    pub state_len: usize,
    pub batch: usize,
    pub input_dim: usize,
    pub classes: usize,
    pub eps: Option<f64>,
    pub weight_ranks: BTreeMap<String, usize>,
    pub asi_ranks: BTreeMap<String, Vec<usize>>,
    /// name -> ((O, I), activation dims) for factored layers.
    pub layer_dims: BTreeMap<String, (Vec<usize>, Vec<usize>)>,
    pub param_spec: Vec<TensorSpec>,
    /// Flat layout of the ASI warm-start state vector (`{layer}.u{m}`
    /// bases); empty for vanilla variants.
    pub state_spec: Vec<TensorSpec>,
}

impl ModelEntry {
    /// Load the variant's initial flat parameter vector, validating the
    /// manifest length.  This is the params path for inference and the
    /// native engine — it never requires a train artifact.
    pub fn load_params(&self) -> Result<Vec<f32>> {
        let params = read_f32_file(&self.params_file)?;
        if params.len() != self.params_len {
            return Err(anyhow!(
                "model {}: params length {} != manifest {}",
                self.name,
                params.len(),
                self.params_len
            ));
        }
        Ok(params)
    }

    /// Load the variant's initial ASI state vector (empty when the
    /// variant carries no state file).
    pub fn load_state(&self) -> Result<Vec<f32>> {
        let state = match &self.state_file {
            Some(p) => read_f32_file(p)?,
            None => Vec::new(),
        };
        if state.len() != self.state_len {
            return Err(anyhow!(
                "model {}: state length {} != manifest {}",
                self.name,
                state.len(),
                self.state_len
            ));
        }
        Ok(state)
    }

    /// Look up one tensor's spec in the flat parameter layout.
    pub fn param_tensor(&self, name: &str) -> Option<&TensorSpec> {
        self.param_spec.iter().find(|t| t.name == name)
    }

    /// Edge length of the square RGB input this variant was compiled
    /// for, or `None` when `input_dim` is not `side² · 3` (sequence
    /// variants take token ids, not images).  The one place this
    /// arithmetic lives, shared by the session's dataset
    /// re-instantiation, the CLI's infer path, and the latency sweeps.
    ///
    /// Known limit: a sequence variant whose seq length happens to be
    /// `3·s²` (48, 108, 192, …) would be misclassified; none of the
    /// current model families hit this.  A dedicated manifest input-kind
    /// field is the clean fix once the AOT pipeline emits one.
    pub fn image_side(&self) -> Option<usize> {
        let side = ((self.input_dim / 3) as f64).sqrt().round() as usize;
        (side > 0 && side * side * 3 == self.input_dim).then_some(side)
    }
}

/// A micro-kernel artifact for the L1 benches.
#[derive(Debug, Clone)]
pub struct KernelEntry {
    pub name: String,
    pub hlo: PathBuf,
    pub shapes: BTreeMap<String, Vec<usize>>,
}

/// The parsed manifest.
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    pub kernels: BTreeMap<String, KernelEntry>,
    pub spectra: BTreeMap<String, Vec<f64>>,
    pub perplexity: Option<PerplexityTable>,
    pub eps_grid: Vec<f64>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("param_spec not an array"))?
        .iter()
        .map(|e| {
            Ok(TensorSpec {
                name: e.req("name")?.as_str().unwrap_or_default().to_string(),
                shape: e.req("shape")?.usize_vec()?,
                offset: e.req("offset")?.as_usize().unwrap_or(0),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj().ok_or_else(|| anyhow!("models not an object"))? {
            let get_path = |key: &str| -> Option<PathBuf> {
                m.get(key).and_then(|v| v.as_str()).map(|s| dir.join(s))
            };
            let mut weight_ranks = BTreeMap::new();
            if let Some(obj) = m.get("weight_ranks").and_then(|v| v.as_obj()) {
                for (k, v) in obj {
                    weight_ranks.insert(k.clone(), v.as_usize().unwrap_or(0));
                }
            }
            let mut asi_ranks = BTreeMap::new();
            if let Some(obj) = m.get("asi_ranks").and_then(|v| v.as_obj()) {
                for (k, v) in obj {
                    asi_ranks.insert(k.clone(), v.usize_vec().unwrap_or_default());
                }
            }
            let mut layer_dims = BTreeMap::new();
            if let Some(obj) = m.get("layer_dims").and_then(|v| v.as_obj()) {
                for (k, v) in obj {
                    let oi = v.get("out_in").map(|x| x.usize_vec().unwrap_or_default()).unwrap_or_default();
                    let act = v.get("act").map(|x| x.usize_vec().unwrap_or_default()).unwrap_or_default();
                    layer_dims.insert(k.clone(), (oi, act));
                }
            }
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    train_hlo: get_path("train_hlo"),
                    infer_hlo: get_path("infer_hlo")
                        .ok_or_else(|| anyhow!("model {name} missing infer_hlo"))?,
                    params_file: get_path("params_file")
                        .ok_or_else(|| anyhow!("model {name} missing params_file"))?,
                    state_file: get_path("state_file"),
                    params_len: m.req("params_len")?.as_usize().unwrap_or(0),
                    state_len: m.req("state_len")?.as_usize().unwrap_or(0),
                    batch: m.req("batch")?.as_usize().unwrap_or(0),
                    input_dim: m.req("input_dim")?.as_usize().unwrap_or(0),
                    classes: m.req("classes")?.as_usize().unwrap_or(0),
                    eps: m.get("eps").and_then(|v| v.as_f64()),
                    weight_ranks,
                    asi_ranks,
                    layer_dims,
                    param_spec: m
                        .get("param_spec")
                        .map(tensor_specs)
                        .transpose()?
                        .unwrap_or_default(),
                    state_spec: m
                        .get("state_spec")
                        .map(tensor_specs)
                        .transpose()?
                        .unwrap_or_default(),
                },
            );
        }

        let mut kernels = BTreeMap::new();
        if let Some(obj) = j.get("kernels").and_then(|v| v.as_obj()) {
            for (name, k) in obj {
                let mut shapes = BTreeMap::new();
                if let Some(sh) = k.get("shapes").and_then(|v| v.as_obj()) {
                    for (sn, sv) in sh {
                        shapes.insert(sn.clone(), sv.usize_vec()?);
                    }
                }
                kernels.insert(
                    name.clone(),
                    KernelEntry {
                        name: name.clone(),
                        hlo: dir.join(k.req("hlo")?.as_str().unwrap_or_default()),
                        shapes,
                    },
                );
            }
        }

        let mut spectra = BTreeMap::new();
        if let Some(obj) = j.get("spectra").and_then(|v| v.as_obj()) {
            for (k, v) in obj {
                spectra.insert(k.clone(), v.f64_vec()?);
            }
        }

        let perplexity = match j.get("perplexity") {
            Some(p) => Some(parse_perplexity(p)?),
            None => None,
        };

        let eps_grid = j
            .get("eps_grid")
            .map(|v| v.f64_vec())
            .transpose()?
            .unwrap_or_default();

        Ok(Manifest { dir, models, kernels, spectra, perplexity, eps_grid })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model {name:?} not in manifest; available: {:?}",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    /// WASI ViT variants sorted by ε (the sweep most evals iterate).
    pub fn vit_wasi_variants(&self) -> Vec<&ModelEntry> {
        let mut v: Vec<&ModelEntry> = self
            .models
            .values()
            .filter(|m| m.name.starts_with("vit_wasi_eps"))
            .collect();
        v.sort_by(|a, b| a.eps.partial_cmp(&b.eps).unwrap());
        v
    }
}

fn parse_perplexity(p: &Json) -> Result<PerplexityTable> {
    let layers = p
        .req("layers")?
        .as_arr()
        .ok_or_else(|| anyhow!("layers"))?
        .iter()
        .map(|v| v.as_str().unwrap_or_default().to_string())
        .collect();
    let eps_grid = p.req("eps_grid")?.f64_vec()?;
    let perplexity = p
        .req("perplexity")?
        .as_arr()
        .ok_or_else(|| anyhow!("perplexity"))?
        .iter()
        .map(|row| row.f64_vec())
        .collect::<Result<Vec<_>>>()?;
    let memory = p
        .req("memory")?
        .as_arr()
        .ok_or_else(|| anyhow!("memory"))?
        .iter()
        .map(|row| row.usize_vec())
        .collect::<Result<Vec<_>>>()?;
    let ranks = p
        .req("ranks")?
        .as_arr()
        .ok_or_else(|| anyhow!("ranks"))?
        .iter()
        .map(|row| {
            row.as_arr()
                .ok_or_else(|| anyhow!("ranks row"))?
                .iter()
                .map(|r| r.usize_vec())
                .collect::<Result<Vec<_>>>()
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(PerplexityTable { layers, eps_grid, perplexity, memory, ranks })
}

/// Read a raw little-endian f32 file (params/state vectors).
pub fn read_f32_file(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let bytes = fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("f32 file length {} not divisible by 4", bytes.len()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a raw little-endian f32 file (checkpoints).
pub fn write_f32_file(path: impl AsRef<Path>, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path.as_ref(), bytes)
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let tmp = std::env::temp_dir().join("wasi_train_f32_test.bin");
        let data = vec![1.0f32, -2.5, 3.25e-8, f32::MAX];
        write_f32_file(&tmp, &data).unwrap();
        let back = read_f32_file(&tmp).unwrap();
        assert_eq!(back, data);
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn manifest_loads_if_built() {
        // Integration: only runs when `make artifacts` has been executed.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("vit_vanilla"));
        let vit = m.model("vit_vanilla").unwrap();
        assert!(vit.params_len > 0);
        assert_eq!(vit.input_dim, 32 * 32 * 3);
        let wasi = m.vit_wasi_variants();
        assert!(!wasi.is_empty());
        for w in &wasi {
            assert!(w.state_len > 0);
            assert!(!w.weight_ranks.is_empty());
        }
        if let Some(p) = &m.perplexity {
            p.validate().unwrap();
        }
    }
}

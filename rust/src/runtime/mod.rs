//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! This is the only place the `xla` crate is touched.  The interchange
//! format is HLO *text* (jax >= 0.5 emits protos with 64-bit instruction
//! ids that xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! Python runs once at `make artifacts`; everything in here is pure rust
//! on the request path.

mod artifacts;
mod client;
mod step;

pub use artifacts::{KernelEntry, Manifest, ModelEntry, TensorSpec};
pub use client::{Executable, Runtime};
pub use step::{InferStep, StepOutput, TrainStep};

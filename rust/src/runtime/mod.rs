//! Artifact runtime: load AOT-compiled HLO text artifacts and execute
//! them, through one of two backends behind a single surface.
//!
//! * **PJRT** (`client` module, behind the off-by-default `pjrt` cargo
//!   feature) — compiles and executes the HLO artifacts through the
//!   `xla` crate's PJRT CPU client.  The interchange format is HLO
//!   *text* (jax >= 0.5 emits protos with 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids).
//! * **Native** (`native` module, always available) — pure-rust
//!   fallback on the `linalg` engine.  It executes the repository's own
//!   kernel artifacts (lowrank forward, dense forward, power step) by
//!   running their reference math natively, and returns a descriptive
//!   error for full model HLO programs, which need PJRT.  This is what
//!   keeps the crate buildable and testable in offline/edge CI with no
//!   `xla` dependency at all.
//!
//! Python runs once at `make artifacts`; everything in here is pure
//! rust on the request path.  See DESIGN.md for the backend split.

mod artifacts;
#[cfg(feature = "pjrt")]
mod client;
mod native;
mod step;

use std::path::Path;

use anyhow::Result;

pub use artifacts::{read_f32_file, write_f32_file, KernelEntry, Manifest, ModelEntry, TensorSpec};
#[cfg(feature = "pjrt")]
pub use client::{PjrtExecutable, PjrtRuntime};
pub use native::{NativeExecutable, NativeRuntime};
pub use step::{InferStep, StepOutput, TrainStep};

/// Backend-dispatching runtime handle.
///
/// `Runtime::cpu()` prefers PJRT when the `pjrt` feature is enabled and
/// a client can be created, and falls back to [`NativeRuntime`]
/// otherwise — so `coordinator::Session` and the eval harness work (for
/// the natively-executable subset) in every build configuration.
pub enum Runtime {
    /// PJRT CPU client (feature `pjrt`).
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtRuntime),
    /// Pure-rust fallback engine.
    Native(NativeRuntime),
}

impl Runtime {
    /// Best available CPU runtime: PJRT when compiled in and usable,
    /// the native fallback otherwise.  Never fails.
    pub fn cpu() -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            match PjrtRuntime::cpu() {
                Ok(rt) => return Ok(Runtime::Pjrt(rt)),
                Err(e) => {
                    eprintln!("wasi-train: PJRT unavailable ({e:#}); using native runtime")
                }
            }
        }
        Ok(Runtime::Native(NativeRuntime::new()))
    }

    /// The native fallback runtime, explicitly.
    pub fn native() -> Runtime {
        Runtime::Native(NativeRuntime::new())
    }

    /// Whether this runtime can execute full model HLO programs (i.e.
    /// the PJRT backend is live).  The native fallback executes only
    /// the repository's kernel artifacts.
    pub fn can_execute_hlo(&self) -> bool {
        match self {
            #[cfg(feature = "pjrt")]
            Runtime::Pjrt(_) => true,
            Runtime::Native(_) => false,
        }
    }

    /// Platform name of the active backend (e.g. `cpu` under PJRT,
    /// `native-cpu` for the fallback).
    pub fn platform(&self) -> String {
        match self {
            #[cfg(feature = "pjrt")]
            Runtime::Pjrt(rt) => rt.platform(),
            Runtime::Native(rt) => rt.platform(),
        }
    }

    /// Load (and for PJRT, compile) an HLO text artifact.  Cached per
    /// path within the runtime.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable<'_>> {
        match self {
            #[cfg(feature = "pjrt")]
            Runtime::Pjrt(rt) => Ok(Executable::Pjrt(rt.load(path)?)),
            Runtime::Native(rt) => Ok(Executable::Native(rt.load(path)?)),
        }
    }
}

/// Handle to a loaded executable in either backend.
#[derive(Clone, Copy)]
pub enum Executable<'rt> {
    /// Compiled PJRT executable (feature `pjrt`).
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtExecutable<'rt>),
    /// Native program handle.
    Native(NativeExecutable<'rt>),
}

impl Executable<'_> {
    /// Execute with f32-vector inputs, shapes supplied per input.
    ///
    /// All artifacts emitted by `aot.py` take f32 tensors and return a
    /// tuple of f32 tensors (lowered with `return_tuple=True`); the
    /// result is one flat vector per tuple element.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        match self {
            #[cfg(feature = "pjrt")]
            Executable::Pjrt(exe) => exe.run_f32(inputs),
            Executable::Native(exe) => exe.run_f32(inputs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_runtime_always_constructs() {
        // With default features this is the native backend; with `pjrt`
        // plus the vendored stub it falls back to native at runtime.
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn native_runtime_reports_platform() {
        let rt = Runtime::native();
        assert_eq!(rt.platform(), "native-cpu");
    }

    #[test]
    fn load_of_missing_artifact_errors() {
        let rt = Runtime::native();
        let missing = std::env::temp_dir().join("wasi_no_such_artifact.hlo.txt");
        let err = match rt.load(&missing) {
            Ok(_) => panic!("load of a missing artifact must fail"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}

//! Thin, safe wrapper over the `xla` crate's PJRT CPU client.
//!
//! Only compiled with the `pjrt` cargo feature; this file is the only
//! place the `xla` crate is touched.  The default build ships the
//! vendored compile-time stub of `xla`, so `cargo check --features
//! pjrt` works offline; executing HLO for real requires swapping in the
//! upstream `xla` crate (see README).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// A PJRT client plus a cache of compiled executables keyed by path.
///
/// Compilation of a train-step module takes O(seconds); callers ask for
/// executables by artifact path and get the cached copy on repeat use.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, usize>>,
    executables: Mutex<Vec<xla::PjRtLoadedExecutable>>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self {
            client,
            cache: Mutex::new(HashMap::new()),
            executables: Mutex::new(Vec::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<PjrtExecutable<'_>> {
        let path = path.as_ref().to_path_buf();
        {
            let cache = self.cache.lock().unwrap();
            if let Some(&idx) = cache.get(&path) {
                return Ok(PjrtExecutable { runtime: self, idx });
            }
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        let mut exes = self.executables.lock().unwrap();
        exes.push(exe);
        let idx = exes.len() - 1;
        self.cache.lock().unwrap().insert(path, idx);
        Ok(PjrtExecutable { runtime: self, idx })
    }
}

/// Handle to a compiled executable living in the runtime's cache.
#[derive(Clone, Copy)]
pub struct PjrtExecutable<'a> {
    runtime: &'a PjrtRuntime,
    idx: usize,
}

impl PjrtExecutable<'_> {
    /// Execute with f32-vector inputs, shapes supplied per input.
    ///
    /// All artifacts emitted by `aot.py` take f32 tensors and return a
    /// tuple of f32 tensors (lowered with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = if dims.is_empty() {
                // rank-0 scalar
                lit.reshape(&[]).map_err(|e| anyhow!("reshape scalar: {e:?}"))?
            } else {
                lit.reshape(&dims).map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))?
            };
            literals.push(lit);
        }
        let exes = self.runtime.executables.lock().unwrap();
        let exe = &exes[self.idx];
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // Artifacts are lowered with return_tuple=True: decompose the tuple.
        let elems = out
            .to_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        let mut vecs = Vec::with_capacity(elems.len());
        for lit in elems {
            vecs.push(
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow!("literal to_vec: {e:?}"))
                    .context("artifact outputs must be f32")?,
            );
        }
        Ok(vecs)
    }
}

//! FLOPs model (paper Eqs. 33-40).

/// One linear layer's dimensions: input activation (B, N, I) -> (B, N, O).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerDims {
    pub b: usize, // batch
    pub n: usize, // tokens
    pub i: usize, // input features
    pub o: usize, // output features
}

/// WASI ranks for one layer: weight rank K, activation ranks r = (r1,r2,r3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WasiRanks {
    pub k: usize,
    pub r: [usize; 3],
}

impl LayerDims {
    pub fn dims(&self) -> [usize; 3] {
        [self.b, self.n, self.i]
    }

    /// Eq. 33: vanilla forward FLOPs  ≈ 2 B N I O.
    pub fn f_vanilla(&self) -> f64 {
        2.0 * self.b as f64 * self.n as f64 * self.i as f64 * self.o as f64
    }

    /// Eq. 34: vanilla backward FLOPs  ≈ 4 B N I O.
    pub fn b_vanilla(&self) -> f64 {
        2.0 * self.f_vanilla()
    }

    /// Eq. 35: WASI forward  ≈ 2 B N K (I + O).
    pub fn f_wasi(&self, k: usize) -> f64 {
        2.0 * self.b as f64 * self.n as f64 * k as f64 * (self.i + self.o) as f64
    }

    /// Eq. 36: WSI refresh overhead  = 4 I O K + 2 O K².
    pub fn o_wsi(&self, k: usize) -> f64 {
        4.0 * self.i as f64 * self.o as f64 * k as f64
            + 2.0 * self.o as f64 * (k * k) as f64
    }

    /// Eq. 37: ASI overhead  = Σ_m (4 d d' r_m + 2 d r_m²)
    /// with d = D_m and d' = Π_{j≠m} D_j.
    pub fn o_asi(&self, r: &[usize; 3]) -> f64 {
        let dims = self.dims();
        let total: usize = dims.iter().product();
        let mut acc = 0.0;
        for m in 0..3 {
            let d = dims[m] as f64;
            let dp = (total / dims[m]) as f64;
            let rm = r[m] as f64;
            acc += 4.0 * d * dp * rm + 2.0 * d * rm * rm;
        }
        acc
    }

    /// Eq. 38: WASI backward
    /// = 2 B N K (I+O)  +  B N O r1 + r1 r2 r3 N + r1 r3 I N + r1 I O N.
    ///
    /// NOTE: the published Eq. 38 writes the contraction-chain terms with
    /// O where the factored implementation uses K (the chain runs on dH);
    /// we follow the paper's formula verbatim for the reproduction and
    /// note the discrepancy in DESIGN.md.
    pub fn b_wasi(&self, ranks: &WasiRanks) -> f64 {
        let (b, n, i, o) = (self.b as f64, self.n as f64, self.i as f64, self.o as f64);
        let k = ranks.k as f64;
        let [r1, r2, r3] = [ranks.r[0] as f64, ranks.r[1] as f64, ranks.r[2] as f64];
        2.0 * b * n * k * (i + o)
            + b * n * o * r1
            + r1 * r2 * r3 * n
            + r1 * r3 * i * n
            + r1 * i * o * n
    }

    /// Eq. 39: S_training = (F_v + B_v) / (F_w + O_wsi + O_asi + B_w).
    pub fn s_training(&self, ranks: &WasiRanks) -> f64 {
        (self.f_vanilla() + self.b_vanilla())
            / (self.f_wasi(ranks.k) + self.o_wsi(ranks.k) + self.o_asi(&ranks.r)
                + self.b_wasi(ranks))
    }

    /// Eq. 40: S_inference = F_vanilla / F_WASI.
    pub fn s_inference(&self, k: usize) -> f64 {
        self.f_vanilla() / self.f_wasi(k)
    }

    /// Total WASI training FLOPs for this layer.
    pub fn wasi_train_flops(&self, ranks: &WasiRanks) -> f64 {
        self.f_wasi(ranks.k) + self.o_wsi(ranks.k) + self.o_asi(&ranks.r) + self.b_wasi(ranks)
    }

    /// Total vanilla training FLOPs for this layer.
    pub fn vanilla_train_flops(&self) -> f64 {
        self.f_vanilla() + self.b_vanilla()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const L: LayerDims = LayerDims { b: 128, n: 197, i: 768, o: 3072 };

    #[test]
    fn vanilla_ratios() {
        assert_eq!(L.b_vanilla(), 2.0 * L.f_vanilla());
        let fwd = 2.0 * 128.0 * 197.0 * 768.0 * 3072.0;
        assert!((L.f_vanilla() - fwd).abs() < 1.0);
    }

    #[test]
    fn speedup_converges_to_one_at_full_rank() {
        // As K -> min(I, O) and r -> dims, WASI cost approaches (and with
        // overheads exceeds) vanilla: S_training <= ~1 (paper §3.4).
        let full = WasiRanks { k: 768, r: [128, 197, 768] };
        assert!(L.s_training(&full) < 1.0);
        // inference crossover: K(I+O) vs I O -> K* = IO/(I+O)
        let kstar = (768 * 3072) / (768 + 3072);
        assert!(L.s_inference(kstar) > 0.99 && L.s_inference(kstar) < 1.01);
    }

    #[test]
    fn speedup_grows_with_compression() {
        let low = WasiRanks { k: 32, r: [8, 16, 32] };
        let mid = WasiRanks { k: 128, r: [16, 32, 64] };
        assert!(L.s_training(&low) > L.s_training(&mid));
        assert!(L.s_training(&low) > 1.0, "low rank must speed up");
        assert!(L.s_inference(32) > L.s_inference(128));
    }

    #[test]
    fn monotone_in_k() {
        let mut prev = f64::INFINITY;
        for k in [16, 32, 64, 128, 256] {
            let s = L.s_inference(k);
            assert!(s < prev);
            prev = s;
        }
    }
}

//! Fig. 2 generator: C/S training+inference surfaces over (dims, rank).

use super::flops::{LayerDims, WasiRanks};

/// One point of the Fig. 2 surfaces.
#[derive(Debug, Clone)]
pub struct CurvePoint {
    pub dim: usize,
    pub rank: usize,
    pub c_training: f64,
    pub c_inference: f64,
    pub s_training: f64,
    pub s_inference: f64,
}

/// Sweep square layers (I = O = dim, N tokens) over ranks, applying the
/// same rank to weights and all activation modes, exactly as §3.4 assumes
/// ("the same optimal rank is applied to both A_i and W_i").
pub fn fig2_sweep(batch: usize, n_tokens: usize, dims: &[usize], ranks: &[usize]) -> Vec<CurvePoint> {
    let mut out = Vec::new();
    for &dim in dims {
        for &rank in ranks {
            if rank > dim || rank > batch.max(1) * 0 + dim {
                continue;
            }
            let l = LayerDims { b: batch, n: n_tokens, i: dim, o: dim };
            let r = [rank.min(batch), rank.min(n_tokens), rank.min(dim)];
            let wr = WasiRanks { k: rank.min(dim), r };
            out.push(CurvePoint {
                dim,
                rank,
                c_training: l.c_training(&wr),
                c_inference: l.c_inference(wr.k),
                s_training: l.s_training(&wr),
                s_inference: l.s_inference(wr.k),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigger_models_compress_more_at_fixed_rank() {
        let pts = fig2_sweep(128, 197, &[256, 512, 1024, 2048], &[32]);
        // paper §3.4: "As model size grows and the optimal rank decreases,
        // WASI delivers greater memory compression and speedup".
        for w in pts.windows(2) {
            assert!(w[1].c_training > w[0].c_training);
            assert!(w[1].s_inference > w[0].s_inference);
        }
    }

    #[test]
    fn ratios_approach_one_at_high_rank() {
        let pts = fig2_sweep(128, 197, &[1024], &[16, 64, 256, 512]);
        let last = pts.last().unwrap();
        assert!(last.s_inference < 1.2, "s_inf {}", last.s_inference);
        let first = pts.first().unwrap();
        assert!(first.s_inference > 10.0);
    }
}

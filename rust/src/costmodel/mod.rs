//! Analytic cost model — the paper's Appendix A.3 formulas, exactly.
//!
//! FLOPs (Eqs. 33-40) and memory (Eqs. 41-46) for vanilla vs WASI
//! training/inference of a linear layer, plus whole-model aggregation
//! over layer-dimension tables for ViT / SwinT / TinyLlama-like models.
//! These regenerate Fig. 2 and the memory/FLOPs axes of Figs. 5-7,
//! 10-11 and Tab. 1.

pub mod curves;
pub mod flops;
pub mod layer_specs;
pub mod memory;

pub use flops::{LayerDims, WasiRanks};

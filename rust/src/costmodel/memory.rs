//! Memory model (paper Eqs. 41-46).  Counts are ELEMENTS; multiply by 4
//! for f32 bytes (helpers provided).  The precision-aware variants
//! price the weight terms at a reduced storage format
//! (`crate::precision`) — the low-memory edge-inference scenario the
//! paper's 62× headline is about compounds the subspace compression
//! with 2-byte bf16 or 1-byte int8 weights.

use crate::precision::Precision;

use super::flops::{LayerDims, WasiRanks};

pub const BYTES_PER_ELEM: f64 = 4.0;

impl LayerDims {
    /// Eq. 41: vanilla weight memory = I O.
    pub fn m_vanilla_w(&self) -> f64 {
        (self.i * self.o) as f64
    }

    /// Eq. 42: vanilla activation memory = B N I.
    pub fn m_vanilla_a(&self) -> f64 {
        (self.b * self.n * self.i) as f64
    }

    /// Eq. 43: WASI weight memory = K (I + O).
    pub fn m_wasi_w(&self, k: usize) -> f64 {
        (k * (self.i + self.o)) as f64
    }

    /// Eq. 44: WASI activation memory = Π r_m + Σ D_m r_m.
    pub fn m_wasi_a(&self, r: &[usize; 3]) -> f64 {
        let dims = self.dims();
        let core: usize = r.iter().product();
        let factors: usize = dims.iter().zip(r).map(|(d, rm)| d * rm).sum();
        (core + factors) as f64
    }

    /// Eq. 45: training memory compression C_training.
    pub fn c_training(&self, ranks: &WasiRanks) -> f64 {
        (self.m_vanilla_w() + self.m_vanilla_a())
            / (self.m_wasi_w(ranks.k) + self.m_wasi_a(&ranks.r))
    }

    /// Eq. 46: inference memory compression C_inference.
    pub fn c_inference(&self, k: usize) -> f64 {
        self.m_vanilla_w() / self.m_wasi_w(k)
    }

    /// Eq. 41 in BYTES at a weight-storage precision.
    pub fn m_vanilla_w_bytes(&self, p: Precision) -> f64 {
        self.m_vanilla_w() * p.bytes_per_elem()
    }

    /// Eq. 43 in BYTES at a weight-storage precision.
    pub fn m_wasi_w_bytes(&self, k: usize, p: Precision) -> f64 {
        self.m_wasi_w(k) * p.bytes_per_elem()
    }

    /// Eq. 46 against the f32 vanilla baseline with WASI weights stored
    /// at precision `p`: the subspace compression and the storage-width
    /// reduction compound (`c_inference_at(k, F32) == c_inference(k)`).
    pub fn c_inference_at(&self, k: usize, p: Precision) -> f64 {
        self.m_vanilla_w_bytes(Precision::F32) / self.m_wasi_w_bytes(k, p)
    }

    /// WASI training memory (elements) for this layer.
    pub fn wasi_train_mem(&self, ranks: &WasiRanks) -> f64 {
        self.m_wasi_w(ranks.k) + self.m_wasi_a(&ranks.r)
    }

    /// Vanilla training memory (elements).
    pub fn vanilla_train_mem(&self) -> f64 {
        self.m_vanilla_w() + self.m_vanilla_a()
    }
}

/// 4D variant of Eq. 44 (SwinLite): dims = (B, H, W, I).
pub fn m_wasi_a_4d(dims: &[usize; 4], r: &[usize; 4]) -> f64 {
    let core: usize = r.iter().product();
    let factors: usize = dims.iter().zip(r).map(|(d, rm)| d * rm).sum();
    (core + factors) as f64
}

pub fn elems_to_mb(elems: f64) -> f64 {
    elems * BYTES_PER_ELEM / (1024.0 * 1024.0)
}

/// Arena-reuse ratio of the pass pipeline's planned program: the summed
/// no-reuse buffer footprint over the liveness-packed arena size
/// (both in elements).  > 1 means the liveness plan shares storage;
/// the `plan` subcommand and the bench's `passes` section report it.
pub fn arena_reuse_ratio(sum_elems: usize, arena_elems: usize) -> f64 {
    if arena_elems == 0 {
        return 1.0;
    }
    sum_elems as f64 / arena_elems as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    const L: LayerDims = LayerDims { b: 128, n: 197, i: 768, o: 3072 };

    #[test]
    fn formulas_match_paper() {
        assert_eq!(L.m_vanilla_w(), 768.0 * 3072.0);
        assert_eq!(L.m_vanilla_a(), 128.0 * 197.0 * 768.0);
        assert_eq!(L.m_wasi_w(64), 64.0 * (768.0 + 3072.0));
        let r = [8usize, 16, 32];
        assert_eq!(
            L.m_wasi_a(&r),
            (8 * 16 * 32 + 128 * 8 + 197 * 16 + 768 * 32) as f64
        );
    }

    #[test]
    fn compression_large_at_low_rank() {
        let ranks = WasiRanks { k: 16, r: [4, 8, 16] };
        assert!(L.c_training(&ranks) > 50.0, "c_tr {}", L.c_training(&ranks));
        assert!(L.c_inference(16) > 30.0);
    }

    #[test]
    fn compression_near_one_at_full_rank() {
        // At K = IO/(I+O) the weight memory matches vanilla.
        let kstar = (768 * 3072) / (768 + 3072);
        let c = L.c_inference(kstar);
        assert!((c - 1.0).abs() < 0.02, "c = {c}");
    }

    #[test]
    fn mb_conversion() {
        assert!((elems_to_mb(1024.0 * 1024.0) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn arena_reuse_ratio_is_safe_and_ordered() {
        assert_eq!(arena_reuse_ratio(0, 0), 1.0);
        assert!((arena_reuse_ratio(300, 100) - 3.0).abs() < 1e-12);
        assert!(arena_reuse_ratio(100, 100) >= 1.0);
    }

    #[test]
    fn precision_compounds_with_subspace_compression() {
        assert!((L.c_inference_at(64, Precision::F32) - L.c_inference(64)).abs() < 1e-12);
        assert!(
            (L.c_inference_at(64, Precision::Bf16) - 2.0 * L.c_inference(64)).abs() < 1e-9,
            "bf16 halves the weight bytes"
        );
        assert!(
            (L.c_inference_at(64, Precision::I8) - 4.0 * L.c_inference(64)).abs() < 1e-9,
            "int8 quarters the weight bytes"
        );
        assert_eq!(L.m_wasi_w_bytes(64, Precision::I8), L.m_wasi_w(64));
    }

    #[test]
    fn four_d_memory() {
        let dims = [16usize, 16, 16, 192];
        let r = [4usize, 8, 8, 24];
        let m = m_wasi_a_4d(&dims, &r);
        assert_eq!(
            m,
            (4 * 8 * 8 * 24 + 16 * 4 + 16 * 8 + 16 * 8 + 192 * 24) as f64
        );
        assert!(m < (16 * 16 * 16 * 192) as f64);
    }
}

//! Layer-dimension tables for the models the paper evaluates.
//!
//! These are the *paper-scale* models (ViT-B/16, Swin-T, TinyLlama-1.1B)
//! used by the analytic exhibits (Fig. 2 surfaces, the memory/FLOPs axes
//! of Figs. 5-7/10-11, Tab. 1) — the executable artifacts use the tiny
//! configs from `aot.py`, but the cost model speaks both scales.

use super::flops::LayerDims;

/// A named model as a list of (layer name, dims) for its MLP linears.
pub struct ModelSpec {
    pub name: &'static str,
    pub layers: Vec<(String, LayerDims)>,
}

/// ViT-B/16 at 224²: 12 blocks, D=768, hidden=3072, N=197.
pub fn vit_b16(batch: usize) -> ModelSpec {
    let mut layers = Vec::new();
    for blk in 0..12 {
        layers.push((
            format!("blocks.{blk}.mlp.fc1"),
            LayerDims { b: batch, n: 197, i: 768, o: 3072 },
        ));
        layers.push((
            format!("blocks.{blk}.mlp.fc2"),
            LayerDims { b: batch, n: 197, i: 3072, o: 768 },
        ));
    }
    ModelSpec { name: "vit-b16", layers }
}

/// ViT-B/16 including attention projections (paper Tab. 1 scope).
pub fn vit_b16_all_linear(batch: usize) -> ModelSpec {
    let mut spec = vit_b16(batch);
    for blk in 0..12 {
        spec.layers.push((
            format!("blocks.{blk}.attn.qkv"),
            LayerDims { b: batch, n: 197, i: 768, o: 2304 },
        ));
        spec.layers.push((
            format!("blocks.{blk}.attn.proj"),
            LayerDims { b: batch, n: 197, i: 768, o: 768 },
        ));
    }
    spec.name = "vit-b16-all";
    spec
}

/// Swin-T: 4 stages (2,2,6,2) with dims (96,192,384,768); token counts
/// 56², 28², 14², 7² — MLP linears only.  Activations are 4D in the real
/// model; here N = H*W for the 3D cost model (the 4D memory variant is
/// exercised separately via `memory::m_wasi_a_4d`).
pub fn swin_t(batch: usize) -> ModelSpec {
    let stages: [(usize, usize, usize); 4] =
        [(2, 96, 56), (2, 192, 28), (6, 384, 14), (2, 768, 7)];
    let mut layers = Vec::new();
    for (s, (depth, dim, side)) in stages.iter().enumerate() {
        for blk in 0..*depth {
            let n = side * side;
            layers.push((
                format!("stages.{s}.blocks.{blk}.mlp.fc1"),
                LayerDims { b: batch, n, i: *dim, o: 4 * dim },
            ));
            layers.push((
                format!("stages.{s}.blocks.{blk}.mlp.fc2"),
                LayerDims { b: batch, n, i: 4 * dim, o: *dim },
            ));
        }
    }
    ModelSpec { name: "swin-t", layers }
}

/// TinyLlama-1.1B: 22 blocks, D=2048, hidden=5632, seq len 512.
/// `last_k` restricts to the last k blocks (the Fig. 7 sweep).
pub fn tinyllama(batch: usize, seq: usize, last_k: usize) -> ModelSpec {
    let depth = 22;
    let start = depth - last_k.min(depth);
    let mut layers = Vec::new();
    for blk in start..depth {
        // LLaMA MLP: gate+up (2 x D->H) and down (H->D).
        layers.push((
            format!("blocks.{blk}.mlp.gate"),
            LayerDims { b: batch, n: seq, i: 2048, o: 5632 },
        ));
        layers.push((
            format!("blocks.{blk}.mlp.up"),
            LayerDims { b: batch, n: seq, i: 2048, o: 5632 },
        ));
        layers.push((
            format!("blocks.{blk}.mlp.down"),
            LayerDims { b: batch, n: seq, i: 5632, o: 2048 },
        ));
    }
    ModelSpec { name: "tinyllama", layers }
}

/// MCUNet-like conv spec for the Fig. 12 WSI-on-conv study: conv weights
/// reshaped (O, I·k·k) — the last four convs of a compact backbone.
pub fn mcunet_tail() -> Vec<(String, usize, usize)> {
    vec![
        ("conv.-4".into(), 160, 960),  // O, I*k*k (pointwise/depthwise mix)
        ("conv.-3".into(), 320, 1440),
        ("conv.-2".into(), 640, 2880),
        ("conv.-1".into(), 1280, 640),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vit_has_24_mlp_linears() {
        let s = vit_b16(128);
        assert_eq!(s.layers.len(), 24);
        assert!(s.layers.iter().all(|(_, d)| d.b == 128 && d.n == 197));
    }

    #[test]
    fn all_linear_adds_attention() {
        assert_eq!(vit_b16_all_linear(1).layers.len(), 48);
    }

    #[test]
    fn swin_dims_follow_stages() {
        let s = swin_t(64);
        assert_eq!(s.layers.len(), 2 * (2 + 2 + 6 + 2));
        // first stage tokens = 56*56
        assert_eq!(s.layers[0].1.n, 3136);
        // last stage dim = 768
        assert_eq!(s.layers.last().unwrap().1.i, 4 * 768);
    }

    #[test]
    fn tinyllama_last_k() {
        assert_eq!(tinyllama(4, 512, 5).layers.len(), 15);
        assert_eq!(tinyllama(4, 512, 22).layers.len(), 66);
        assert_eq!(tinyllama(4, 512, 99).layers.len(), 66);
    }
}

//! Host calibration: measure this machine's sustained matmul GFLOP/s and
//! effective memory bandwidth so projections anchor to reality.

use std::time::Instant;

use crate::data::rng::Pcg64;
use crate::linalg::matrix::Mat;

use super::spec::DeviceSpec;

/// Measure sustained dense-matmul GFLOP/s with the native engine.
pub fn measure_gflops(size: usize, reps: usize) -> f64 {
    let mut rng = Pcg64::new(0xca11);
    let a = Mat::random(size, size, &mut rng);
    let b = Mat::random(size, size, &mut rng);
    let _warm = a.matmul(&b);
    let t0 = Instant::now();
    let mut sink = 0.0f32;
    for _ in 0..reps {
        let c = a.matmul(&b);
        sink += c.data[0];
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(sink);
    let flops = 2.0 * (size as f64).powi(3) * reps as f64;
    flops / dt / 1e9
}

/// Measure effective stream bandwidth (GB/s) with a big copy+add.
pub fn measure_bandwidth(mb: usize, reps: usize) -> f64 {
    let n = mb * 1024 * 1024 / 4;
    let src = vec![1.0f32; n];
    let mut dst = vec![0.0f32; n];
    let t0 = Instant::now();
    for r in 0..reps {
        let s = r as f32;
        for (d, x) in dst.iter_mut().zip(&src) {
            *d = x + s;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(dst[0]);
    // 2 streams (read + write) per element
    (2.0 * n as f64 * 4.0 * reps as f64) / dt / 1e9
}

/// Full host profile as a DeviceSpec (power unknown: use a desktop-class
/// placeholder; the host profile is only used for time, not energy).
pub fn host_profile() -> DeviceSpec {
    let gflops = measure_gflops(256, 4);
    let mem = measure_bandwidth(64, 2);
    DeviceSpec {
        name: "host",
        gflops,
        mem_gbps: mem,
        power_active_w: 65.0,
        power_idle_w: 15.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gflops_positive_and_sane() {
        let g = measure_gflops(96, 1);
        assert!(g > 0.05 && g < 10_000.0, "gflops {g}");
    }

    #[test]
    fn bandwidth_positive() {
        let b = measure_bandwidth(4, 1);
        assert!(b > 0.1 && b < 2_000.0, "bw {b}");
    }
}

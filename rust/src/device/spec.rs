//! Device profiles.  Numbers are public-spec order-of-magnitude figures
//! (sustained f32 GFLOPs on CPU-only inference workloads, not peak), which
//! is all the roofline projection needs to reproduce the paper's *ratios*.

/// An edge-device profile for the roofline simulator.
#[derive(Debug, Clone, Copy)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Sustained f32 GFLOP/s for dense matmul-bound work.
    pub gflops: f64,
    /// Sustained memory bandwidth, GB/s.
    pub mem_gbps: f64,
    /// Active power draw under full load, watts.
    pub power_active_w: f64,
    /// Idle/base power, watts.
    pub power_idle_w: f64,
}

/// The boards in the paper's Tables 2-4 plus this host (calibrated live).
pub const DEVICES: &[DeviceSpec] = &[
    DeviceSpec { name: "raspberry-pi-5", gflops: 28.0, mem_gbps: 8.5, power_active_w: 7.5, power_idle_w: 2.5 },
    DeviceSpec { name: "raspberry-pi-4", gflops: 11.0, mem_gbps: 4.0, power_active_w: 6.0, power_idle_w: 2.0 },
    DeviceSpec { name: "jetson-orin", gflops: 120.0, mem_gbps: 34.0, power_active_w: 15.0, power_idle_w: 5.0 },
    DeviceSpec { name: "jetson-nano", gflops: 12.0, mem_gbps: 6.0, power_active_w: 7.0, power_idle_w: 2.0 },
];

pub fn device(name: &str) -> Option<DeviceSpec> {
    DEVICES.iter().find(|d| d.name == name).copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_works() {
        assert!(device("raspberry-pi-5").is_some());
        assert!(device("cray-1").is_none());
    }

    #[test]
    fn relative_ordering_matches_paper() {
        // Paper Tab. 3: Orin fastest, Nano slowest of the Jetsons; Pi4
        // slower than Pi5.
        let orin = device("jetson-orin").unwrap();
        let nano = device("jetson-nano").unwrap();
        let pi5 = device("raspberry-pi-5").unwrap();
        let pi4 = device("raspberry-pi-4").unwrap();
        assert!(orin.gflops > pi5.gflops);
        assert!(pi5.gflops > pi4.gflops);
        assert!(orin.gflops > nano.gflops);
    }
}

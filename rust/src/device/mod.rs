//! Edge-device simulator (the Raspberry Pi / Jetson substitution,
//! DESIGN.md §3).
//!
//! The paper measures per-iteration wallclock and energy on physical
//! boards; we (a) measure real wallclock on this host via the PJRT
//! executables and the native engine, and (b) project to each board with
//! a calibrated roofline model: t = max(flops / F_dev, bytes / B_dev) per
//! phase, energy = P_dev(util) * t.  Speedup *ratios* — what the paper
//! actually reports — transfer through the roofline.

pub mod calibrate;
pub mod energy;
pub mod latency;
pub mod spec;

pub use latency::estimate_latency;
pub use spec::{DeviceSpec, DEVICES};

//! Roofline latency projection: t = max(compute, memory) per phase.

use super::spec::DeviceSpec;

/// A workload phase in FLOPs + bytes moved.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub flops: f64,
    pub bytes: f64,
}

/// Roofline time for one phase on a device, seconds.
pub fn phase_time(dev: &DeviceSpec, w: &Workload) -> f64 {
    let compute = w.flops / (dev.gflops * 1e9);
    let memory = w.bytes / (dev.mem_gbps * 1e9);
    compute.max(memory)
}

/// Total latency over phases with a fixed per-iteration framework
/// overhead fraction (interpreter/dispatch; fitted from host calibration).
pub fn estimate_latency(dev: &DeviceSpec, phases: &[Workload], overhead_frac: f64) -> f64 {
    let t: f64 = phases.iter().map(|w| phase_time(dev, w)).sum();
    t * (1.0 + overhead_frac)
}

/// Project a measured host time to a device via the compute-roofline
/// ratio (used when we have real wallclock for the exact workload).
pub fn project_time(host_time_s: f64, host_gflops: f64, dev: &DeviceSpec,
                    arithmetic_intensity: f64) -> f64 {
    // effective rate = min(F, B * AI); ratio of host to device rates.
    let host_rate = host_gflops * 1e9;
    let dev_rate = (dev.gflops * 1e9).min(dev.mem_gbps * 1e9 * arithmetic_intensity);
    host_time_s * host_rate / dev_rate
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::spec::device;

    #[test]
    fn compute_bound_phase() {
        let dev = device("raspberry-pi-5").unwrap();
        // high arithmetic intensity -> compute bound
        let w = Workload { flops: 28e9, bytes: 1e6 };
        assert!((phase_time(&dev, &w) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn memory_bound_phase() {
        let dev = device("raspberry-pi-5").unwrap();
        let w = Workload { flops: 1e6, bytes: 8.5e9 };
        assert!((phase_time(&dev, &w) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn faster_device_is_faster() {
        let pi = device("raspberry-pi-4").unwrap();
        let orin = device("jetson-orin").unwrap();
        let w = Workload { flops: 1e10, bytes: 1e8 };
        assert!(phase_time(&orin, &w) < phase_time(&pi, &w));
    }

    #[test]
    fn projection_preserves_ratio() {
        let pi5 = device("raspberry-pi-5").unwrap();
        // Two workloads with 2x time ratio keep 2x after projection.
        let a = project_time(1.0, 50.0, &pi5, 100.0);
        let b = project_time(2.0, 50.0, &pi5, 100.0);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}

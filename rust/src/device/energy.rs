//! Energy model (Tab. 4): E = P_active * t_busy + P_idle * t_idle.
//! The paper measures with an INA3221 sensor on the Jetson Orin; the
//! first-order model is power-at-utilization times phase time.

use super::spec::DeviceSpec;

/// Energy for a phase that keeps the device at `util` in [0,1] for `t` s.
pub fn phase_energy(dev: &DeviceSpec, t_seconds: f64, util: f64) -> f64 {
    let p = dev.power_idle_w + (dev.power_active_w - dev.power_idle_w) * util.clamp(0.0, 1.0);
    p * t_seconds
}

/// Training iterations keep the CPU pinned; inference batches too.  The
/// paper's Tab. 4 rows are one inference pass + one training iteration.
pub fn iteration_energy(dev: &DeviceSpec, t_seconds: f64) -> f64 {
    phase_energy(dev, t_seconds, 0.95)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::spec::device;

    #[test]
    fn energy_scales_with_time() {
        let dev = device("jetson-orin").unwrap();
        let e1 = iteration_energy(&dev, 1.0);
        let e2 = iteration_energy(&dev, 2.0);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn idle_less_than_active() {
        let dev = device("jetson-orin").unwrap();
        assert!(phase_energy(&dev, 1.0, 0.0) < phase_energy(&dev, 1.0, 1.0));
    }

    #[test]
    fn orin_magnitudes_plausible() {
        // Paper Tab. 4: vanilla inference 6.84s -> 47.51 J (≈7 W average).
        let dev = device("jetson-orin").unwrap();
        let e = iteration_energy(&dev, 6.84);
        assert!(e > 30.0 && e < 150.0, "e = {e}");
    }
}

//! A counting wrapper around the system allocator.
//!
//! The bench's `passes` section pins "per-step heap allocations in
//! steady state are ~zero" with a real number: `wasi-train`'s `main.rs`
//! installs [`CountingAllocator`] as the `#[global_allocator]`, and the
//! bench reads [`allocation_count`] around a timed region.  The counter
//! is a single relaxed atomic increment per `alloc` — cheap enough to
//! leave on unconditionally, and `dealloc`/`realloc` pass straight
//! through.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// System allocator plus a process-wide allocation counter.
pub struct CountingAllocator;

// SAFETY: defers entirely to `System`; the counter does not allocate.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Heap allocations performed by this process so far.  Monotone; bench
/// code diffs two reads around a region.  Reads 0 forever unless the
/// binary installed [`CountingAllocator`] (unit tests run under the
/// default allocator, so tests must not assert non-zero counts).
pub fn allocation_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

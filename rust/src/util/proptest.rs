//! Mini property-test harness (no proptest crate in the vendored set).
//!
//! Runs a property over `cases` randomized inputs from a seeded PCG
//! stream; on failure it reports the case index and seed so the case is
//! exactly reproducible.  Sizes shrink geometrically on failure to find
//! a smaller counterexample (structural shrinking only — enough for the
//! coordinator/linalg invariants this project checks).

use crate::data::rng::Pcg64;

/// A source of random test inputs.
pub struct Gen {
    pub rng: Pcg64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.rng.next_u64() as usize) % (hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.next_normal()).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `prop` over `cases` random cases.  Panics with a reproducible
/// seed + case number on the first failure.
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(name: &str, cases: usize, mut prop: F) {
    let base_seed = 0x5eed_0000u64;
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut g = Gen { rng: Pcg64::new(seed) };
        if let Err(msg) = prop(&mut g) {
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two slices are element-wise close (relative to max magnitude).
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    let scale = b.iter().fold(1e-6f32, |m, x| m.max(x.abs()));
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * scale {
            return Err(format!(
                "element {i}: {x} vs {y} (|diff|={} > tol*scale={})",
                (x - y).abs(),
                tol * scale
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counts", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn check_reports_failure() {
        check("fails", 5, |g| {
            if g.usize_in(0, 10) <= 10 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_detects_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(assert_close(&[1.0, 2.1], &[1.0, 2.0], 1e-3).is_err());
    }
}

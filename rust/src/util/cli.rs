//! Tiny CLI argument parser (no clap in the vendored crate set).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be written `--key=value` or `--key value`.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit arg list (first element = argv[1]).
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer, got {v:?}: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects a number, got {v:?}: {e}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Reject any `--option`/`--flag` not in the subcommand's accepted
    /// set, so a typo (`--step 50`) errors instead of silently falling
    /// back to a default.
    ///
    /// The two lists are checked as a union on both sides: the parser
    /// classifies `--name` as an option or a flag by whether a value
    /// token follows, so an accepted flag written with a value (or an
    /// accepted option written trailing) must not be rejected here —
    /// the per-subcommand handler still reads it through the accessor
    /// that matches its kind.
    pub fn reject_unknown(&self, subcommand: &str, options: &[&str], flags: &[&str]) -> Result<()> {
        let known = |name: &str| options.contains(&name) || flags.contains(&name);
        let unknown = self
            .options
            .keys()
            .map(|k| k.as_str())
            .chain(self.flags.iter().map(|f| f.as_str()))
            .find(|name| !known(name));
        let Some(name) = unknown else { return Ok(()) };
        let mut accepted: Vec<&str> = options.iter().chain(flags).copied().collect();
        accepted.sort_unstable();
        let hint = accepted
            .iter()
            .find(|a| a.starts_with(name) || name.starts_with(**a))
            .map(|a| format!(" (did you mean --{a}?)"))
            .unwrap_or_default();
        Err(anyhow!(
            "unknown option --{name} for `{subcommand}`{hint}; accepted: {}",
            accepted
                .iter()
                .map(|a| format!("--{a}"))
                .collect::<Vec<_>>()
                .join(", ")
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --model vit_wasi_eps80 --steps 100 extra --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("vit_wasi_eps80"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("eval --eps=0.8 --out=/tmp/x");
        assert_eq!(a.f64_or("eps", 0.0).unwrap(), 0.8);
        assert_eq!(a.get("out"), Some("/tmp/x"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --steps nope");
        assert!(a.usize_or("steps", 1).is_err());
    }

    #[test]
    fn key_value_vs_equals_vs_trailing_parse_identically_for_lookup() {
        // `--key value`, `--key=value`, and a trailing `--flag` are the
        // three parse shapes; pin where each lands.
        let spaced = parse("train --steps 50");
        let equals = parse("train --steps=50");
        let trailing = parse("train --steps");
        assert_eq!(spaced.get("steps"), Some("50"));
        assert_eq!(equals.get("steps"), Some("50"));
        assert_eq!(spaced.options, equals.options);
        // A trailing `--steps` has no value token, so it parses as a
        // flag — get() misses, flag() hits.
        assert_eq!(trailing.get("steps"), None);
        assert!(trailing.flag("steps"));
        // `--key=value` never swallows the next token.
        let mixed = parse("train --out=/tmp/x extra");
        assert_eq!(mixed.get("out"), Some("/tmp/x"));
        assert_eq!(mixed.positional, vec!["extra"]);
        // A flag followed by another option stays a flag.
        let flagged = parse("train --quick --steps 9");
        assert!(flagged.flag("quick"));
        assert_eq!(flagged.usize_or("steps", 0).unwrap(), 9);
    }

    #[test]
    fn reject_unknown_accepts_known_and_rejects_typos() {
        let a = parse("train --steps 50 --silent");
        assert!(a.reject_unknown("train", &["steps"], &["silent"]).is_ok());

        // The motivating bug: `--step 50` must error, not silently use
        // the default step count — and suggest the close match.
        let typo = parse("train --step 50");
        let err = typo.reject_unknown("train", &["steps", "model"], &["silent"]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown option --step"), "{msg}");
        assert!(msg.contains("did you mean --steps?"), "{msg}");
        assert!(msg.contains("--model"), "accepted set must be listed: {msg}");

        // Unknown flags (no value) are rejected too.
        let flag = parse("train --frobnicate");
        assert!(flag.reject_unknown("train", &["steps"], &["silent"]).is_err());
    }

    #[test]
    fn reject_unknown_tolerates_kind_mismatch() {
        // A declared flag written with a value parses as an option; a
        // declared option written trailing parses as a flag.  Both must
        // pass the known-name check (the accessor sorts it out).
        let a = parse("train --silent extra");
        assert_eq!(a.get("silent"), Some("extra"));
        assert!(a.reject_unknown("train", &["steps"], &["silent"]).is_ok());
        let b = parse("train --steps");
        assert!(b.flag("steps"));
        assert!(b.reject_unknown("train", &["steps"], &["silent"]).is_ok());
    }
}

//! Tiny CLI argument parser (no clap in the vendored crate set).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be written `--key=value` or `--key value`.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit arg list (first element = argv[1]).
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer, got {v:?}: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects a number, got {v:?}: {e}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --model vit_wasi_eps80 --steps 100 extra --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("vit_wasi_eps80"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("eval --eps=0.8 --out=/tmp/x");
        assert_eq!(a.f64_or("eps", 0.0).unwrap(), 0.8);
        assert_eq!(a.get("out"), Some("/tmp/x"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --steps nope");
        assert!(a.usize_or("steps", 1).is_err());
    }
}

//! Scoped data-parallel helpers over std::thread (no rayon vendored).
//!
//! The native engine's matmuls and the eval sweeps use `parallel_chunks`
//! to split row ranges across cores.  Work is partitioned statically —
//! the workloads here are regular (dense linear algebra panels), so
//! static partitioning beats a work-stealing queue and costs nothing.

/// Number of worker threads to use (env `WASI_THREADS` overrides).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("WASI_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(chunk_start, chunk_end)` over `0..n` split into per-thread
/// contiguous ranges.  `f` must be Sync; mutation happens through raw
/// pointers or per-chunk output slices owned by the caller.
pub fn parallel_ranges<F: Fn(usize, usize) + Sync>(n: usize, f: F) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n < 64 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Map a function over items in parallel, preserving order.
pub fn parallel_map<T: Sync, U: Send, F: Fn(&T) -> U + Sync>(items: &[T], f: F) -> Vec<U> {
    let threads = num_threads().min(items.len().max(1));
    if threads <= 1 || items.len() < 2 {
        return items.iter().map(&f).collect();
    }
    let mut out: Vec<Option<U>> = (0..items.len()).map(|_| None).collect();
    let chunk = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (i_chunk, out_chunk) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
            let f = &f;
            s.spawn(move || {
                for (x, o) in i_chunk.iter().zip(out_chunk.iter_mut()) {
                    *o = Some(f(x));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::*;

    #[test]
    fn ranges_cover_exactly_once() {
        let count = AtomicUsize::new(0);
        parallel_ranges(1000, |lo, hi| {
            count.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn small_n_runs_inline() {
        let count = AtomicUsize::new(0);
        parallel_ranges(3, |lo, hi| {
            count.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }
}

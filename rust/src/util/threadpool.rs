//! Scoped data-parallel helpers over std::thread (no rayon vendored).
//!
//! The kernel layer (`linalg::kernels`) and the eval sweeps use
//! `parallel_ranges` to split row ranges across cores.  Work is
//! partitioned statically — the workloads here are regular (dense linear
//! algebra panels), so static partitioning beats a work-stealing queue
//! and costs nothing.
//!
//! The worker count is process-global: `set_num_threads` (driven by the
//! CLI `--threads` flag and `FinetuneConfig::threads`) overrides the
//! auto-detected value; `WASI_THREADS` in the environment overrides the
//! hardware default when no explicit override is set.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// 0 = no override (auto-detect).
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Serializes unit tests that mutate the process-global override (lib
/// tests run in parallel; kernel results are override-independent, but
/// assertions ABOUT the override value itself must not interleave).
#[cfg(test)]
pub(crate) static TEST_OVERRIDE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn auto_threads() -> usize {
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        if let Ok(v) = std::env::var("WASI_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Override the worker-thread count for all kernel-layer parallelism
/// (`0` resets to auto-detect).  Kernels partition output rows
/// disjointly, so results are bit-identical across thread counts — this
/// knob trades wall-clock only.
pub fn set_num_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// The raw override value (`0` = auto-detect) — lets a scope that
/// sweeps thread counts (`wasi-train bench`) restore the caller's
/// setting exactly.
pub fn thread_override() -> usize {
    THREAD_OVERRIDE.load(Ordering::Relaxed)
}

/// Scoped thread-count override: applies `FinetuneConfig::threads` (or
/// any explicit count) on construction and restores the caller's raw
/// override on drop, so one session's `threads` setting never leaks
/// into subsequent sessions in the same process.  `apply(None)` is a
/// no-op guard (records and restores the current setting).
///
/// The override is process-global, so overlapping guards on different
/// threads interleave arbitrarily; kernels are bit-deterministic across
/// thread counts, so this only ever perturbs wall-clock (the job
/// service documents that concurrent jobs should leave `threads` unset).
#[must_use = "the guard restores the prior thread count when dropped"]
pub struct ThreadCountGuard {
    prior: usize,
}

impl ThreadCountGuard {
    pub fn apply(threads: Option<usize>) -> ThreadCountGuard {
        let prior = thread_override();
        if let Some(n) = threads {
            set_num_threads(n);
        }
        ThreadCountGuard { prior }
    }
}

impl Drop for ThreadCountGuard {
    fn drop(&mut self) {
        set_num_threads(self.prior);
    }
}

/// Number of worker threads to use (the `set_num_threads` override, else
/// env `WASI_THREADS`, else the hardware parallelism).
pub fn num_threads() -> usize {
    match THREAD_OVERRIDE.load(Ordering::Relaxed) {
        0 => auto_threads(),
        n => n,
    }
}

/// Run `f(chunk_start, chunk_end)` over `0..n` split into per-thread
/// contiguous ranges.  `f` must be Sync; mutation happens through raw
/// pointers or per-chunk output slices owned by the caller.
pub fn parallel_ranges<F: Fn(usize, usize) + Sync>(n: usize, f: F) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n < 64 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Map a function over items in parallel, preserving order.
pub fn parallel_map<T: Sync, U: Send, F: Fn(&T) -> U + Sync>(items: &[T], f: F) -> Vec<U> {
    let threads = num_threads().min(items.len().max(1));
    if threads <= 1 || items.len() < 2 {
        return items.iter().map(&f).collect();
    }
    let mut out: Vec<Option<U>> = (0..items.len()).map(|_| None).collect();
    let chunk = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (i_chunk, out_chunk) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
            let f = &f;
            s.spawn(move || {
                for (x, o) in i_chunk.iter().zip(out_chunk.iter_mut()) {
                    *o = Some(f(x));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::*;

    #[test]
    fn ranges_cover_exactly_once() {
        let count = AtomicUsize::new(0);
        parallel_ranges(1000, |lo, hi| {
            count.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn thread_override_roundtrip() {
        let _guard = TEST_OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(0);
        assert!(num_threads() >= 1);
    }

    #[test]
    fn guard_restores_prior_override() {
        let _guard = TEST_OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_num_threads(7);
        {
            let _g = ThreadCountGuard::apply(Some(2));
            assert_eq!(num_threads(), 2);
        }
        assert_eq!(thread_override(), 7, "guard must restore the caller's setting");
        {
            let _g = ThreadCountGuard::apply(None);
            assert_eq!(thread_override(), 7, "None leaves the setting alone");
        }
        assert_eq!(thread_override(), 7);
        set_num_threads(0);
    }

    #[test]
    fn small_n_runs_inline() {
        let count = AtomicUsize::new(0);
        parallel_ranges(3, |lo, hi| {
            count.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }
}

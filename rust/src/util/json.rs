//! Minimal JSON parser + writer (no serde in the vendored crate set).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the eval-harness output files: objects, arrays, strings (with escapes),
//! numbers, booleans, null.  Numbers are stored as f64 (the manifest only
//! carries shapes, offsets, spectra, and perplexities — all exact in f64).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.  Objects use BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest reading).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("expected number")))
            .collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()
            .ok_or_else(|| anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("expected number")))
            .collect()
    }

    // -- writer --------------------------------------------------------------
    // Serialization goes through `Display`, so `.to_string()` keeps
    // working at every call site via the blanket `ToString` impl.

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Builder helpers so eval modules can construct output JSON tersely.
pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Number that degrades to `null` when not finite — `Json::Num`
/// serializes NaN/inf as-is, which is not valid JSON, so any metric
/// that can legitimately be NaN (a loss before the first step, an
/// accuracy over an empty split) goes through this instead.
pub fn finite_num(n: f64) -> Json {
    if n.is_finite() {
        Json::Num(n)
    } else {
        Json::Null
    }
}

pub fn str(s: impl Into<String>) -> Json {
    Json::Str(s.into())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("invalid escape at {}", self.i),
                    }
                }
                c => {
                    // Re-assemble multi-byte UTF-8 runs.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let text = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().f64_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("b").unwrap().get("d").unwrap().as_bool(), Some(true));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_scientific_numbers() {
        let v = Json::parse("[1e-3, 2.5E2, -1.25e+1]").unwrap();
        assert_eq!(v.f64_vec().unwrap(), vec![1e-3, 250.0, -12.5]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""café ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ✓");
    }

    #[test]
    fn deterministic_output() {
        let v = obj(vec![("z", num(1.0)), ("a", num(2.0))]);
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn finite_num_degrades_to_null() {
        assert_eq!(finite_num(1.5).to_string(), "1.5");
        assert_eq!(finite_num(f64::NAN).to_string(), "null");
        assert_eq!(finite_num(f64::INFINITY).to_string(), "null");
        // The output stays parseable either way.
        assert!(Json::parse(&finite_num(f64::NAN).to_string()).is_ok());
    }
}

//! Aligned ASCII tables for the eval harness (paper-style rows).

/// Column-aligned table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(headers: I) -> Table {
        Table {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn title(mut self, t: impl Into<String>) -> Table {
        self.title = Some(t.into());
        self
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) {
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let ncol = self
            .headers
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("== {t} ==\n"));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, &w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(|s| s.as_str()).unwrap_or("");
                line.push_str(&format!("{cell:<w$}"));
                if i + 1 < widths.len() {
                    line.push_str("  ");
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol.saturating_sub(1))));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers used across the eval modules.
pub fn si(v: f64) -> String {
    let a = v.abs();
    if a >= 1e12 {
        format!("{:.2}T", v / 1e12)
    } else if a >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if a >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if a >= 1e3 {
        format!("{:.2}K", v / 1e3)
    } else {
        format!("{v:.2}")
    }
}

pub fn mb(bytes: f64) -> String {
    format!("{:.2}", bytes / (1024.0 * 1024.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = Table::new(["eps", "mem"]);
        t.row(["0.4", "39.39"]);
        t.row(["0.9999", "1"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines[0].starts_with("eps     mem"));
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn si_formats() {
        assert_eq!(si(3.92e11), "392.00G");
        assert_eq!(si(1.04e12), "1.04T");
        assert_eq!(si(42.0), "42.00");
    }
}

//! Wallclock timing helpers.

use std::time::Instant;

/// Times a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Simple scope timer that reports on drop when verbose.
pub struct ScopeTimer {
    label: String,
    start: Instant,
    verbose: bool,
}

impl ScopeTimer {
    pub fn new(label: impl Into<String>, verbose: bool) -> Self {
        ScopeTimer {
            label: label.into(),
            start: Instant::now(),
            verbose,
        }
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        if self.verbose {
            eprintln!("[time] {}: {:.3}s", self.label, self.elapsed());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_result() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}

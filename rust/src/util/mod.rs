//! Dependency-free support layer: JSON, CLI parsing, ASCII tables,
//! timing, statistics, a scoped thread pool, and a mini property-test
//! harness.  These exist because the vendored crate set has no serde /
//! clap / criterion / rayon / proptest — each is implemented from
//! scratch at the size this project needs.

pub mod alloc;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod timer;

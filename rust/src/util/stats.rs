//! Summary statistics used by the bench harness and eval modules.

/// Streaming mean/variance (Welford).
#[derive(Debug, Default, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a copy of the samples (nearest-rank).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[idx.min(v.len() - 1)]
}

pub fn median(samples: &[f64]) -> f64 {
    percentile(samples, 50.0)
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.var() - direct_var).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}

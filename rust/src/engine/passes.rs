//! Optimization-pass pipeline over the layer-graph IR (DESIGN.md
//! §Pass pipeline).
//!
//! The planner (`engine::graph`) transforms the node program before
//! execution; this module holds the pass *vocabulary* and the generic
//! machinery the planner runs:
//!
//! * [`PassSet`] — which passes are enabled (`--passes all|none|<list>`,
//!   `WASI_PASSES` env), every pass individually disableable;
//! * [`Liveness`] — first-def/last-use interval collection over the
//!   simulated executor walk;
//! * [`assign_offsets`] — first-fit arena offset assignment with
//!   free-hole coalescing, turning the interval set into one pre-sized
//!   arena per executor;
//! * [`check_disjoint`] — the independent verifier that rejects any
//!   assignment where two simultaneously-live buffers overlap.
//!
//! Every pass preserves bit-identity with the unoptimized program: the
//! arena pass only changes *where* each intermediate lives (same kernel
//! calls, same deterministic partitioning, same accumulation order),
//! prepack stores the exact f32 image the dequantizing GEMM would have
//! materialized per call, folding precomputes a value with the same
//! single-operation arithmetic the runtime would have used, and fusion
//! selects epilogue forms that are algebraically *and* bitwise the same
//! as the split ops (`gelu(y + b)` either way).  `tests/passes.rs`
//! pins all of this against the unoptimized walk at every precision.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

use anyhow::{bail, Result};

/// Bit for [`PassSet`]: constant folding of frozen-base subgraphs
/// (pack-time precompute of the CLS+positional assemble constant).
const FOLD: u8 = 1 << 0;
/// Bit for [`PassSet`]: epilogue fusion of adjacent scale/bias/GELU
/// into the GEMM epilogue (`linalg::kernels::Epilogue`).
const FUSE: u8 = 1 << 1;
/// Bit for [`PassSet`]: buffer-liveness analysis + arena reuse (the
/// planned executors that drive per-step heap allocation to ~zero).
const ARENA: u8 = 1 << 2;
/// Bit for [`PassSet`]: pre-packed weight panels for quantized weights
/// (`linalg::kernels::PackedPanel`), packed once at plan time — f32
/// images for bf16 weights, raw quantized bytes for int8 (fed to the
/// true-integer GEMM).
const PREPACK: u8 = 1 << 3;

const ALL: u8 = FOLD | FUSE | ARENA | PREPACK;

/// The enabled optimization passes, as threaded through
/// `--passes all|none|fold,fuse,arena,prepack` and the `WASI_PASSES`
/// environment variable.  The default is *all* passes on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PassSet {
    bits: u8,
}

impl PassSet {
    /// Every pass enabled (the default).
    pub fn all() -> Self {
        PassSet { bits: ALL }
    }

    /// No passes: the executor runs the original unoptimized walks.
    pub fn none() -> Self {
        PassSet { bits: 0 }
    }

    /// Parse `all`, `none`, or a comma-separated subset of
    /// `fold,fuse,arena,prepack`.  Unknown names are refused with the
    /// valid vocabulary (refusal-first, like the artifact parsers).
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("all") {
            return Ok(Self::all());
        }
        if s.eq_ignore_ascii_case("none") || s.is_empty() {
            return Ok(Self::none());
        }
        let mut bits = 0u8;
        for name in s.split(',') {
            bits |= match name.trim() {
                "fold" => FOLD,
                "fuse" => FUSE,
                "arena" => ARENA,
                "prepack" => PREPACK,
                other => bail!(
                    "unknown pass {other:?} (valid: all, none, or a comma list \
                     of fold, fuse, arena, prepack)"
                ),
            };
        }
        Ok(PassSet { bits })
    }

    /// Constant folding of frozen-base subgraphs enabled?
    pub fn fold(&self) -> bool {
        self.bits & FOLD != 0
    }

    /// Epilogue fusion enabled?
    pub fn fuse(&self) -> bool {
        self.bits & FUSE != 0
    }

    /// Arena-planned buffer reuse enabled?
    pub fn arena(&self) -> bool {
        self.bits & ARENA != 0
    }

    /// Pre-packed weight panels enabled?
    pub fn prepack(&self) -> bool {
        self.bits & PREPACK != 0
    }

    /// This set minus one named pass (test helper for per-pass pins).
    pub fn without(&self, name: &str) -> Result<Self> {
        let mask = Self::parse(name)?;
        Ok(PassSet { bits: self.bits & !mask.bits })
    }
}

impl fmt::Display for PassSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.bits == ALL {
            return write!(f, "all");
        }
        if self.bits == 0 {
            return write!(f, "none");
        }
        let mut names = Vec::new();
        if self.fold() {
            names.push("fold");
        }
        if self.fuse() {
            names.push("fuse");
        }
        if self.arena() {
            names.push("arena");
        }
        if self.prepack() {
            names.push("prepack");
        }
        write!(f, "{}", names.join(","))
    }
}

/// Process-global pass override (same idiom as
/// `util::threadpool::set_num_threads`): `0xFF` = unset, otherwise the
/// `PassSet` bits.  Set once at CLI startup from `--passes`; executors
/// capture the resolved set at construction.
static PASS_OVERRIDE: AtomicU8 = AtomicU8::new(UNSET);
const UNSET: u8 = 0xFF;

/// Install a process-global pass set (CLI `--passes`).  Takes
/// precedence over the `WASI_PASSES` environment variable.
pub fn set_passes(p: PassSet) {
    PASS_OVERRIDE.store(p.bits, Ordering::SeqCst);
}

/// The pass set new executors capture: the [`set_passes`] override if
/// one was installed, else `WASI_PASSES` (refusing a malformed value),
/// else all passes.
pub fn current_passes() -> Result<PassSet> {
    let bits = PASS_OVERRIDE.load(Ordering::SeqCst);
    if bits != UNSET {
        return Ok(PassSet { bits });
    }
    match std::env::var("WASI_PASSES") {
        Ok(s) => PassSet::parse(&s)
            .map_err(|e| anyhow::anyhow!("WASI_PASSES: {e}")),
        Err(std::env::VarError::NotPresent) => Ok(PassSet::all()),
        Err(std::env::VarError::NotUnicode(_)) => {
            bail!("WASI_PASSES is not valid unicode")
        }
    }
}

// ---------------------------------------------------------------------------
// Buffer liveness + arena assignment
// ---------------------------------------------------------------------------

/// A planned slice of the executor arena: `arena[off .. off + len]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufRange {
    /// Element offset into the arena.
    pub off: usize,
    /// Length in elements.
    pub len: usize,
}

/// One intermediate buffer's lifetime over the simulated walk:
/// first defined at timestep `def`, last read at timestep `last`
/// (inclusive), `elems` f32 elements wide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    /// Buffer id (index into [`ArenaLayout::offsets`]).
    pub id: usize,
    /// Timestep of the defining write.
    pub def: usize,
    /// Timestep of the last read (inclusive).
    pub last: usize,
    /// Size in f32 elements.
    pub elems: usize,
}

/// Interval collector: the planner replays the executor walk, calling
/// [`Liveness::alloc`] at each buffer definition and
/// [`Liveness::touch`] at each later use; the finished interval set
/// feeds [`assign_offsets`].
#[derive(Debug, Default)]
pub struct Liveness {
    intervals: Vec<Interval>,
}

impl Liveness {
    pub fn new() -> Self {
        Liveness { intervals: Vec::new() }
    }

    /// Record a buffer defined at `time`, returning its id.
    pub fn alloc(&mut self, time: usize, elems: usize) -> usize {
        let id = self.intervals.len();
        self.intervals.push(Interval { id, def: time, last: time, elems });
        id
    }

    /// Record a use of buffer `id` at `time`, extending its lifetime.
    pub fn touch(&mut self, id: usize, time: usize) {
        let iv = &mut self.intervals[id];
        if time > iv.last {
            iv.last = time;
        }
    }

    /// The collected intervals, in definition order.
    pub fn intervals(&self) -> &[Interval] {
        &self.intervals
    }

    /// Sum of all buffer sizes — what per-step allocation would touch
    /// without reuse (the denominator of the arena-savings metric).
    pub fn sum_elems(&self) -> usize {
        self.intervals.iter().map(|iv| iv.elems).sum()
    }
}

/// The arena assignment produced by [`assign_offsets`]: one element
/// offset per interval id, plus the total arena length.
#[derive(Debug, Clone)]
pub struct ArenaLayout {
    /// Element offset per buffer id.
    pub offsets: Vec<usize>,
    /// Total arena length in elements.
    pub total: usize,
}

/// Return `layout.offsets[iv.id]` as a [`BufRange`].
pub fn range_of(layout: &ArenaLayout, iv: &Interval) -> BufRange {
    BufRange { off: layout.offsets[iv.id], len: iv.elems }
}

/// First-fit arena assignment over liveness intervals.
///
/// Intervals are processed in definition order; a buffer whose last
/// use precedes the current definition returns its range to a sorted,
/// coalesced free list, and each new buffer takes the first hole that
/// fits (extending the arena when none does).  Two buffers share an
/// offset range only when their lifetimes are provably disjoint —
/// [`check_disjoint`] re-verifies that property independently.
pub fn assign_offsets(intervals: &[Interval]) -> ArenaLayout {
    let mut order: Vec<usize> = (0..intervals.len()).collect();
    order.sort_by_key(|&i| (intervals[i].def, intervals[i].id));
    let mut offsets = vec![0usize; intervals.len()];
    // (offset, len) holes, sorted by offset, adjacent holes coalesced.
    let mut free: Vec<(usize, usize)> = Vec::new();
    // (last, offset, len) of currently-live placements.
    let mut active: Vec<(usize, usize, usize)> = Vec::new();
    let mut total = 0usize;
    for &i in &order {
        let iv = &intervals[i];
        // Expire buffers whose last use is strictly before this def:
        // a buffer read at the same timestep a new one is written must
        // NOT share storage (GEMM src/dst overlap).
        let mut j = 0;
        while j < active.len() {
            if active[j].0 < iv.def {
                let (_, off, len) = active.swap_remove(j);
                release(&mut free, off, len);
            } else {
                j += 1;
            }
        }
        let mut found = None;
        for (fi, &(off, len)) in free.iter().enumerate() {
            if len >= iv.elems {
                found = Some((fi, off));
                break;
            }
        }
        let off = match found {
            Some((fi, off)) => {
                let (hole_off, hole_len) = free[fi];
                if hole_len == iv.elems {
                    free.remove(fi);
                } else {
                    free[fi] = (hole_off + iv.elems, hole_len - iv.elems);
                }
                off
            }
            None => {
                let off = total;
                total += iv.elems;
                off
            }
        };
        offsets[iv.id] = off;
        if iv.elems > 0 {
            active.push((iv.last, off, iv.elems));
        }
    }
    ArenaLayout { offsets, total }
}

/// Return a hole to the sorted free list, coalescing with neighbors.
fn release(free: &mut Vec<(usize, usize)>, off: usize, len: usize) {
    if len == 0 {
        return;
    }
    let pos = free.partition_point(|&(o, _)| o < off);
    free.insert(pos, (off, len));
    if pos + 1 < free.len() && free[pos].0 + free[pos].1 == free[pos + 1].0 {
        free[pos].1 += free[pos + 1].1;
        free.remove(pos + 1);
    }
    if pos > 0 && free[pos - 1].0 + free[pos - 1].1 == free[pos].0 {
        free[pos - 1].1 += free[pos].1;
        free.remove(pos);
    }
}

/// Independent verifier: any two intervals whose lifetimes overlap in
/// time must occupy disjoint arena ranges.  Run by the planner on
/// every layout it produces (a violated assignment is a planner bug
/// that would silently corrupt activations, so it fails loudly).
pub fn check_disjoint(intervals: &[Interval], layout: &ArenaLayout) -> Result<()> {
    if layout.offsets.len() != intervals.len() {
        bail!(
            "layout has {} offsets for {} intervals",
            layout.offsets.len(),
            intervals.len()
        );
    }
    for a in intervals {
        let (ao, ae) = (layout.offsets[a.id], a.elems);
        if ae > 0 && ao + ae > layout.total {
            bail!(
                "buffer {} range [{ao}, {}) exceeds arena total {}",
                a.id,
                ao + ae,
                layout.total
            );
        }
        for b in intervals {
            if b.id <= a.id || a.elems == 0 || b.elems == 0 {
                continue;
            }
            let lifetimes_overlap = a.def <= b.last && b.def <= a.last;
            if !lifetimes_overlap {
                continue;
            }
            let (bo, be) = (layout.offsets[b.id], b.elems);
            let ranges_overlap = ao < bo + be && bo < ao + ae;
            if ranges_overlap {
                bail!(
                    "live buffers {} (t[{}..={}], [{ao}, {})) and {} \
                     (t[{}..={}], [{bo}, {})) overlap in the arena",
                    a.id,
                    a.def,
                    a.last,
                    ao + ae,
                    b.id,
                    b.def,
                    b.last,
                    bo + be
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_refuses_unknown() {
        assert_eq!(PassSet::parse("all").unwrap(), PassSet::all());
        assert_eq!(PassSet::parse("none").unwrap(), PassSet::none());
        let p = PassSet::parse("arena,prepack").unwrap();
        assert!(p.arena() && p.prepack() && !p.fold() && !p.fuse());
        assert_eq!(p.to_string(), "arena,prepack");
        assert_eq!(PassSet::parse("fold,fuse,arena,prepack").unwrap(), PassSet::all());
        assert_eq!(PassSet::all().to_string(), "all");
        assert_eq!(PassSet::none().to_string(), "none");
        let err = PassSet::parse("arena,banana").unwrap_err().to_string();
        assert!(err.contains("banana"), "{err}");
        assert!(!PassSet::all().without("arena").unwrap().arena());
        assert!(PassSet::all().without("arena").unwrap().prepack());
    }

    #[test]
    fn liveness_intervals_extend_with_touch() {
        let mut lv = Liveness::new();
        let a = lv.alloc(0, 10);
        let b = lv.alloc(1, 20);
        lv.touch(a, 3);
        lv.touch(a, 2); // non-monotone touch must not shrink
        assert_eq!(lv.intervals()[a], Interval { id: a, def: 0, last: 3, elems: 10 });
        assert_eq!(lv.intervals()[b], Interval { id: b, def: 1, last: 1, elems: 20 });
        assert_eq!(lv.sum_elems(), 30);
    }

    #[test]
    fn assign_offsets_reuses_dead_ranges() {
        // a: t0..t1, b: t1..t2 (overlaps a at t1), c: t3.. (a and b dead).
        let mut lv = Liveness::new();
        let a = lv.alloc(0, 8);
        let b = lv.alloc(1, 8);
        lv.touch(a, 1);
        lv.touch(b, 2);
        let c = lv.alloc(3, 12);
        lv.touch(c, 4);
        let layout = assign_offsets(lv.intervals());
        check_disjoint(lv.intervals(), &layout).unwrap();
        assert_ne!(layout.offsets[a], layout.offsets[b], "a and b are simultaneously live");
        // c fits into the coalesced hole left by a+b: no arena growth.
        assert_eq!(layout.total, 16, "{layout:?}");
        assert!(layout.offsets[c] + 12 <= 16);
    }

    #[test]
    fn check_disjoint_rejects_overlapping_assignment() {
        let mut lv = Liveness::new();
        let a = lv.alloc(0, 8);
        let b = lv.alloc(1, 8);
        lv.touch(a, 2);
        lv.touch(b, 2);
        let mut layout = assign_offsets(lv.intervals());
        check_disjoint(lv.intervals(), &layout).unwrap();
        // Hand-corrupt: collide b onto a while both are live.
        layout.offsets[b] = layout.offsets[a] + 4;
        let err = check_disjoint(lv.intervals(), &layout).unwrap_err().to_string();
        assert!(err.contains("overlap"), "{err}");
    }

    #[test]
    fn zero_length_buffers_never_collide() {
        let mut lv = Liveness::new();
        let a = lv.alloc(0, 0);
        let b = lv.alloc(0, 16);
        lv.touch(a, 5);
        lv.touch(b, 5);
        let layout = assign_offsets(lv.intervals());
        check_disjoint(lv.intervals(), &layout).unwrap();
        assert_eq!(layout.total, 16);
    }

    #[test]
    fn first_fit_prefers_lowest_hole() {
        // Two dead holes [0,4) and [8,16); a 3-elem buffer should land
        // at offset 0, not 8.
        let mut lv = Liveness::new();
        let a = lv.alloc(0, 4);
        let b = lv.alloc(0, 4); // live past everything: pins [4, 8)
        let c = lv.alloc(0, 8);
        lv.touch(b, 10);
        lv.touch(a, 1);
        lv.touch(c, 1);
        let d = lv.alloc(3, 3);
        lv.touch(d, 4);
        let layout = assign_offsets(lv.intervals());
        check_disjoint(lv.intervals(), &layout).unwrap();
        assert_eq!(layout.offsets[d], layout.offsets[a]);
        assert_eq!(layout.total, 16);
    }

    #[test]
    fn current_passes_honors_override() {
        // NOTE: touches the process-global override; keep this the only
        // test that does (parallel test threads share it).
        set_passes(PassSet::parse("fuse").unwrap());
        assert_eq!(current_passes().unwrap().to_string(), "fuse");
        set_passes(PassSet::all());
        assert_eq!(current_passes().unwrap(), PassSet::all());
    }
}

//! Pure-rust demo artifact generator: a tiny "pretrained" ViT written
//! straight into the manifest format, so the default offline build can
//! fine-tune end to end (`wasi-train demo --out DIR` then
//! `wasi-train train --artifacts DIR --engine native`) without Python,
//! JAX, or PJRT anywhere.
//!
//! The fixture mirrors `python/compile/aot.py`'s layout: a vanilla
//! (dense) variant plus a WASI variant whose MLP linears are factored at
//! explained-variance threshold ε from the *same* base weights, with
//! ASI warm-start bases in the state vector.  Weights follow the
//! power-law-spectrum "pretrained" premise (DESIGN.md §3).  No train
//! HLO is emitted — `--engine auto` therefore routes training to the
//! native engine in every build configuration — and the (manifest-
//! required) infer HLO is a stub the native engine never reads.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::data::rng::Pcg64;
use crate::linalg::matrix::Mat;
use crate::linalg::subspace::SubspaceState;
use crate::runtime::write_f32_file;
use crate::util::json::{arr, num, str as jstr, Json};
use crate::wasi::wsi::{powerlaw, WsiFactors};

/// Shape of the generated demo model.
#[derive(Debug, Clone)]
pub struct DemoConfig {
    pub image: usize,
    pub patch: usize,
    pub dim: usize,
    pub depth: usize,
    pub mlp_ratio: usize,
    pub classes: usize,
    pub batch: usize,
    /// Explained-variance threshold for the WASI variant's factorization.
    pub eps: f64,
    pub seed: u64,
}

impl Default for DemoConfig {
    fn default() -> Self {
        DemoConfig {
            image: 16,
            patch: 4,
            dim: 32,
            depth: 2,
            mlp_ratio: 2,
            classes: 10,
            batch: 8,
            eps: 0.8,
            seed: 41,
        }
    }
}

impl DemoConfig {
    pub fn tokens(&self) -> usize {
        let g = self.image / self.patch;
        g * g + 1
    }

    pub fn patch_dim(&self) -> usize {
        self.patch * self.patch * 3
    }

    pub fn hidden(&self) -> usize {
        self.dim * self.mlp_ratio
    }

    pub fn input_dim(&self) -> usize {
        self.image * self.image * 3
    }
}

/// A parameter dict packed exactly like the AOT pipeline packs one:
/// name-sorted tensors concatenated into a flat f32 vector.
struct FlatSet {
    tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl FlatSet {
    fn new() -> Self {
        FlatSet { tensors: BTreeMap::new() }
    }

    fn add(&mut self, name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) {
        let name = name.into();
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "tensor {name} shape/data mismatch"
        );
        self.tensors.insert(name, (shape, data));
    }

    /// (flat vector, manifest `param_spec`/`state_spec` JSON).
    fn pack(&self) -> (Vec<f32>, Json) {
        let mut flat = Vec::new();
        let mut spec = Vec::new();
        for (name, (shape, data)) in &self.tensors {
            spec.push(Json::Obj(BTreeMap::from([
                ("name".to_string(), jstr(name.clone())),
                ("shape".to_string(), arr(shape.iter().map(|&d| num(d as f64)))),
                ("offset".to_string(), num(flat.len() as f64)),
            ])));
            flat.extend_from_slice(data);
        }
        (flat, arr(spec))
    }
}

/// Base "pretrained" dense parameter set (shared by both variants).
fn base_params(cfg: &DemoConfig) -> FlatSet {
    let mut rng = Pcg64::new(cfg.seed);
    let mut p = FlatSet::new();
    let d = cfg.dim;
    let mut seed = cfg.seed.wrapping_mul(977);
    let mut next_seed = || {
        seed = seed.wrapping_add(1);
        seed
    };
    let mut linear = |p: &mut FlatSet, name: &str, o: usize, i: usize| {
        p.add(format!("{name}.w"), vec![o, i], powerlaw(o, i, 0.8, next_seed()).data);
        p.add(format!("{name}.b"), vec![o], vec![0.0; o]);
    };
    linear(&mut p, "embed", d, cfg.patch_dim());
    p.add("cls", vec![1, 1, d], rng.normal_vec(d).iter().map(|v| 0.02 * v).collect());
    p.add(
        "pos",
        vec![1, cfg.tokens(), d],
        rng.normal_vec(cfg.tokens() * d).iter().map(|v| 0.02 * v).collect(),
    );
    for b in 0..cfg.depth {
        linear(&mut p, &format!("blocks.{b}.attn.qkv"), 3 * d, d);
        linear(&mut p, &format!("blocks.{b}.attn.proj"), d, d);
        linear(&mut p, &format!("blocks.{b}.mlp.fc1"), cfg.hidden(), d);
        linear(&mut p, &format!("blocks.{b}.mlp.fc2"), d, cfg.hidden());
        for ln in ["ln1", "ln2"] {
            p.add(format!("blocks.{b}.{ln}.g"), vec![d], vec![1.0; d]);
            p.add(format!("blocks.{b}.{ln}.b"), vec![d], vec![0.0; d]);
        }
    }
    p.add("norm.g", vec![d], vec![1.0; d]);
    p.add("norm.b", vec![d], vec![0.0; d]);
    linear(&mut p, "head", cfg.classes, d);
    p
}

struct Variant {
    name: String,
    params: FlatSet,
    state: FlatSet,
    eps: Option<f64>,
    weight_ranks: BTreeMap<String, usize>,
    asi_ranks: BTreeMap<String, Vec<usize>>,
    layer_dims: BTreeMap<String, (Vec<usize>, Vec<usize>)>,
}

/// Factor the MLP linears of the base set at ε (the WASI variant).
fn wasi_variant(cfg: &DemoConfig, base: &FlatSet) -> Variant {
    let mut params = FlatSet::new();
    let mut state = FlatSet::new();
    let mut weight_ranks = BTreeMap::new();
    let mut asi_ranks = BTreeMap::new();
    let mut layer_dims = BTreeMap::new();
    let t = cfg.tokens();
    let mut seed = cfg.seed.wrapping_mul(31);
    for (name, (shape, data)) in &base.tensors {
        let factored = name.contains(".mlp.fc") && name.ends_with(".w");
        if !factored {
            params.add(name.clone(), shape.clone(), data.clone());
            continue;
        }
        let prefix = name.trim_end_matches(".w").to_string();
        let (o, i) = (shape[0], shape[1]);
        let w = Mat::from_vec(o, i, data.clone());
        let (factors, _) = WsiFactors::init_svd(&w, cfg.eps);
        let k = factors.k();
        params.add(format!("{prefix}.l"), vec![o, k], factors.l.data);
        params.add(format!("{prefix}.r"), vec![k, i], factors.r.data);
        weight_ranks.insert(prefix.clone(), k);
        let dims = [cfg.batch, t, i];
        let ranks = vec![dims[0].min(4), dims[1].min(8), dims[2].min(12)];
        for (m, (&dm, &rm)) in dims.iter().zip(&ranks).enumerate() {
            seed = seed.wrapping_add(1);
            let mut rng = Pcg64::new(seed);
            let u = SubspaceState::random(dm, rm, &mut rng).u;
            state.add(format!("{prefix}.u{}", m + 1), vec![dm, rm], u.data);
        }
        asi_ranks.insert(prefix.clone(), ranks);
        layer_dims.insert(prefix.clone(), (vec![o, i], vec![t, i]));
    }
    let tag = format!("vit_demo_wasi_eps{}", (cfg.eps * 100.0).round() as usize);
    Variant {
        name: tag,
        params,
        state,
        eps: Some(cfg.eps),
        weight_ranks,
        asi_ranks,
        layer_dims,
    }
}

fn variant_json(cfg: &DemoConfig, v: &Variant, dir: &Path) -> Result<Json> {
    let (pflat, pspec) = v.params.pack();
    let (sflat, sspec) = v.state.pack();
    let params_file = format!("{}.params.f32", v.name);
    write_f32_file(dir.join(&params_file), &pflat)?;
    // No train_hlo on purpose: `--engine auto` then routes BOTH
    // training and inference to the native engine even on a
    // PJRT-capable build (the engine selectors' no-train-artifact
    // rule), instead of compiling a stub.  infer_hlo is a required
    // manifest key, so a stub file is still written; only a forced
    // `--engine hlo` ever touches it.
    let infer_hlo = format!("{}.infer.hlo.txt", v.name);
    std::fs::write(dir.join(&infer_hlo), "HloModule native_demo_stub\n")
        .with_context(|| format!("writing {infer_hlo}"))?;
    let mut m = BTreeMap::from([
        ("infer_hlo".to_string(), jstr(infer_hlo)),
        ("params_file".to_string(), jstr(params_file)),
        ("params_len".to_string(), num(pflat.len() as f64)),
        ("state_len".to_string(), num(sflat.len() as f64)),
        ("batch".to_string(), num(cfg.batch as f64)),
        ("input_dim".to_string(), num(cfg.input_dim() as f64)),
        ("classes".to_string(), num(cfg.classes as f64)),
        ("param_spec".to_string(), pspec),
        ("state_spec".to_string(), sspec),
    ]);
    if !sflat.is_empty() {
        let state_file = format!("{}.state.f32", v.name);
        write_f32_file(dir.join(&state_file), &sflat)?;
        m.insert("state_file".to_string(), jstr(state_file));
    }
    if let Some(eps) = v.eps {
        m.insert("eps".to_string(), num(eps));
    }
    if !v.weight_ranks.is_empty() {
        m.insert(
            "weight_ranks".to_string(),
            Json::Obj(
                v.weight_ranks
                    .iter()
                    .map(|(k, &r)| (k.clone(), num(r as f64)))
                    .collect(),
            ),
        );
    }
    if !v.asi_ranks.is_empty() {
        m.insert(
            "asi_ranks".to_string(),
            Json::Obj(
                v.asi_ranks
                    .iter()
                    .map(|(k, r)| (k.clone(), arr(r.iter().map(|&x| num(x as f64)))))
                    .collect(),
            ),
        );
    }
    if !v.layer_dims.is_empty() {
        m.insert(
            "layer_dims".to_string(),
            Json::Obj(
                v.layer_dims
                    .iter()
                    .map(|(k, (oi, act))| {
                        (
                            k.clone(),
                            Json::Obj(BTreeMap::from([
                                ("out_in".to_string(), arr(oi.iter().map(|&x| num(x as f64)))),
                                ("act".to_string(), arr(act.iter().map(|&x| num(x as f64)))),
                            ])),
                        )
                    })
                    .collect(),
            ),
        );
    }
    Ok(Json::Obj(m))
}

/// Write a complete demo artifact set (manifest + params/state + stub
/// HLO) into `dir`.  Returns the generated model names
/// (vanilla first, then the WASI variant).
pub fn write_demo_artifacts(dir: impl AsRef<Path>, cfg: &DemoConfig) -> Result<Vec<String>> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let base = base_params(cfg);
    let wasi = wasi_variant(cfg, &base);
    let vanilla = Variant {
        name: "vit_demo_vanilla".into(),
        params: base,
        state: FlatSet::new(),
        eps: None,
        weight_ranks: BTreeMap::new(),
        asi_ranks: BTreeMap::new(),
        layer_dims: BTreeMap::new(),
    };

    let mut models = BTreeMap::new();
    let mut names = Vec::new();
    for v in [&vanilla, &wasi] {
        models.insert(v.name.clone(), variant_json(cfg, v, dir)?);
        names.push(v.name.clone());
    }
    let manifest = Json::Obj(BTreeMap::from([
        ("models".to_string(), Json::Obj(models)),
        ("eps_grid".to_string(), arr([num(cfg.eps)])),
        (
            "demo_config".to_string(),
            Json::Obj(BTreeMap::from([
                ("image".to_string(), num(cfg.image as f64)),
                ("patch".to_string(), num(cfg.patch as f64)),
                ("dim".to_string(), num(cfg.dim as f64)),
                ("depth".to_string(), num(cfg.depth as f64)),
                ("classes".to_string(), num(cfg.classes as f64)),
            ])),
        ),
    ]));
    std::fs::write(dir.join("manifest.json"), manifest.to_string())
        .with_context(|| format!("writing {}/manifest.json", dir.display()))?;
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn demo_manifest_loads_and_validates() {
        let dir = std::env::temp_dir().join("wasi_demo_gen_test");
        let _ = std::fs::remove_dir_all(&dir);
        let names = write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        assert_eq!(names.len(), 2);
        let m = Manifest::load(&dir).unwrap();
        let van = m.model("vit_demo_vanilla").unwrap();
        assert_eq!(van.input_dim, 16 * 16 * 3);
        assert_eq!(van.state_len, 0);
        assert!(van.params_len > 0);
        let wasi = m.model("vit_demo_wasi_eps80").unwrap();
        assert!(wasi.state_len > 0);
        assert!(!wasi.state_spec.is_empty());
        assert!(!wasi.weight_ranks.is_empty());
        // Factored variant is strictly smaller than dense on the factored
        // layers, so total params shrink.
        assert!(wasi.params_len < van.params_len);
        // Params load and match their manifest lengths.
        assert_eq!(van.load_params().unwrap().len(), van.params_len);
        assert_eq!(wasi.load_state().unwrap().len(), wasi.state_len);
    }

    #[test]
    fn demo_generation_is_deterministic() {
        let d1 = std::env::temp_dir().join("wasi_demo_det_1");
        let d2 = std::env::temp_dir().join("wasi_demo_det_2");
        for d in [&d1, &d2] {
            let _ = std::fs::remove_dir_all(d);
            write_demo_artifacts(d, &DemoConfig::default()).unwrap();
        }
        let p1 = std::fs::read(d1.join("vit_demo_vanilla.params.f32")).unwrap();
        let p2 = std::fs::read(d2.join("vit_demo_vanilla.params.f32")).unwrap();
        assert_eq!(p1, p2);
        let m1 = std::fs::read_to_string(d1.join("manifest.json")).unwrap();
        let m2 = std::fs::read_to_string(d2.join("manifest.json")).unwrap();
        assert_eq!(m1, m2);
    }
}

//! Native full-model engines: thin drivers over the layer-graph IR
//! (`engine::graph`).
//!
//! [`NativeModelEngine`] owns the flat parameter/state vectors and a
//! [`GraphExecutor`]; one training step is
//! `forward → softmax-CE → backward → update-program → state pack`,
//! every stage executed by the graph against the flat vectors through
//! the shared kernel layer (`linalg::kernels`).  [`NativeInferEngine`]
//! is the batch-size-free inference walk of the same graph with fused
//! bias/GELU epilogues.
//!
//! The architecture reconstruction (`ModelPlan`), the node program, and
//! the documented attention-substitution argument live in
//! `engine/graph.rs` (DESIGN.md §4).

use anyhow::{bail, Result};

use crate::precision::{round_bf16_inplace, Precision};
use crate::runtime::{ModelEntry, StepOutput};

use super::graph::{DeltaOverlay, GraphExecutor, LayerGraph, ModelPlan, NodeTiming, PackedParams};
use super::{EngineKind, InferEngine, TrainEngine};

/// Pure-rust training engine for one ViT variant.
pub struct NativeModelEngine {
    entry: ModelEntry,
    exec: GraphExecutor,
    flat_params: Vec<f32>,
    flat_state: Vec<f32>,
    /// Reused flat gradient buffer (zeroed each step).
    grads: Vec<f32>,
    /// Weight storage precision: `Bf16` rounds the flat parameter
    /// vector to bf16-representable values after load, restore, and
    /// every optimizer step (DESIGN.md §Precision).  Compute stays f32.
    precision: Precision,
}

impl NativeModelEngine {
    /// Build from a manifest entry, loading initial params/state from
    /// the artifact files (f32 weight storage).
    pub fn load(entry: &ModelEntry) -> Result<Self> {
        Self::load_with(entry, Precision::F32)
    }

    /// [`NativeModelEngine::load`] with an explicit weight-storage
    /// precision (`--precision`).  Int8 is inference-only and refused.
    pub fn load_with(entry: &ModelEntry, precision: Precision) -> Result<Self> {
        let params = entry.load_params()?;
        let state = entry.load_state()?;
        Self::from_flat_with(entry, params, state, precision)
    }

    /// Build from explicit flat vectors (checkpoint restore, tests).
    pub fn from_flat(entry: &ModelEntry, params: Vec<f32>, state: Vec<f32>) -> Result<Self> {
        Self::from_flat_with(entry, params, state, Precision::F32)
    }

    /// [`NativeModelEngine::from_flat`] at an explicit precision.
    pub fn from_flat_with(
        entry: &ModelEntry,
        mut params: Vec<f32>,
        state: Vec<f32>,
        precision: Precision,
    ) -> Result<Self> {
        if !precision.trainable() {
            bail!(
                "precision {precision} is inference-only; train with f32 or bf16 \
                 and quantize the result for serving"
            );
        }
        if params.len() != entry.params_len {
            bail!("params length {} != manifest {}", params.len(), entry.params_len);
        }
        if state.len() != entry.state_len {
            bail!("state length {} != manifest {}", state.len(), entry.state_len);
        }
        if precision == Precision::Bf16 {
            round_bf16_inplace(&mut params);
        }
        let graph = LayerGraph::from_entry(entry)?;
        let mut exec = GraphExecutor::new(graph, entry)?;
        exec.load_state(&state)?;
        Ok(NativeModelEngine {
            entry: entry.clone(),
            grads: vec![0.0; params.len()],
            exec,
            flat_params: params,
            flat_state: state,
            precision,
        })
    }

    /// The weight-storage precision this engine maintains.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// The reconstructed architecture plan.
    pub fn plan(&self) -> &ModelPlan {
        self.exec.plan()
    }

    /// Toggle per-node wallclock accumulation (latency attribution).
    pub fn set_profiling(&mut self, on: bool) {
        self.exec.set_profiling(on);
    }

    pub fn reset_timings(&mut self) {
        self.exec.reset_timings();
    }

    /// Per-node accumulated (fwd, bwd) wallclock since the last reset.
    pub fn node_timings(&self) -> Vec<NodeTiming> {
        self.exec.node_timings()
    }

    #[cfg(test)]
    fn loss_only(&mut self, x: &[f32], y_onehot: &[f32]) -> Result<f32> {
        let logits = self.exec.forward_train(&self.flat_params, x)?;
        Ok(self.exec.loss_and_grad(&logits, y_onehot).0)
    }
}

impl TrainEngine for NativeModelEngine {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn step(&mut self, x: &[f32], y_onehot: &[f32], lr: f32) -> Result<StepOutput> {
        if y_onehot.len() != self.entry.batch * self.entry.classes {
            bail!("y length {} mismatch", y_onehot.len());
        }
        let logits = self.exec.forward_train(&self.flat_params, x)?;
        let (loss, accuracy, dlogits) = self.exec.loss_and_grad(&logits, y_onehot);
        self.grads.fill(0.0);
        self.exec.backward(&self.flat_params, &dlogits, &mut self.grads)?;
        self.exec.update(&mut self.flat_params, &self.grads, lr);
        if self.precision == Precision::Bf16 {
            // bf16 weight storage: what persists between steps is the
            // rounded vector, exactly as a 2-byte store would hold.
            round_bf16_inplace(&mut self.flat_params);
        }
        self.exec.store_state(&mut self.flat_state);
        Ok(StepOutput { loss, accuracy })
    }

    fn params(&self) -> &[f32] {
        &self.flat_params
    }

    fn state(&self) -> &[f32] {
        &self.flat_state
    }

    fn restore(&mut self, params: &[f32], state: &[f32]) -> Result<()> {
        if params.len() != self.flat_params.len() || state.len() != self.flat_state.len() {
            bail!(
                "restore shape mismatch: params {} (want {}), state {} (want {})",
                params.len(),
                self.flat_params.len(),
                state.len(),
                self.flat_state.len()
            );
        }
        self.flat_params.copy_from_slice(params);
        if self.precision == Precision::Bf16 {
            round_bf16_inplace(&mut self.flat_params);
        }
        self.flat_state.copy_from_slice(state);
        self.exec.load_state(&self.flat_state)
    }

    fn backend(&self) -> &'static str {
        "native"
    }

    fn kind(&self) -> EngineKind {
        EngineKind::Native
    }

    fn restrict_to_subspace(&mut self) -> Result<usize> {
        self.exec.restrict_to_subspace()
    }
}

// ---------------------------------------------------------------------------
// Inference
// ---------------------------------------------------------------------------

/// Pure-rust inference for one ViT variant: Eq. 8 only for factored
/// layers (no ASI compression, matching the lowered infer step), batch
/// size free, GELU fused into the fc1 epilogue.
///
/// A quantized engine ([`NativeInferEngine::load_quantized`])
/// additionally holds a [`PackedParams`] set built from the variant's
/// initial params at load time and serves `infer_quantized` straight
/// from that compact representation — the pool caches one such engine
/// per (variant, precision).
pub struct NativeInferEngine {
    entry: ModelEntry,
    exec: GraphExecutor,
    packed: Option<PackedParams>,
}

impl NativeInferEngine {
    pub fn load(entry: &ModelEntry) -> Result<Self> {
        let graph = LayerGraph::from_entry(entry)?;
        // Inference never compresses activations: skip ASI construction.
        let exec = GraphExecutor::new_infer(graph, entry)?;
        Ok(NativeInferEngine { entry: entry.clone(), exec, packed: None })
    }

    /// Quantize-on-load: build the engine AND pack the variant's
    /// initial parameters at `precision` (f32 packs nothing and
    /// behaves exactly like [`NativeInferEngine::load`]).
    pub fn load_quantized(entry: &ModelEntry, precision: Precision) -> Result<Self> {
        if precision == Precision::F32 {
            return Self::load(entry);
        }
        let params = entry.load_params()?;
        Self::load_quantized_from(entry, &params, precision)
    }

    /// [`NativeInferEngine::load_quantized`] over an already-loaded
    /// flat parameter vector (the pool passes its cached initial
    /// params instead of re-reading the artifact file).
    pub fn load_quantized_from(
        entry: &ModelEntry,
        params: &[f32],
        precision: Precision,
    ) -> Result<Self> {
        let mut eng = Self::load(entry)?;
        if precision != Precision::F32 {
            eng.packed = Some(PackedParams::pack(entry, params, precision)?);
        }
        Ok(eng)
    }

    /// The precision of the held packed set (`F32` when none).
    pub fn precision(&self) -> Precision {
        self.packed.as_ref().map(|p| p.precision()).unwrap_or(Precision::F32)
    }

    /// Payload bytes of the held packed set, if any.
    pub fn packed_bytes(&self) -> Option<usize> {
        self.packed.as_ref().map(|p| p.bytes())
    }

    /// Pack an explicit parameter vector (a finished job's personalized
    /// weights) at `precision` for [`NativeInferEngine::infer_packed`].
    pub fn pack_params(&self, params: &[f32], precision: Precision) -> Result<PackedParams> {
        PackedParams::pack(&self.entry, params, precision)
    }

    /// Inference from the quantize-on-load packed set.  Errors on an
    /// engine constructed without one (callers select the packed path
    /// by precision, so this is a wiring bug, not a user mistake).
    pub fn infer_quantized(&self, x: &[f32]) -> Result<Vec<f32>> {
        let packed = self
            .packed
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("engine holds no packed params (f32 pool entry)"))?;
        self.infer_packed(packed, x)
    }

    /// Inference from an explicit packed set (personalized params).
    pub fn infer_packed(&self, packed: &PackedParams, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() % self.entry.input_dim != 0 {
            bail!("x length {} not a multiple of input_dim {}", x.len(), self.entry.input_dim);
        }
        let b = x.len() / self.entry.input_dim;
        self.exec.infer_packed(packed, x, b)
    }

    /// Inference with a variant's subspace factors overlaid on the
    /// shared frozen base (delta-apply serving, DESIGN.md §Variant
    /// store) — the personalized vector is never materialized.
    pub fn infer_overlay(&self, overlay: &DeltaOverlay, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() % self.entry.input_dim != 0 {
            bail!("x length {} not a multiple of input_dim {}", x.len(), self.entry.input_dim);
        }
        let b = x.len() / self.entry.input_dim;
        self.exec.infer_overlay(overlay, x, b)
    }
}

impl InferEngine for NativeInferEngine {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn infer(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        if x.len() % self.entry.input_dim != 0 {
            bail!("x length {} not a multiple of input_dim {}", x.len(), self.entry.input_dim);
        }
        let b = x.len() / self.entry.input_dim;
        self.exec.infer(params, x, b)
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::super::demo::{write_demo_artifacts, DemoConfig};
    use super::*;
    use crate::data::synth::VisionTask;
    use crate::runtime::Manifest;

    fn demo_manifest(tag: &str) -> Manifest {
        let dir = std::env::temp_dir().join(format!("wasi_engine_test_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        write_demo_artifacts(&dir, &DemoConfig::default()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn tensor_roundtrips_offsets_and_shapes() {
        let m = demo_manifest("roundtrip");
        let entry = m.model("vit_demo_wasi_eps80").unwrap();
        let eng = NativeModelEngine::load(entry).unwrap();
        let initial = entry.load_params().unwrap();
        // Construction must not perturb the flat vector.
        assert_eq!(eng.params(), &initial[..]);
        for spec in &entry.param_spec {
            let (data, shape) = eng.tensor(&spec.name).unwrap();
            assert_eq!(shape, spec.shape, "{}", spec.name);
            assert_eq!(data, &initial[spec.offset..spec.offset + spec.numel()]);
        }
        // Restore round-trip.
        let mut eng = eng;
        let state = entry.load_state().unwrap();
        eng.restore(&initial, &state).unwrap();
        assert_eq!(eng.params(), &initial[..]);
        assert_eq!(eng.state(), &state[..]);
    }

    #[test]
    fn training_reduces_loss_on_both_parameterizations() {
        // Repeated steps on one fixed batch: the loss must fall
        // decisively (2.x -> ~1.6 in the numpy oracle of this math).
        let m = demo_manifest("train");
        for model in ["vit_demo_vanilla", "vit_demo_wasi_eps80"] {
            let entry = m.model(model).unwrap();
            let mut eng = NativeModelEngine::load(entry).unwrap();
            let mut task = VisionTask::new("t", entry.classes, 16, 0.5, 4, 233);
            let (x, y, _) = task.batch_onehot(entry.batch);
            let mut losses = Vec::new();
            for _ in 0..16 {
                let out = eng.step(&x, &y, 0.1).unwrap();
                assert!(out.loss.is_finite(), "{model}: loss must stay finite");
                losses.push(out.loss);
            }
            let head: f32 = losses[..4].iter().sum::<f32>() / 4.0;
            let tail: f32 = losses[12..].iter().sum::<f32>() / 4.0;
            assert!(
                tail < head * 0.9,
                "{model}: loss should fall decisively ({losses:?})"
            );
        }
    }

    #[test]
    fn loss_only_is_consistent_with_step_loss() {
        let m = demo_manifest("lossonly");
        let entry = m.model("vit_demo_vanilla").unwrap();
        let mut eng = NativeModelEngine::load(entry).unwrap();
        let mut task = VisionTask::new("l", entry.classes, 16, 0.5, 4, 5);
        let (x, y, _) = task.batch_onehot(entry.batch);
        let probe = eng.loss_only(&x, &y).unwrap();
        let step = eng.step(&x, &y, 0.05).unwrap();
        assert!((probe - step.loss).abs() < 1e-5, "{probe} vs {}", step.loss);
    }

    #[test]
    fn infer_matches_train_engine_forward_at_load() {
        let m = demo_manifest("infer");
        let entry = m.model("vit_demo_vanilla").unwrap();
        let mut eng = NativeModelEngine::load(entry).unwrap();
        let infer = NativeInferEngine::load(entry).unwrap();
        let mut task = VisionTask::new("i", entry.classes, 16, 0.5, 4, 9);
        let (x, _, _) = task.batch_onehot(entry.batch);
        let params = eng.params().to_vec();
        let train_logits = eng.exec.forward_train(&params, &x).unwrap();
        let infer_logits = infer.infer(eng.params(), &x).unwrap();
        assert_eq!(train_logits.len(), infer_logits.len());
        for (a, b) in train_logits.iter().zip(&infer_logits) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn node_timings_accumulate_when_profiling() {
        let m = demo_manifest("prof");
        let entry = m.model("vit_demo_wasi_eps80").unwrap();
        let mut eng = NativeModelEngine::load(entry).unwrap();
        eng.set_profiling(true);
        let mut task = VisionTask::new("p", entry.classes, 16, 0.5, 4, 7);
        let (x, y, _) = task.batch_onehot(entry.batch);
        eng.step(&x, &y, 0.05).unwrap();
        let timings = eng.node_timings();
        assert!(!timings.is_empty());
        assert!(timings.iter().all(|t| t.fwd_s >= 0.0 && t.bwd_s >= 0.0));
        assert!(timings.iter().any(|t| t.calls > 0));
        assert!(timings.iter().any(|t| t.label.starts_with("wasi:")));
        eng.reset_timings();
        assert!(eng.node_timings().iter().all(|t| t.calls == 0));
    }
}
